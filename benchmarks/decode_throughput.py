"""Decode throughput: per-token host loop vs device-resident scanned decode.

The serving-side half of the paper's efficiency claim: with a compressed
O(n/c·r) cache the per-step compute is tiny, so decode latency is dominated
by the Python-level host round-trip per generated token. This benchmark
measures tokens/sec of the legacy per-token loop
(`ServingEngine.generate_batch_per_token`) against the chunked `lax.scan`
decode (`generate_batch`, one host sync per `decode_chunk` tokens) at
prefill lengths S ∈ {512, 4096}, on the default (fused-kernel) compute path.

Emits the standard ``name,us_per_call,derived`` CSV lines with us_per_call =
microseconds per generated token.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.configs.base import AttentionConfig, LinformerConfig, ModelConfig
from repro.models import model as M
from repro.serving import ServingEngine


def _cfg(max_seq: int) -> ModelConfig:
    return ModelConfig(
        name="decode-bench",
        num_layers=2,
        d_model=64,
        vocab_size=512,
        max_seq_len=max_seq,
        attention=AttentionConfig(
            kind="linformer_causal",
            num_heads=4,
            num_kv_heads=2,
            head_dim=16,
            linformer=LinformerConfig(block_size=128, block_slots=8),
        ),
        dtype="float32",
        remat="none",
    )


def _time_decode(eng, fn, prompt, n_tokens, iters):
    """Median decode-phase seconds: prefill runs OUTSIDE the timer (each
    iteration needs a fresh cache — the scanned path donates its buffers)."""
    times = []
    for i in range(iters + 1):                 # first iteration = warmup
        cache, logits = eng.prefill(prompt)
        jax.block_until_ready(cache)
        t0 = time.perf_counter()
        out = fn(cache, logits, n_tokens)
        times.append(time.perf_counter() - t0)
    return float(np.median(times[1:])), out


def _eos_free_engine(S, max_seq, n_tokens):
    """Engine + prompt whose greedy decode emits no EOS for n_tokens steps.

    An EOS early-exit would truncate BOTH loops and the benchmark would time
    prefill only, so scan over init seeds until the full-length trajectory is
    EOS-free (deterministic per codebase state; almost always seed 0 or 1).
    """
    from repro.data.pipeline import EOS
    cfg = _cfg(max_seq)
    for seed in range(16):
        params = M.init_params(jax.random.PRNGKey(seed), cfg)
        eng = ServingEngine(params, cfg, max_seq=max_seq,
                            cache_dtype=jnp.float32, decode_chunk=32)
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(S + seed), (2, S), 4,
                               cfg.vocab_size), np.int32)
        out = eng.generate_batch(prompt, n_tokens)
        if not (out == EOS).any():
            return eng, prompt
    raise RuntimeError("no EOS-free decode trajectory found in 16 seeds")


def run(quick: bool = True):
    n_tokens = 32 if quick else 128
    iters = 2 if quick else 3
    results = {}
    for S in [512, 4096]:
        max_seq = S + 256
        eng, prompt = _eos_free_engine(S, max_seq, n_tokens)

        t_old, out_old = _time_decode(eng, eng.decode_tokens_per_token,
                                      prompt, n_tokens, iters)
        t_new, out_new = _time_decode(eng, eng.decode_tokens,
                                      prompt, n_tokens, iters)
        assert (out_old == out_new).all(), "loops diverged"
        tok_s_old = n_tokens / t_old
        tok_s_new = n_tokens / t_new
        emit(f"decode_throughput/per_token/s{S}", t_old / n_tokens * 1e6,
             f"tok_per_s={tok_s_old:.1f}")
        emit(f"decode_throughput/scanned/s{S}", t_new / n_tokens * 1e6,
             f"tok_per_s={tok_s_new:.1f},speedup={t_old / t_new:.2f}x")
        results[S] = (tok_s_old, tok_s_new)
    write_bench_json("decode_throughput", {
        "mode": "quick" if quick else "full",
        "n_tokens": n_tokens,
        "by_prefill_len": {
            str(S): {"tok_per_s_per_token_loop": round(old, 1),
                     "tok_per_s_scanned": round(new, 1),
                     "speedup": round(new / old, 2)}
            for S, (old, new) in results.items()},
    })
    return results


if __name__ == "__main__":
    run(quick=False)
