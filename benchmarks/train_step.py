"""Training-step latency: fused Pallas backward vs reference-recompute.

The training-side half of the paper's O(n) claim: with the fused backward
(`kernels/blockwise_causal_attn.blockwise_causal_attn_bwd`) a train step
runs fwd + bwd without a second, unfused attention pass — the
`backward_impl="reference"` oracle instead re-runs the pure-jnp reference
under jax.vjp, materializing the (S × nb·r) global score tensor the fused
path exists to avoid. This benchmark times the COMPLETE jit'd train step
(fwd + bwd + clip + AdamW, `train/trainer.make_train_step` — the exact
production step) for both backward implementations on a linformer_causal
config whose compressed width nb·r is large enough that the recompute
matters.

With ``--mesh tp=2`` (or ``tp=2,sp=2``) the same fused step additionally
runs SHARDED through the attention execution plan (parallel/plan.py:
head-parallel fused kernels inside shard_map, per-shard E/F) on a forced
8-host-device mesh, recording sharded-vs-single-shard step time under the
``mesh`` key of BENCH_train_step.json. On this CPU container the forced
host devices share 2 cores, so the sharded wall time measures plan/dispatch
overhead, not speedup — the number that matters on real chips is the
per-device memory and step-time scaling the plan unlocks.

Emits the standard ``name,us_per_call,derived`` CSV lines (us_per_call =
microseconds per train step) and records BENCH_train_step.json via
`common.write_bench_json` (merging, so single-device and mesh legs can be
recorded by separate runs).

    PYTHONPATH=src python -m benchmarks.train_step [--smoke] [--mesh tp=2] \
        [--trace-out t.json] [--metrics-out m.jsonl]

With ``--trace-out`` / ``--metrics-out`` a `repro.telemetry.Telemetry` is
attached: every timed call (compile included) becomes a span in the
Perfetto trace and per-impl step timings land in the metrics JSONL
(summarize with ``python -m benchmarks.report --trace t.json``).
"""
from __future__ import annotations

import json
import os
import sys


def _parse_mesh_arg(argv):
    if "--mesh" in argv:
        i = argv.index("--mesh")
        if i + 1 < len(argv):
            return argv[i + 1]
        raise SystemExit("--mesh needs a spec, e.g. --mesh tp=2")
    return None


def _parse_path_arg(argv, flag):
    if flag in argv:
        i = argv.index(flag)
        if i + 1 < len(argv):
            return argv[i + 1]
        raise SystemExit(f"{flag} needs a path")
    return None


# The device count is locked at first jax import, so the forced-host-device
# flag must be set before anything below pulls jax in.
_MESH_SPEC = _parse_mesh_arg(sys.argv[1:]) if __name__ == "__main__" else None
if _MESH_SPEC and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import REPO_ROOT, emit, write_bench_json
from repro.configs.base import (AttentionConfig, LinformerConfig, ModelConfig,
                                OptimizerConfig)
from repro.models import model as M
from repro.optim import adamw_init
from repro.telemetry import as_telemetry
from repro.train.trainer import make_train_step


def _mesh_shards(spec: str):
    """'tp=2' / 'tp=2,sp=2' -> (model_shards, seq_shards)."""
    tp, sp = 1, 1
    for part in spec.split(","):
        key, _, val = part.partition("=")
        if key == "tp":
            tp = int(val)
        elif key == "sp":
            sp = int(val)
        else:
            raise SystemExit(f"unknown mesh axis {key!r} (use tp=/sp=)")
    return tp, sp


def _merge_bench_json(payload: dict) -> None:
    """Merge into BENCH_train_step.json so the --mesh leg and the default
    fused-vs-reference leg don't clobber each other's records."""
    path = os.path.join(REPO_ROOT, "BENCH_train_step.json")
    rec = {}
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
    rec.update(payload)
    write_bench_json("train_step", rec)


def _cfg(backward_impl: str, *, seq: int, block_size: int,
         block_slots: int) -> ModelConfig:
    return ModelConfig(
        name="train-step-bench",
        num_layers=2,
        d_model=64,
        vocab_size=512,
        max_seq_len=seq,
        attention=AttentionConfig(
            kind="linformer_causal",
            num_heads=4,
            num_kv_heads=2,
            head_dim=16,
            backward_impl=backward_impl,
            linformer=LinformerConfig(block_size=block_size,
                                      block_slots=block_slots),
        ),
        dtype="float32",
        remat="none",
    )


def _cfg_exact(*, seq: int, k: int) -> ModelConfig:
    """Exact (bidirectional) Linformer at the autotuner's committed
    shape bucket: S=2048, k=128, H=4/Hkv=2/Dh=16 fp32 — the shapes the
    fused projection + attention kernels launch with inside the step."""
    return ModelConfig(
        name="train-step-bench-exact",
        num_layers=2,
        d_model=64,
        vocab_size=512,
        max_seq_len=seq,
        objective="mlm",
        attention=AttentionConfig(
            kind="linformer",
            backend="fused",
            num_heads=4,
            num_kv_heads=2,
            head_dim=16,
            causal=False,
            use_rope=False,
            linformer=LinformerConfig(k=k, sharing="layerwise"),
        ),
        dtype="float32",
        remat="none",
    )


def _time_cfg(cfg: ModelConfig, *, seq: int, batch_size: int, iters: int,
              ctx=None, telemetry=None, label: str = "") -> float:
    """Median seconds of the jit'd train step (first call = compile+warmup,
    excluded). No donation so the same buffers are re-fed every iteration.
    With `ctx` the step runs on the mesh, params laid out per the sharding
    rules and attention through the plan's shard_map. With `telemetry` every
    call (compile included) becomes a span in the exported trace."""
    import contextlib
    tel = as_telemetry(telemetry)
    opt_cfg = OptimizerConfig()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params, opt_cfg)
    toks = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (batch_size, seq)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks),
             "loss_mask": jnp.ones((batch_size, seq), jnp.int32)}
    if ctx is None:
        step = jax.jit(make_train_step(cfg, opt_cfg))
        scope = contextlib.nullcontext()
    else:
        from repro.parallel.sharding import param_shardings
        step = jax.jit(make_train_step(cfg, opt_cfg, ctx=ctx),
                       in_shardings=(param_shardings(params, ctx),
                                     None, None))
        scope = ctx.mesh
    with scope:
        with tel.span("train_step_compile", cat="bench", impl=label):
            jax.block_until_ready(step(params, opt_state, batch))
        times = []
        for i in range(iters):
            t0 = time.perf_counter()
            with tel.span("train_step", cat="bench", impl=label, iter=i):
                jax.block_until_ready(step(params, opt_state, batch))
            times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _time_step(backward_impl: str, *, seq: int, block_size: int,
               block_slots: int, batch_size: int, iters: int,
               ctx=None, telemetry=None, label: str = "") -> float:
    cfg = _cfg(backward_impl, seq=seq, block_size=block_size,
               block_slots=block_slots)
    return _time_cfg(cfg, seq=seq, batch_size=batch_size, iters=iters,
                     ctx=ctx, telemetry=telemetry, label=label)


def run(quick: bool = True, telemetry=None):
    # quick: nb·r = 1024 compressed slots at S=2048 — small enough for the
    # smoke gate, big enough that the reference recompute's global score
    # tensor dominates its backward. full: the 4k training shape.
    if quick:
        seq, block_size, block_slots, batch_size, iters = 2048, 64, 32, 1, 3
    else:
        seq, block_size, block_slots, batch_size, iters = 4096, 128, 32, 1, 5
    tel = as_telemetry(telemetry)
    results = {}
    for impl in ("fused", "reference"):
        t = _time_step(impl, seq=seq, block_size=block_size,
                       block_slots=block_slots, batch_size=batch_size,
                       iters=iters, telemetry=telemetry, label=impl)
        results[impl] = t
        tel.record("bench_train_step", impl=impl, seq=seq,
                   step_ms=round(t * 1e3, 3),
                   steps_per_s=round(1.0 / t, 3))
        emit(f"train_step/{impl}/s{seq}", t * 1e6,
             f"steps_per_s={1.0 / t:.3f}")
    speedup = results["reference"] / results["fused"]
    emit(f"train_step/speedup/s{seq}", results["fused"] * 1e6,
         f"fused_over_reference={speedup:.2f}x")
    _merge_bench_json({
        "mode": "quick" if quick else "full",
        "shape": {"seq": seq, "block_size": block_size,
                  "block_slots": block_slots, "batch": batch_size,
                  "slots_total": seq // block_size * block_slots},
        "step_ms_fused": round(results["fused"] * 1e3, 1),
        "step_ms_reference": round(results["reference"] * 1e3, 1),
        "speedup_fused_over_reference": round(speedup, 2),
    })
    run_exact_tuned(quick, telemetry=telemetry)
    return results


def run_exact_tuned(quick: bool = True, telemetry=None):
    """The autotuned leg: the exact (bidirectional) form's COMPLETE train
    step with the hand-picked kernel defaults vs the committed
    TUNING.json winners (block_q/block_s resolved through the attention
    plan's table lookup). Both runs pin the table with override() so the
    comparison reflects exactly those two tables, not whatever
    REPRO_TUNING_PATH happens to say. block_q is bitwise-invariant and
    block_s moves only the reduction tiling, so this is a pure perf leg."""
    from repro.tune.table import TuningTable, override
    tel = as_telemetry(telemetry)
    seq, k, iters = (2048, 128, 3) if quick else (2048, 128, 5)
    cfg = _cfg_exact(seq=seq, k=k)
    tuned_table = TuningTable.load()
    results = {}
    for label, tab in (("defaults", TuningTable()),
                       ("tuned", tuned_table)):
        with override(tab):
            t = _time_cfg(cfg, seq=seq, batch_size=1, iters=iters,
                          telemetry=telemetry, label=f"exact_{label}")
        results[label] = t
        tel.record("bench_train_step_exact", table=label, seq=seq,
                   step_ms=round(t * 1e3, 3))
        emit(f"train_step/exact_{label}/s{seq}", t * 1e6,
             f"steps_per_s={1.0 / t:.3f}")
    speedup = results["defaults"] / results["tuned"]
    entry = next((e for e in tuned_table.entries
                  if e["form"] == "exact"), None)
    emit(f"train_step/exact_tuned_speedup/s{seq}",
         results["tuned"] * 1e6, f"tuned_over_defaults={speedup:.2f}x")
    _merge_bench_json({
        "exact_tuned": {
            "mode": "quick" if quick else "full",
            "shape": {"seq": seq, "k": k, "batch": 1},
            "step_ms_defaults": round(results["defaults"] * 1e3, 1),
            "step_ms_tuned": round(results["tuned"] * 1e3, 1),
            "tuned_over_defaults": round(speedup, 2),
            "table_params": entry["params"] if entry else None,
        },
    })
    return results


def run_mesh(spec: str, quick: bool = True, telemetry=None):
    """Fused train step sharded through the attention plan vs the same step
    single-shard, on a forced-8-host-device mesh. The manual region shards
    whatever the spec names (tp=2 → heads only; the leftover data axis is
    wider than the batch, which then rides replicated inside the region)."""
    from repro.launch.mesh import make_local_mesh
    from repro.parallel.sharding import ParallelCtx
    tp, sp = _mesh_shards(spec)
    if quick:
        seq, block_size, block_slots, batch_size, iters = 512, 64, 16, 2, 3
    else:
        seq, block_size, block_slots, batch_size, iters = 2048, 64, 32, 2, 3
    single = _time_step("fused", seq=seq, block_size=block_size,
                        block_slots=block_slots, batch_size=batch_size,
                        iters=iters, telemetry=telemetry,
                        label="single_shard")
    mesh = make_local_mesh(model_shards=tp, seq_shards=sp)
    ctx = ParallelCtx(mesh=mesh, fsdp="none")
    sharded = _time_step("fused", seq=seq, block_size=block_size,
                         block_slots=block_slots, batch_size=batch_size,
                         iters=iters, ctx=ctx, telemetry=telemetry,
                         label=f"mesh_{spec}")
    emit(f"train_step/mesh_{spec}/s{seq}", sharded * 1e6,
         f"single_shard_ms={single * 1e3:.1f}")
    _merge_bench_json({
        "mesh": {
            "spec": spec, "devices": len(jax.devices()),
            "mode": "quick" if quick else "full",
            "shape": {"seq": seq, "block_size": block_size,
                      "block_slots": block_slots, "batch": batch_size},
            "step_ms_sharded": round(sharded * 1e3, 1),
            "step_ms_single_shard": round(single * 1e3, 1),
            "sharded_over_single": round(single / sharded, 2),
        },
    })
    return {"single": single, "sharded": sharded}


if __name__ == "__main__":
    _trace_out = _parse_path_arg(sys.argv[1:], "--trace-out")
    _metrics_out = _parse_path_arg(sys.argv[1:], "--metrics-out")
    _tel = None
    if _trace_out or _metrics_out:
        from repro.telemetry import Telemetry
        _tel = Telemetry()
    if _MESH_SPEC:
        run_mesh(_MESH_SPEC, quick="--smoke" in sys.argv[1:], telemetry=_tel)
    else:
        run(quick="--smoke" in sys.argv[1:], telemetry=_tel)
    if _tel is not None and _trace_out:
        _tel.export_trace(_trace_out, metadata={"bench": "train_step"})
        print(f"# trace -> {_trace_out}")
    if _tel is not None and _metrics_out:
        _tel.export_metrics_jsonl(_metrics_out)
        print(f"# metrics -> {_metrics_out}")
