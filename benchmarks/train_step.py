"""Training-step latency: fused Pallas backward vs reference-recompute.

The training-side half of the paper's O(n) claim: with the fused backward
(`kernels/blockwise_causal_attn.blockwise_causal_attn_bwd`) a train step
runs fwd + bwd without a second, unfused attention pass — the
`backward_impl="reference"` oracle instead re-runs the pure-jnp reference
under jax.vjp, materializing the (S × nb·r) global score tensor the fused
path exists to avoid. This benchmark times the COMPLETE jit'd train step
(fwd + bwd + clip + AdamW, `train/trainer.make_train_step` — the exact
production step) for both backward implementations on a linformer_causal
config whose compressed width nb·r is large enough that the recompute
matters.

Emits the standard ``name,us_per_call,derived`` CSV lines (us_per_call =
microseconds per train step) and records BENCH_train_step.json via
`common.write_bench_json`.

    PYTHONPATH=src python -m benchmarks.train_step [--smoke]
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.configs.base import (AttentionConfig, LinformerConfig, ModelConfig,
                                OptimizerConfig)
from repro.models import model as M
from repro.optim import adamw_init
from repro.train.trainer import make_train_step


def _cfg(backward_impl: str, *, seq: int, block_size: int,
         block_slots: int) -> ModelConfig:
    return ModelConfig(
        name="train-step-bench",
        num_layers=2,
        d_model=64,
        vocab_size=512,
        max_seq_len=seq,
        attention=AttentionConfig(
            kind="linformer_causal",
            num_heads=4,
            num_kv_heads=2,
            head_dim=16,
            backward_impl=backward_impl,
            linformer=LinformerConfig(block_size=block_size,
                                      block_slots=block_slots),
        ),
        dtype="float32",
        remat="none",
    )


def _time_step(backward_impl: str, *, seq: int, block_size: int,
               block_slots: int, batch_size: int, iters: int) -> float:
    """Median seconds of the jit'd train step (first call = compile+warmup,
    excluded). No donation so the same buffers are re-fed every iteration."""
    cfg = _cfg(backward_impl, seq=seq, block_size=block_size,
               block_slots=block_slots)
    opt_cfg = OptimizerConfig()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params, opt_cfg)
    toks = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (batch_size, seq)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks),
             "loss_mask": jnp.ones((batch_size, seq), jnp.int32)}
    step = jax.jit(make_train_step(cfg, opt_cfg))
    jax.block_until_ready(step(params, opt_state, batch))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(step(params, opt_state, batch))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(quick: bool = True):
    # quick: nb·r = 1024 compressed slots at S=2048 — small enough for the
    # smoke gate, big enough that the reference recompute's global score
    # tensor dominates its backward. full: the 4k training shape.
    if quick:
        seq, block_size, block_slots, batch_size, iters = 2048, 64, 32, 1, 3
    else:
        seq, block_size, block_slots, batch_size, iters = 4096, 128, 32, 1, 5
    results = {}
    for impl in ("fused", "reference"):
        t = _time_step(impl, seq=seq, block_size=block_size,
                       block_slots=block_slots, batch_size=batch_size,
                       iters=iters)
        results[impl] = t
        emit(f"train_step/{impl}/s{seq}", t * 1e6,
             f"steps_per_s={1.0 / t:.3f}")
    speedup = results["reference"] / results["fused"]
    emit(f"train_step/speedup/s{seq}", results["fused"] * 1e6,
         f"fused_over_reference={speedup:.2f}x")
    write_bench_json("train_step", {
        "mode": "quick" if quick else "full",
        "shape": {"seq": seq, "block_size": block_size,
                  "block_slots": block_slots, "batch": batch_size,
                  "slots_total": seq // block_size * block_slots},
        "step_ms_fused": round(results["fused"] * 1e3, 1),
        "step_ms_reference": round(results["reference"] * 1e3, 1),
        "speedup_fused_over_reference": round(speedup, 2),
    })
    return results


if __name__ == "__main__":
    run(quick="--smoke" in sys.argv[1:])
