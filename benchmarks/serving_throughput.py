"""End-to-end serving throughput: continuous (slot) batching vs the static
bucketed baseline on a mixed-length arrival trace.

The workload is adversarial for static batching in exactly the way real
traffic is: prompts of several lengths (so the static scheduler fragments
into per-length buckets) and a long-tailed generation-budget mix (a few long
requests per bucket, so short rows sit EOS-frozen while the bucket drains).
Continuous batching retires a slot the moment its request completes and
admits the next queued request between decode chunks, keeping the pool full.

The slot pool is at most HALF the request count, so the continuous scheduler
must actually recycle slots to win. Both schedulers see identical requests
and produce byte-identical greedy outputs (asserted here and in
tests/test_serving_scheduler.py) — the comparison is pure scheduling.

A second continuous run replays a Poisson-ish arrival trace (requests become
admissible at increasing chunk indices) to record occupancy under staggered
arrivals rather than an instantaneous backlog.

Emits ``name,us_per_call,derived`` CSV lines (us_per_call = microseconds per
generated token) and writes BENCH_serving.json at the repo root.

    python -m benchmarks.serving_throughput [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.configs.base import AttentionConfig, LinformerConfig, ModelConfig
from repro.data.pipeline import EOS
from repro.models import model as M
from repro.serving import ServingEngine


def _cfg(max_seq: int) -> ModelConfig:
    return ModelConfig(
        name="serving-bench",
        num_layers=2,
        d_model=64,
        vocab_size=512,
        max_seq_len=max_seq,
        attention=AttentionConfig(
            kind="linformer_causal",
            num_heads=4,
            num_kv_heads=2,
            head_dim=16,
            linformer=LinformerConfig(block_size=8, block_slots=4),
        ),
        dtype="float32",
        remat="none",
    )


def _trace(n_requests: int, long_budget: int, short_budget: int, seed: int):
    """Mixed-length prompts (block multiples: scheduling, not remainder
    decode, is what's under test), a long-tailed budget mix spread across
    the length buckets, shuffled arrival order, Poisson-ish arrival gaps."""
    rng = np.random.default_rng(seed)
    prompts, budgets = [], []
    for i in range(n_requests):
        plen = int(rng.choice([8, 16, 24]))
        prompts.append(list(rng.integers(4, 512, plen)))
        budgets.append(long_budget if i % 4 == 0 else short_budget)
    order = rng.permutation(n_requests)
    prompts = [prompts[i] for i in order]
    budgets = [budgets[i] for i in order]
    arrivals = np.cumsum(rng.poisson(0.4, n_requests)).tolist()
    return prompts, budgets, arrivals


def _engine(max_seq: int, decode_chunk: int, seed: int) -> ServingEngine:
    cfg = _cfg(max_seq)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    return ServingEngine(params, cfg, max_seq=max_seq,
                         cache_dtype=jnp.float32, decode_chunk=decode_chunk)


def _eos_free_setup(n_requests, long_budget, short_budget, max_seq,
                    decode_chunk):
    """Engine + trace whose greedy outputs never hit EOS: every request runs
    its full budget, so both schedulers do identical token work and the
    measurement isolates scheduling (same trick as decode_throughput)."""
    for seed in range(16):
        eng = _engine(max_seq, decode_chunk, seed)
        prompts, budgets, arrivals = _trace(n_requests, long_budget,
                                            short_budget, seed)
        outs = eng.serve_static(prompts, budgets, max_batch=4)
        if all(len(o) == b for o, b in zip(outs, budgets)):
            return eng, prompts, budgets, arrivals
    raise RuntimeError("no EOS-free serving trace found in 16 seeds")


def run(quick: bool = True):
    if quick:
        n_requests, pool, long_b, short_b, chunk = 8, 4, 24, 6, 6
        iters = 3
    else:
        n_requests, pool, long_b, short_b, chunk = 16, 8, 40, 8, 8
        iters = 3
    max_seq = 24 + long_b + chunk  # longest prompt + budget + chunk slack
    max_seq = ((max_seq + 7) // 8) * 8
    eng, prompts, budgets, arrivals = _eos_free_setup(
        n_requests, long_b, short_b, max_seq, chunk)
    total_budget = sum(budgets)

    # warmup: compile every (batch, length) shape both paths will touch
    static_warm = eng.serve_static(prompts, budgets, max_batch=pool)
    cont_warm = eng.serve(prompts, budgets, max_batch=pool)
    assert cont_warm == static_warm, \
        "continuous and static schedulers diverged"

    def timed(fn):
        times, out = [], None
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn()
            times.append(time.perf_counter() - t0)
        return float(np.median(times)), out

    t_static, outs_static = timed(
        lambda: eng.serve_static(prompts, budgets, max_batch=pool))
    t_cont, cont_res = timed(
        lambda: eng.serve(prompts, budgets, max_batch=pool,
                          return_scheduler=True))
    outs_cont, sched = cont_res
    assert outs_cont == outs_static

    n_tok = sum(len(o) for o in outs_cont)
    assert n_tok == total_budget  # EOS-free: every request ran its budget
    tok_s_static = n_tok / t_static
    tok_s_cont = n_tok / t_cont
    speedup = t_static / t_cont
    occ = sched.stats.mean_occupancy

    # replay with the Poisson-ish arrival trace: occupancy under staggered
    # arrivals instead of an instantaneous backlog
    _, sched_arr = eng.serve(prompts, budgets, max_batch=pool,
                             arrival_chunks=arrivals, return_scheduler=True)

    emit(f"serving_throughput/static/n{n_requests}",
         t_static / n_tok * 1e6, f"tok_per_s={tok_s_static:.1f}")
    emit(f"serving_throughput/continuous/n{n_requests}",
         t_cont / n_tok * 1e6,
         f"tok_per_s={tok_s_cont:.1f},speedup={speedup:.2f}x,"
         f"occupancy={occ:.2f}")
    emit(f"serving_throughput/continuous_arrivals/n{n_requests}",
         0.0, f"occupancy={sched_arr.stats.mean_occupancy:.2f},"
              f"idle_ticks={sched_arr.stats.idle_ticks}")

    write_bench_json("serving", {
        "mode": "smoke" if quick else "full",
        "n_requests": n_requests,
        "slot_pool": pool,
        "decode_chunk": chunk,
        "total_tokens": n_tok,
        "static": {"wall_s": round(t_static, 3),
                   "tok_per_s": round(tok_s_static, 1)},
        "continuous": {"wall_s": round(t_cont, 3),
                       "tok_per_s": round(tok_s_cont, 1),
                       "mean_occupancy": round(occ, 3),
                       "chunks": sched.stats.chunks,
                       "row_steps": sched.stats.row_steps},
        "continuous_with_arrivals": {
            "mean_occupancy": round(sched_arr.stats.mean_occupancy, 3),
            "idle_ticks": sched_arr.stats.idle_ticks},
        "speedup": round(speedup, 2),
        "outputs_match_static": True,
    })
    return {"speedup": speedup, "tok_s_cont": tok_s_cont,
            "tok_s_static": tok_s_static, "occupancy": occ}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast mode for the scripts/check.sh smoke gate")
    args = ap.parse_args()
    res = run(quick=args.smoke)
    print(f"# speedup continuous/static = {res['speedup']:.2f}x")
