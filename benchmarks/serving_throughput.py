"""End-to-end serving throughput, four traces:

**mixed** — continuous (slot) batching vs the static bucketed baseline on a
mixed-length arrival trace. The workload is adversarial for static batching
in exactly the way real traffic is: prompts of several lengths (so the
static scheduler fragments into per-length buckets) and a long-tailed
generation-budget mix (a few long requests per bucket, so short rows sit
EOS-frozen while the bucket drains). Continuous batching retires a slot the
moment its request completes and admits the next queued request between
decode chunks, keeping the pool full. The slot pool is at most HALF the
request count, so the continuous scheduler must actually recycle slots to
win. A second continuous run replays a Poisson-ish arrival trace to record
occupancy under staggered arrivals rather than an instantaneous backlog.

**long_prompt** — chunked admission (``prefill_chunk > 0``) vs monolithic
admission within the continuous scheduler, on a trace where long prompts of
SEVERAL DISTINCT lengths arrive into a pool of short decoding requests.
This is adversarial for monolithic admission twice over: (a) every distinct
prompt length compiles its own B=1 prefill forward — the cold (first-serve)
wall time grows with the number of novel lengths, while chunked admission
re-uses one fixed chunk shape for every length (padding the final chunk);
(b) each long prefill stalls every decoding slot for a full forward
(head-of-line blocking), while chunked admission interleaves chunk and
decode rounds and batches co-arriving prompts into shared forwards. Both
cold (includes jit, the realistic serve-novel-traffic number) and warm
(steady-state) walls are reported; outputs are asserted byte-identical.
A third ``chunked_paged`` leg replays the trace on the paged int8 pool
(perf-only — int8 storage rounds, so no byte comparison). Measured result:
paged chunk writes do NOT close the chunked-vs-monolithic warm gap at
these CPU smoke shapes — the warm gap is dominated by the extra
interleaved scheduler rounds and (for paged) the per-group page gather +
quantize, not by the dense pool's full-pool scatter; the paged pool's win
is capacity (see the capacity trace), not warm wall.

**capacity** — the paged, quantized pool's memory claim: at EQUAL arena
bytes, the paged int8 pool (block-table indirection over a shared page
arena, int8 payloads + per-block fp32 scales) must hold >= 3x the resident
requests of the dense fp32 pool. The trace sizes the paged pool to the
dense pool's exact byte budget (``ServingEngine.cache_bytes``), serves an
oversubscribing backlog through both, and records resident rows, mean
occupancy, tokens/s, and page-allocator traffic. The 3x floor is asserted
IN-RUN, so scripts/check.sh gates it on every smoke run.

**overload** — graceful degradation: a 2×+ oversubscribed low-priority
backlog against a bounded admission queue, with a thin stream of
high-priority, deadline-carrying arrivals. The trace asserts the SLO
contract rather than timing it: the queue sheds part of the backlog with
explicit ShedResults (no silent unbounded queueing), the high-priority
requests preempt their way into the pool, and every one of them meets its
deadline. Recorded: shed count/reasons, preemptions, high-priority p50
latency in ticks, mean occupancy.

All traces emit ``name,us_per_call,derived`` CSV lines (us_per_call =
microseconds per generated token) and are recorded together in
BENCH_serving.json at the repo root.

    python -m benchmarks.serving_throughput [--smoke] \
        [--trace mixed|long_prompt|capacity|overload|both]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.configs.base import AttentionConfig, LinformerConfig, ModelConfig
from repro.data.pipeline import EOS
from repro.models import model as M
from repro.serving import ServingEngine


def _cfg(max_seq: int, block_size: int = 8, block_slots: int = 4,
         backend: str = "auto") -> ModelConfig:
    return ModelConfig(
        name="serving-bench",
        num_layers=2,
        d_model=64,
        vocab_size=512,
        max_seq_len=max_seq,
        attention=AttentionConfig(
            kind="linformer_causal",
            backend=backend,
            num_heads=4,
            num_kv_heads=2,
            head_dim=16,
            linformer=LinformerConfig(block_size=block_size,
                                      block_slots=block_slots),
        ),
        dtype="float32",
        remat="none",
    )


# ---------------------------------------------------------------------------
# Trace 1: mixed-length arrivals, continuous vs static (PR 2's comparison)
# ---------------------------------------------------------------------------


def _trace(n_requests: int, long_budget: int, short_budget: int, seed: int):
    """Mixed-length prompts (block multiples: scheduling, not remainder
    decode, is what's under test), a long-tailed budget mix spread across
    the length buckets, shuffled arrival order, Poisson-ish arrival gaps."""
    rng = np.random.default_rng(seed)
    prompts, budgets = [], []
    for i in range(n_requests):
        plen = int(rng.choice([8, 16, 24]))
        prompts.append(list(rng.integers(4, 512, plen)))
        budgets.append(long_budget if i % 4 == 0 else short_budget)
    order = rng.permutation(n_requests)
    prompts = [prompts[i] for i in order]
    budgets = [budgets[i] for i in order]
    arrivals = np.cumsum(rng.poisson(0.4, n_requests)).tolist()
    return prompts, budgets, arrivals


def _engine(max_seq: int, decode_chunk: int, seed: int) -> ServingEngine:
    cfg = _cfg(max_seq)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    return ServingEngine(params, cfg, max_seq=max_seq,
                         cache_dtype=jnp.float32, decode_chunk=decode_chunk)


def _eos_free_setup(n_requests, long_budget, short_budget, max_seq,
                    decode_chunk):
    """Engine + trace whose greedy outputs never hit EOS: every request runs
    its full budget, so both schedulers do identical token work and the
    measurement isolates scheduling (same trick as decode_throughput)."""
    for seed in range(16):
        eng = _engine(max_seq, decode_chunk, seed)
        prompts, budgets, arrivals = _trace(n_requests, long_budget,
                                            short_budget, seed)
        outs = eng.serve_static(prompts, budgets, max_batch=4)
        if all(len(o) == b for o, b in zip(outs, budgets)):
            return eng, prompts, budgets, arrivals
    raise RuntimeError("no EOS-free serving trace found in 16 seeds")


def run_mixed(quick: bool = True, telemetry=None) -> dict:
    if quick:
        n_requests, pool, long_b, short_b, chunk = 8, 4, 24, 6, 6
        iters = 3
    else:
        n_requests, pool, long_b, short_b, chunk = 16, 8, 40, 8, 8
        iters = 3
    max_seq = 24 + long_b + chunk  # longest prompt + budget + chunk slack
    max_seq = ((max_seq + 7) // 8) * 8
    eng, prompts, budgets, arrivals = _eos_free_setup(
        n_requests, long_b, short_b, max_seq, chunk)
    total_budget = sum(budgets)

    # warmup: compile every (batch, length) shape both paths will touch
    static_warm = eng.serve_static(prompts, budgets, max_batch=pool)
    cont_warm = eng.serve(prompts, budgets, max_batch=pool)
    assert cont_warm == static_warm, \
        "continuous and static schedulers diverged"

    def timed(fn):
        times, out = [], None
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn()
            times.append(time.perf_counter() - t0)
        return float(np.median(times)), out

    t_static, outs_static = timed(
        lambda: eng.serve_static(prompts, budgets, max_batch=pool))
    t_cont, cont_res = timed(
        lambda: eng.serve(prompts, budgets, max_batch=pool,
                          return_scheduler=True))
    outs_cont, sched = cont_res
    assert outs_cont == outs_static

    n_tok = sum(len(o) for o in outs_cont)
    assert n_tok == total_budget  # EOS-free: every request ran its budget
    tok_s_static = n_tok / t_static
    tok_s_cont = n_tok / t_cont
    speedup = t_static / t_cont
    occ = sched.stats.mean_occupancy

    # replay with the Poisson-ish arrival trace: occupancy under staggered
    # arrivals instead of an instantaneous backlog (this is the run that
    # carries the trace when --trace-out is set — the timed runs above stay
    # un-instrumented so the recorded walls are never perturbed)
    _, sched_arr = eng.serve(prompts, budgets, max_batch=pool,
                             arrival_chunks=arrivals, return_scheduler=True,
                             telemetry=telemetry)

    emit(f"serving_throughput/static/n{n_requests}",
         t_static / n_tok * 1e6, f"tok_per_s={tok_s_static:.1f}")
    emit(f"serving_throughput/continuous/n{n_requests}",
         t_cont / n_tok * 1e6,
         f"tok_per_s={tok_s_cont:.1f},speedup={speedup:.2f}x,"
         f"occupancy={occ:.2f}")
    emit(f"serving_throughput/continuous_arrivals/n{n_requests}",
         0.0, f"occupancy={sched_arr.stats.mean_occupancy:.2f},"
              f"idle_ticks={sched_arr.stats.idle_ticks}")

    return {
        "mode": "smoke" if quick else "full",
        "n_requests": n_requests,
        "slot_pool": pool,
        "decode_chunk": chunk,
        "total_tokens": n_tok,
        "static": {"wall_s": round(t_static, 3),
                   "tok_per_s": round(tok_s_static, 1)},
        "continuous": {"wall_s": round(t_cont, 3),
                       "tok_per_s": round(tok_s_cont, 1),
                       "mean_occupancy": round(occ, 3),
                       "chunks": sched.stats.chunks,
                       "row_steps": sched.stats.row_steps},
        "continuous_with_arrivals": {
            "mean_occupancy": round(sched_arr.stats.mean_occupancy, 3),
            "idle_ticks": sched_arr.stats.idle_ticks},
        "speedup": round(speedup, 2),
        "outputs_match_static": True,
    }


# ---------------------------------------------------------------------------
# Trace 2: long-prompt arrivals, chunked vs monolithic admission
# ---------------------------------------------------------------------------


def _long_prompt_trace(quick: bool, seed: int = 0):
    """Short decoding traffic + long prompts of several DISTINCT lengths
    (each novel length costs monolithic admission a fresh B=1 prefill
    compile; two of the longs co-arrive, so chunked admission also batches
    them into shared chunk forwards). Lengths are block multiples; the
    longs are NOT all chunk multiples, so the padded-final-chunk path is
    exercised too."""
    rng = np.random.default_rng(seed)
    if quick:
        block, pchunk, dchunk, pool = 16, 64, 4, 4
        short_lens = [16, 32, 48, 64]
        long_lens = [256, 320, 336]
        short_b, long_b = 6, 8
    else:
        block, pchunk, dchunk, pool = 32, 256, 8, 8
        short_lens = [32, 64, 96, 128, 160, 192]
        long_lens = [2048, 2304, 2560, 3104]
        short_b, long_b = 8, 12
    prompts, budgets, arrivals = [], [], []
    for L in short_lens:                      # shorts arrive first, decode
        prompts.append(list(rng.integers(4, 512, L)))
        budgets.append(short_b)
        arrivals.append(0)
    for i, L in enumerate(long_lens):         # longs arrive into live pool
        prompts.append(list(rng.integers(4, 512, L)))
        budgets.append(long_b)
        arrivals.append(1 if i < 2 else 2)    # first two co-arrive: batching
    max_seq = max(len(p) + b for p, b in zip(prompts, budgets)) + dchunk
    max_seq = ((max_seq + pchunk - 1) // pchunk) * pchunk
    return (prompts, budgets, arrivals,
            dict(block=block, pchunk=pchunk, dchunk=dchunk, pool=pool,
                 max_seq=max_seq))


def run_long_prompt(quick: bool = True) -> dict:
    """Cold (first serve, includes jit for every novel shape) and warm
    (steady state) end-to-end wall, monolithic vs chunked admission.

    Engines use the reference backend: the comparison is pure admission
    policy, and the interpret-mode kernels' per-grid-step overhead at
    multi-thousand-token prompts would swamp the scheduling signal on CPU
    (on TPU the fused path is the default for both variants alike)."""
    prompts, budgets, arrivals, p = _long_prompt_trace(quick)
    cfg = _cfg(p["max_seq"], p["block"], 4, backend="reference")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    def fresh(prefill_chunk: int, fmt: str = "dense") -> ServingEngine:
        return ServingEngine(params, cfg, max_seq=p["max_seq"],
                             cache_dtype=jnp.float32,
                             decode_chunk=p["dchunk"],
                             prefill_chunk=prefill_chunk,
                             cache_format=fmt)

    def serve(eng):
        return eng.serve(prompts, budgets, max_batch=p["pool"],
                         arrival_chunks=arrivals, return_scheduler=True)

    results = {}
    outs = {}
    # chunked_paged rides the same trace on the paged int8 pool: chunk
    # writes scatter only the row's pages instead of the full dense pool,
    # which is where the dense chunked warm path loses to monolithic
    for name, pchunk, fmt in (("monolithic", 0, "dense"),
                              ("chunked", p["pchunk"], "dense"),
                              ("chunked_paged", p["pchunk"], "paged")):
        eng = fresh(pchunk, fmt)          # fresh jit caches: genuine cold
        t0 = time.perf_counter()
        out_cold, _ = serve(eng)
        t_cold = time.perf_counter() - t0
        serve(eng)                        # settle stragglers before timing
        walls = []
        for _ in range(3):
            t0 = time.perf_counter()
            out_warm, sched_w = serve(eng)
            walls.append(time.perf_counter() - t0)
        t_warm = float(np.median(walls))
        assert out_warm == out_cold
        outs[name] = out_cold
        n_tok = sum(len(o) for o in out_cold)
        results[name] = {
            "wall_cold_s": round(t_cold, 3),
            "wall_warm_s": round(t_warm, 3),
            "tok_per_s_cold": round(n_tok / t_cold, 1),
            "tok_per_s_warm": round(n_tok / t_warm, 1),
            "mean_occupancy": round(sched_w.stats.mean_occupancy, 3),
            "prefill_forwards": sched_w.stats.prefill_forwards,
            "prefill_tokens": sched_w.stats.prefill_tokens,
        }
        emit(f"serving_throughput/long_prompt/{name}",
             t_cold / n_tok * 1e6,
             f"tok_per_s_cold={n_tok / t_cold:.1f},"
             f"tok_per_s_warm={n_tok / t_warm:.1f}")

    assert outs["chunked"] == outs["monolithic"], \
        "chunked and monolithic admission diverged"
    # the paged leg is perf-only: int8 storage rounds, so its tokens are
    # tolerance-banded (tests/test_paged_cache.py), not byte-compared here
    speedup_cold = (results["monolithic"]["wall_cold_s"]
                    / results["chunked"]["wall_cold_s"])
    speedup_warm = (results["monolithic"]["wall_warm_s"]
                    / results["chunked"]["wall_warm_s"])
    speedup_warm_paged = (results["monolithic"]["wall_warm_s"]
                          / results["chunked_paged"]["wall_warm_s"])
    emit("serving_throughput/long_prompt/speedup", 0.0,
         f"cold={speedup_cold:.2f}x,warm={speedup_warm:.2f}x,"
         f"warm_paged={speedup_warm_paged:.2f}x")
    return {
        "mode": "smoke" if quick else "full",
        "n_requests": len(prompts),
        "long_prompt_lens": sorted({len(pr) for pr in prompts
                                    if len(pr) > p["pchunk"]}),
        "slot_pool": p["pool"],
        "prefill_chunk": p["pchunk"],
        "decode_chunk": p["dchunk"],
        "monolithic": results["monolithic"],
        "chunked": results["chunked"],
        "chunked_paged": results["chunked_paged"],
        "speedup_cold": round(speedup_cold, 2),
        "speedup_warm": round(speedup_warm, 2),
        "speedup_warm_paged": round(speedup_warm_paged, 2),
        "outputs_match": True,
    }


# ---------------------------------------------------------------------------
# Trace 3: capacity — paged int8 pool vs dense fp32 pool at equal arena bytes
# ---------------------------------------------------------------------------


def run_capacity(quick: bool = True) -> dict:
    """Size the paged pool to the dense pool's byte budget and serve the
    same oversubscribing backlog through both. Resident capacity (pool
    rows at equal bytes) is the claim; tokens/s and occupancy are recorded
    so capacity gains are never bought with a hidden throughput cliff
    (CPU walls compare interpret-mode kernels — the RATIO of the two pools'
    token work is the meaningful number, not the absolute walls)."""
    dense_rows = 2 if quick else 4
    budget, plen, dchunk = 8, 24, 4
    max_seq = ((plen + budget + 7) // 8) * 8 + 16      # fold + decode slack
    cfg = _cfg(max_seq)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    dense = ServingEngine(params, cfg, max_seq=max_seq,
                          cache_dtype=jnp.float32, decode_chunk=dchunk)
    paged = ServingEngine(params, cfg, max_seq=max_seq,
                          cache_dtype=jnp.float32, decode_chunk=dchunk,
                          cache_format="paged")
    arena_bytes = dense.cache_bytes(dense_rows)
    paged_rows = dense_rows
    while paged.cache_bytes(paged_rows + 1) <= arena_bytes:
        paged_rows += 1
    ratio = paged_rows / dense_rows
    assert ratio >= 3.0, (
        f"capacity gate: paged int8 pool holds only {paged_rows} rows vs "
        f"dense {dense_rows} at {arena_bytes} arena bytes ({ratio:.2f}x, "
        "need >= 3x)")

    # oversubscribe BOTH pools (2x the larger pool): every pool runs full
    # until the backlog drains, so mean occupancy ~= resident rows
    rng = np.random.default_rng(0)
    n_requests = 2 * paged_rows
    prompts = [list(rng.integers(4, 512, plen)) for _ in range(n_requests)]
    budgets = [budget] * n_requests

    def timed(eng, rows, **warm_kw):
        # warm run compiles every shape; the paged warm run also captures
        # snapshots so the quantization-error telemetry below is populated
        # without perturbing the timed wall
        _, sched_warm = eng.serve(prompts, budgets, max_batch=rows,
                                  return_scheduler=True, **warm_kw)
        t0 = time.perf_counter()
        outs, sched = eng.serve(prompts, budgets, max_batch=rows,
                                return_scheduler=True)
        wall = time.perf_counter() - t0
        n_tok = sum(len(o) for o in outs)
        return wall, n_tok, sched, sched_warm

    wall_d, tok_d, sched_d, _ = timed(dense, dense_rows)
    wall_p, tok_p, sched_p, sched_snap = timed(paged, paged_rows,
                                               snapshot_chunks=2)
    pool_p = sched_p.pool
    pool_p.alloc.check()                                   # no leaked pages

    emit(f"serving_throughput/capacity/dense_fp32/rows{dense_rows}",
         wall_d / tok_d * 1e6,
         f"tok_per_s={tok_d / wall_d:.1f},"
         f"occupancy={sched_d.stats.mean_occupancy:.2f}")
    emit(f"serving_throughput/capacity/paged_int8/rows{paged_rows}",
         wall_p / tok_p * 1e6,
         f"tok_per_s={tok_p / wall_p:.1f},"
         f"occupancy={sched_p.stats.mean_occupancy:.2f},"
         f"resident_ratio={ratio:.2f}x")

    return {
        "mode": "smoke" if quick else "full",
        "n_requests": n_requests,
        "arena_bytes": int(arena_bytes),
        "resident_ratio": round(ratio, 2),
        "dense_fp32": {
            "rows": dense_rows,
            "bytes": int(dense.cache_bytes(dense_rows)),
            "tok_per_s": round(tok_d / wall_d, 1),
            "mean_occupancy": round(sched_d.stats.mean_occupancy, 3),
        },
        "paged_int8": {
            "rows": paged_rows,
            "bytes": int(paged.cache_bytes(paged_rows)),
            "tok_per_s": round(tok_p / wall_p, 1),
            "mean_occupancy": round(sched_p.stats.mean_occupancy, 3),
            "pages_allocated": pool_p.pages_allocated,
            "pages_freed": pool_p.pages_freed,
            "page_preemptions": sched_p.stats.page_preemptions,
            "quant_error_bound": round(sched_snap.pool.quant_error_bound, 3),
        },
    }


# ---------------------------------------------------------------------------
# Trace 4: overload — bounded queue, priorities, deadlines, preemption
# ---------------------------------------------------------------------------


def _overload_trace(quick: bool, seed: int = 0):
    """2× (slot) oversubscribed backlog of low-priority requests plus a thin
    stream of high-priority, deadline-carrying arrivals. The point is
    graceful degradation: the bounded queue must shed part of the backlog
    EXPLICITLY (no silent unbounded queueing) while the high-priority
    requests preempt their way in and meet their deadlines.

    Two mid-priority requests carry a deadline their own budget makes
    impossible (deadline < needed decode chunks): the feasibility check
    must shed them at admission as `deadline_infeasible` — the scheduler
    converts what would be a certain deadline miss into an early, explicit
    rejection, and the telemetry gate (scripts/check_trace.py) asserts the
    exported trace records exactly that."""
    rng = np.random.default_rng(seed)
    if quick:
        pool, dchunk = 4, 4
        n_low, low_b = 12, 16     # 4 chunks each: still running at tick 2+
        hi_arrivals = [2, 4, 6, 8]
        hi_b, hi_margin = 4, 4
        max_queue = 8
    else:
        pool, dchunk = 8, 8
        n_low, low_b = 24, 16
        hi_arrivals = [2, 4, 6, 8, 10, 12]
        hi_b, hi_margin = 8, 4
        max_queue = 16
    prompts, budgets, arrivals, prios, deadlines = [], [], [], [], []
    for _ in range(n_low):                    # instantaneous backlog
        plen = int(rng.choice([8, 16, 24]))
        prompts.append(list(rng.integers(4, 512, plen)))
        budgets.append(low_b)
        arrivals.append(0)
        prios.append(2)
        deadlines.append(None)
    for a in hi_arrivals:                     # interactive stream with SLOs
        prompts.append(list(rng.integers(4, 512, 8)))
        budgets.append(hi_b)
        arrivals.append(a)
        prios.append(0)
        deadlines.append(a + hi_margin)
    n_inf = 2
    for _ in range(n_inf):                    # provably-infeasible deadlines
        prompts.append(list(rng.integers(4, 512, 8)))
        budgets.append(low_b)                 # needs low_b/dchunk chunks...
        arrivals.append(1)
        prios.append(1)
        deadlines.append(2)                   # ...but the deadline is 1 away
    # widen the queue by the infeasible entries: at submit they displace
    # backlog entries (they outrank priority 2), and without the slack the
    # thinned backlog would leave free slots — no preemption leg left
    max_queue += n_inf
    max_seq = max(len(p) + b for p, b in zip(prompts, budgets)) + dchunk
    max_seq = ((max_seq + 7) // 8) * 8
    n_hi = len(hi_arrivals)
    return (prompts, budgets, arrivals, prios, deadlines,
            dict(pool=pool, dchunk=dchunk, max_queue=max_queue,
                 max_seq=max_seq, n_low=n_low, n_hi=n_hi, n_inf=n_inf))


def run_overload(quick: bool = True, telemetry=None) -> dict:
    # EOS-free seed (same trick as the mixed trace): every request must run
    # its full budget, so the low-priority backlog genuinely occupies its
    # slots and the high-priority stream can only get in by preempting.
    for seed in range(16):
        prompts, budgets, arrivals, prios, deadlines, p = _overload_trace(
            quick, seed)
        cfg = _cfg(p["max_seq"])
        params = M.init_params(jax.random.PRNGKey(seed), cfg)
        eng = ServingEngine(params, cfg, max_seq=p["max_seq"],
                            cache_dtype=jnp.float32,
                            decode_chunk=p["dchunk"])
        outs = eng.serve_static(prompts, budgets, max_batch=p["pool"])
        if all(len(o) == b for o, b in zip(outs, budgets)):
            break
    else:
        raise RuntimeError("no EOS-free overload trace found in 16 seeds")

    outs, sched = eng.serve(prompts, budgets, max_batch=p["pool"],
                            arrival_chunks=arrivals, priorities=prios,
                            deadlines=deadlines, max_queue=p["max_queue"],
                            return_scheduler=True, telemetry=telemetry)

    from repro.serving import ShedResult
    shed = [o for o in outs if isinstance(o, ShedResult)]
    reasons: dict = {}
    for s in shed:
        reasons[s.reason] = reasons.get(s.reason, 0) + 1
    n_low = p["n_low"]
    hi_ids = list(range(n_low, n_low + p["n_hi"]))
    hi_shed = [i for i in hi_ids if isinstance(outs[i], ShedResult)]
    hi_lat = [sched.completed_at[i] - arrivals[i]
              for i in hi_ids if i not in hi_shed]
    hi_misses = sum(1 for i in hi_ids if i not in hi_shed
                    and sched.completed_at[i] > deadlines[i])
    p50 = float(np.median(hi_lat)) if hi_lat else float("nan")

    assert len(shed) > 0, "overload trace must shed (bounded queue)"
    assert not hi_shed, f"high-priority requests were shed: {hi_shed}"
    assert hi_misses == 0, f"{hi_misses} high-priority deadline misses"
    assert reasons.get("deadline_infeasible", 0) == p["n_inf"], \
        f"expected {p['n_inf']} deadline_infeasible sheds, got {reasons}"

    emit("serving_throughput/overload/sheds", 0.0,
         f"sheds={len(shed)},preemptions={sched.stats.preemptions}")
    emit("serving_throughput/overload/high_priority", 0.0,
         f"p50_latency_ticks={p50:.1f},deadline_misses={hi_misses},"
         f"occupancy={sched.stats.mean_occupancy:.2f}")

    return {
        "mode": "smoke" if quick else "full",
        "n_requests": len(prompts),
        "slot_pool": p["pool"],
        "oversubscription": round((n_low + p["n_hi"] + p["n_inf"])
                                  / p["pool"], 1),
        "max_queue": p["max_queue"],
        "sheds": len(shed),
        "shed_reasons": reasons,
        "preemptions": sched.stats.preemptions,
        "deadline_misses_total": sched.stats.deadline_misses,
        "mean_occupancy": round(sched.stats.mean_occupancy, 3),
        "high_priority": {
            "n": p["n_hi"],
            "completed": p["n_hi"] - len(hi_shed),
            "p50_latency_ticks": p50,
            "deadline_misses": hi_misses,
        },
    }


def run(quick: bool = True, trace: str = "both", telemetry=None):
    payload = {}
    if trace in ("mixed", "both"):
        payload["mixed"] = run_mixed(quick, telemetry=telemetry)
    if trace in ("long_prompt", "both"):
        payload["long_prompt"] = run_long_prompt(quick)
    if trace in ("capacity", "both"):
        payload["capacity"] = run_capacity(quick)
    if trace in ("overload", "both"):
        payload["overload"] = run_overload(quick, telemetry=telemetry)
    if trace == "both":
        # the committed perf record carries BOTH traces; selective runs
        # print CSV only so a partial run can't clobber the artifact
        write_bench_json("serving", payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast mode for the scripts/check.sh smoke gate")
    ap.add_argument("--trace", default="both",
                    choices=["mixed", "long_prompt", "capacity", "overload",
                             "both"])
    ap.add_argument("--trace-out", default=None,
                    help="export a Chrome-trace/Perfetto JSON of the "
                         "instrumented serve runs to this path")
    ap.add_argument("--metrics-out", default=None,
                    help="export the metrics dump (scheduler counters + "
                         "per-priority TTFT/TPOT histograms) as JSONL")
    args = ap.parse_args()
    telemetry = None
    if args.trace_out or args.metrics_out:
        from repro.telemetry import Telemetry
        telemetry = Telemetry()
    res = run(quick=args.smoke, trace=args.trace, telemetry=telemetry)
    if telemetry is not None and args.trace_out:
        telemetry.export_trace(args.trace_out,
                               metadata={"bench": "serving_throughput",
                                         "trace": args.trace})
        print(f"# trace -> {args.trace_out}")
    if telemetry is not None and args.metrics_out:
        telemetry.export_metrics_jsonl(args.metrics_out)
        print(f"# metrics -> {args.metrics_out}")
    if "mixed" in res:
        print(f"# mixed: continuous/static = {res['mixed']['speedup']:.2f}x")
    if "long_prompt" in res:
        lp = res["long_prompt"]
        print(f"# long_prompt: chunked/monolithic cold = "
              f"{lp['speedup_cold']:.2f}x, warm = {lp['speedup_warm']:.2f}x, "
              f"warm paged = {lp['speedup_warm_paged']:.2f}x")
    if "capacity" in res:
        cp = res["capacity"]
        print(f"# capacity: paged-int8 {cp['paged_int8']['rows']} rows vs "
              f"dense-fp32 {cp['dense_fp32']['rows']} rows at "
              f"{cp['arena_bytes']} arena bytes "
              f"({cp['resident_ratio']:.2f}x resident)")
    if "overload" in res:
        ov = res["overload"]
        print(f"# overload: {ov['sheds']} sheds at "
              f"{ov['oversubscription']}x oversubscription, hi-pri p50 = "
              f"{ov['high_priority']['p50_latency_ticks']:.1f} ticks, "
              f"misses = {ov['high_priority']['deadline_misses']}")
