"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts, plus a summary of the committed BENCH_*.json
perf-trajectory records (both serving traces, decode throughput, ...).

    PYTHONPATH=src python -m benchmarks.report [--mesh 16x16] [--tag TAG]
    PYTHONPATH=src python -m benchmarks.report --trace overload.json \
        --trace-metrics overload.jsonl

A missing or malformed input artifact (a BENCH_*.json that isn't valid
JSON, a record without its required fields, an unreadable trace) is a
hard error: a clear message on stderr and exit code 1, never a silently
truncated report.

``--trace`` summarizes a Chrome-trace/Perfetto JSON exported by the
telemetry subsystem (top spans by total duration, instant-event counts);
``--trace-metrics`` summarizes a metrics JSONL dump (per-priority
TTFT/TPOT/queue-wait percentiles reconstructed from the exported
histogram buckets via `repro.telemetry.percentile_from_cumulative`, plus
shed/preemption counters). See docs/observability.md.

``--lint LINT.json`` summarizes a `scripts/check_static.py --json`
report: per-rule finding counts, waiver-pragma count, and the jaxpr
audit's measured-vs-model collective bytes. See docs/static-analysis.md.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys
from collections import defaultdict

from benchmarks.common import REPO_ROOT
from benchmarks.roofline import load_records
from repro.telemetry import percentile_from_cumulative


class BenchJsonError(Exception):
    """An input artifact (BENCH_*.json, trace, metrics dump) is missing,
    unreadable, or structurally malformed."""


def load_json_artifact(path: str):
    """Read one JSON input or raise BenchJsonError with a usable message."""
    try:
        with open(path) as f:
            return json.load(f)
    except OSError as e:
        raise BenchJsonError(f"cannot read {path}: {e}") from e
    except json.JSONDecodeError as e:
        raise BenchJsonError(f"malformed JSON in {path}: {e}") from e


def gib(b):
    return b / 2 ** 30


def fmt(rec):
    rl = rec["roofline"]
    dom = {"compute_s": "compute", "memory_s": "memory",
           "collective_s": "collective"}[rec["dominant"]]
    mem = rec["memory"].get("total_bytes", 0)
    return (f"| {rec['arch']} | {rec['shape']} | {rec['attention_kind']} "
            f"| {rec['flops_per_device']:.2e} | {gib(mem):.1f} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | {dom} "
            f"| {rec.get('useful_flops_ratio', 0):.2f} "
            f"| {rec['compile_s']:.0f}s |")


HEADER = ("| arch | shape | attn | FLOPs/dev | mem GiB/dev | compute s "
          "| memory s | collective s | dominant | useful | compile |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def bench_json_summary(out=None, bench_dir=None):
    """Pretty-print the committed BENCH_*.json records. The serving record
    carries FOUR traces: `mixed` (continuous vs static scheduling),
    `long_prompt` (chunked vs monolithic admission prefill), `capacity`
    (paged-int8 vs dense-fp32 pool at equal arena bytes), and
    `overload` (2x-oversubscribed SLO trace: sheds, preemptions,
    high-priority deadline latency). Written to stderr by default so
    `report > section.md` (the EXPERIMENTS.md workflow) keeps only the
    tables on stdout. A malformed record raises BenchJsonError."""
    out = out if out is not None else sys.stderr
    print_ = lambda *a: print(*a, file=out)
    bench_dir = bench_dir if bench_dir is not None else REPO_ROOT
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    if not paths:
        return
    print_("\n### Committed perf trajectory (BENCH_*.json)\n")
    for path in paths:
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        rec = load_json_artifact(path)
        if not isinstance(rec, dict):
            raise BenchJsonError(f"{path}: expected a JSON object, got "
                                 f"{type(rec).__name__}")
        print_(f"* **{name}**")
        try:
            _summarize_bench_record(name, rec, print_)
        except (KeyError, TypeError) as e:
            raise BenchJsonError(
                f"{path}: record is missing/miswired field {e!r} — "
                f"regenerate it with the matching benchmark") from e


def _summarize_bench_record(name, rec, print_):
    if name == "serving":
        mixed = rec.get("mixed")
        if mixed:
            print_(f"  * mixed trace ({mixed['mode']}): continuous "
                   f"{mixed['continuous']['tok_per_s']} tok/s vs static "
                   f"{mixed['static']['tok_per_s']} tok/s "
                   f"({mixed['speedup']}x, occupancy "
                   f"{mixed['continuous']['mean_occupancy']})")
        lp = rec.get("long_prompt")
        if lp:
            print_(f"  * long-prompt trace ({lp['mode']}, lens "
                   f"{lp['long_prompt_lens']}, chunk "
                   f"{lp['prefill_chunk']}): chunked vs monolithic "
                   f"admission {lp['speedup_cold']}x cold / "
                   f"{lp['speedup_warm']}x warm"
                   + (f" / {lp['speedup_warm_paged']}x warm-paged"
                      if "speedup_warm_paged" in lp else "")
                   + f" ({lp['chunked']['tok_per_s_cold']} vs "
                   f"{lp['monolithic']['tok_per_s_cold']} tok/s cold)")
        cp = rec.get("capacity")
        if cp:
            pg, dn = cp["paged_int8"], cp["dense_fp32"]
            print_(f"  * capacity trace ({cp['mode']}): paged-int8 "
                   f"{pg['rows']} rows vs dense-fp32 {dn['rows']} rows at "
                   f"{cp['arena_bytes']} arena bytes "
                   f"({cp['resident_ratio']}x resident; "
                   f"{pg['tok_per_s']} vs {dn['tok_per_s']} tok/s, "
                   f"{pg['pages_allocated']} pages allocated, "
                   f"quant error bound {pg['quant_error_bound']})")
        ov = rec.get("overload")
        if ov:
            hi = ov["high_priority"]
            print_(f"  * overload trace ({ov['mode']}, "
                   f"{ov['oversubscription']}x oversubscribed, queue "
                   f"bound {ov['max_queue']}): {ov['sheds']} sheds "
                   f"{ov['shed_reasons']}, {ov['preemptions']} "
                   f"preemptions; high-priority {hi['completed']}/"
                   f"{hi['n']} completed, p50 latency "
                   f"{hi['p50_latency_ticks']} ticks, "
                   f"{hi['deadline_misses']} deadline misses "
                   f"(occupancy {ov['mean_occupancy']})")
    elif name == "train_step":
        sh = rec.get("shape", {})
        print_(f"  * train step ({rec['mode']}, S={sh.get('seq')}, "
               f"{sh.get('slots_total')} compressed slots): fused "
               f"backward {rec['step_ms_fused']}ms vs "
               f"reference-recompute {rec['step_ms_reference']}ms "
               f"({rec['speedup_fused_over_reference']}x)")
        ex = rec.get("exact_tuned")
        if ex:
            print_(f"  * exact-form autotuned leg ({ex['mode']}, "
                   f"S={ex['shape'].get('seq')}, k={ex['shape'].get('k')}): "
                   f"TUNING.json {ex['step_ms_tuned']}ms vs defaults "
                   f"{ex['step_ms_defaults']}ms "
                   f"({ex['tuned_over_defaults']}x, params "
                   f"{json.dumps(ex.get('table_params'), sort_keys=True)})")
        mrec = rec.get("mesh")
        if mrec:
            print_(f"  * sharded plan ({mrec['spec']}, "
                   f"{mrec['devices']} forced host devices, "
                   f"S={mrec['shape'].get('seq')}): "
                   f"{mrec['step_ms_sharded']}ms sharded vs "
                   f"{mrec['step_ms_single_shard']}ms single-shard "
                   f"({mrec['sharded_over_single']}x on this CPU "
                   f"container; meaningful scaling needs real chips)")
    else:
        scalars = {k: v for k, v in rec.items()
                   if not isinstance(v, (dict, list))}
        print_(f"  * {json.dumps(scalars, sort_keys=True)}")


def trace_summary(path, out=None, top=10):
    """Summarize a telemetry Chrome-trace JSON: top span families by total
    duration, instant-event counts, dropped-event metadata."""
    out = out if out is not None else sys.stdout
    print_ = lambda *a: print(*a, file=out)
    doc = load_json_artifact(path)
    events = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise BenchJsonError(f"{path}: no traceEvents array — not a "
                             "telemetry trace export")
    spans = defaultdict(lambda: [0, 0.0, 0.0])   # name -> [n, total_us, max]
    instants = defaultdict(int)
    for e in events:
        if not isinstance(e, dict) or "ph" not in e:
            raise BenchJsonError(f"{path}: event without 'ph' — not a "
                                 "Chrome-trace event stream")
        if e["ph"] == "X":
            s = spans[e.get("name", "?")]
            s[0] += 1
            s[1] += e.get("dur", 0.0)
            s[2] = max(s[2], e.get("dur", 0.0))
        elif e["ph"] == "i":
            instants[e.get("name", "?")] += 1
    meta = doc.get("metadata", {}) if isinstance(doc, dict) else {}
    print_(f"\n### Trace summary: {path}\n")
    if meta:
        print_(f"* metadata: {json.dumps(meta, sort_keys=True)}")
    print_(f"* {sum(s[0] for s in spans.values())} spans "
           f"({len(spans)} families), "
           f"{sum(instants.values())} instants ({len(instants)} kinds)")
    print_(f"\n| span | count | total ms | mean ms | max ms |\n"
           f"|---|---|---|---|---|")
    ranked = sorted(spans.items(), key=lambda kv: -kv[1][1])[:top]
    for name, (n, tot, mx) in ranked:
        print_(f"| {name} | {n} | {tot / 1e3:.3f} | {tot / 1e3 / n:.3f} "
               f"| {mx / 1e3:.3f} |")
    if instants:
        print_("\n* instants: " + ", ".join(
            f"{k}×{v}" for k, v in sorted(instants.items())))


def _percentiles_from_record(rec):
    """(p50, p90, p99) reconstructed from an exported histogram record's
    cumulative buckets — the same math the live registry uses."""
    cum = [(math.inf if le == "+Inf" else float(le), c)
           for le, c in rec["buckets"]]
    lo = rec.get("min", math.inf)
    hi = rec.get("max", -math.inf)
    return tuple(percentile_from_cumulative(cum, rec["count"], p, lo, hi)
                 for p in (50, 90, 99))


def metrics_summary(path, out=None):
    """Summarize a telemetry metrics JSONL dump: per-priority serving SLO
    percentiles (reconstructed from the exported buckets) and the
    shed/preemption/deadline counters."""
    out = out if out is not None else sys.stdout
    print_ = lambda *a: print(*a, file=out)
    slo = ("serving_queue_wait_ticks", "serving_ttft_ticks",
           "serving_ttft_ms", "serving_tpot_ms",
           "serving_deadline_slack_ticks", "train_step_ms")
    hists, counters = [], []
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        raise BenchJsonError(f"cannot read {path}: {e}") from e
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            raise BenchJsonError(
                f"malformed JSONL in {path} line {i + 1}: {e}") from e
        if rec.get("type") == "histogram" and rec.get("metric") in slo:
            hists.append(rec)
        elif rec.get("type") == "counter" and (
                "shed" in rec.get("metric", "")
                or "preempt" in rec.get("metric", "")
                or "deadline" in rec.get("metric", "")
                or "quarantin" in rec.get("metric", "")):
            counters.append(rec)
    print_(f"\n### Metrics summary: {path}\n")
    if hists:
        print_("| metric | labels | run | count | p50 | p90 | p99 |\n"
               "|---|---|---|---|---|---|---|")
        for rec in hists:
            if not rec.get("count"):
                continue
            p50, p90, p99 = _percentiles_from_record(rec)
            labels = ",".join(f"{k}={v}"
                              for k, v in sorted(rec["labels"].items()))
            print_(f"| {rec['metric']} | {labels or '-'} "
                   f"| {rec.get('run', '-')} | {rec['count']} "
                   f"| {p50:.2f} | {p90:.2f} | {p99:.2f} |")
    for rec in counters:
        if not rec.get("value"):
            continue
        labels = ",".join(f"{k}={v}"
                          for k, v in sorted(rec["labels"].items()))
        print_(f"* {rec['metric']}{{{labels}}} = {rec['value']:g} "
               f"({rec.get('run', '-')})")


def lint_summary(path, out=None):
    """Summarize a check_static JSON report (per-rule counts, pragma
    usage, jaxpr comm-bytes stats). Malformed input raises
    BenchJsonError — a lint report the tooling cannot read is itself a
    red gate, never a silently empty section."""
    out = out if out is not None else sys.stdout
    print_ = lambda *a: print(*a, file=out)
    doc = load_json_artifact(path)
    if not isinstance(doc, dict) or doc.get("check") != "check_static":
        raise BenchJsonError(
            f"{path}: not a check_static report (expected a JSON object "
            f"with check='check_static'; run scripts/check_static.py "
            f"--json {path})")
    for key in ("ok", "findings", "stats"):
        if key not in doc:
            raise BenchJsonError(f"{path}: check_static report is missing "
                                 f"the {key!r} field — regenerate it")
    stats = doc["stats"]
    print_(f"\n### Static analysis: {path}\n")
    print_(f"* verdict: {'CLEAN' if doc['ok'] else 'FINDINGS'} "
           f"({len(doc['findings'])} new, {doc.get('baselined', 0)} "
           f"baselined) — {stats.get('files', '?')} files, "
           f"{stats.get('pragmas', '?')} waiver pragmas")
    by_rule = defaultdict(int)
    for f in doc["findings"]:
        by_rule[f.get("rule", "?")] += 1
    rules = doc.get("rules", {})
    for rule in sorted(by_rule):
        print_(f"  * {rule}: {by_rule[rule]} — "
               f"{rules.get(rule, 'unknown rule')}")
    jx = stats.get("jaxpr")
    if jx:
        sc, se = jx.get("sp_causal", {}), jx.get("sp_exact", {})
        if sc:
            print_(f"* sp-causal comm: {sc.get('all_gathers')} all-gathers, "
                   f"{sc.get('gathered_bytes')}B traced vs "
                   f"{sc.get('model_bytes')}B blockwise_sp_comm_bytes")
        if se:
            print_(f"* sp-exact comm: {se.get('psums')} psums, "
                   f"{se.get('psum_bytes')}B traced vs "
                   f"{se.get('model_bytes')}B seq_parallel_comm_bytes")
        dec = jx.get("decode_scan", {})
        if dec:
            print_(f"* decode chunk: {dec.get('scan_eqns')} scans, "
                   f"{dec.get('body_eqns')} body eqns, "
                   f"{dec.get('host_effects')} host effects, "
                   f"{dec.get('widenings')} widenings")


def tuning_summary(path=None, out=None):
    """Summarize a TUNING.json autotuner table: per-entry winning params
    with their measured defaults-vs-tuned deltas. Schema violations raise
    BenchJsonError — a table the runtime would silently ignore is a red
    gate here, never an empty section."""
    from repro.tune.table import default_path, validate_doc
    out = out if out is not None else sys.stdout
    print_ = lambda *a: print(*a, file=out)
    path = path if path is not None else default_path()
    doc = load_json_artifact(path)
    errs = validate_doc(doc)
    if errs:
        raise BenchJsonError(f"{path}: invalid tuning table — "
                             + "; ".join(errs))
    print_(f"\n### Tuning table: {path}\n")
    print_(f"* generated by {doc.get('generated_by', '?')} "
           f"(mode {doc.get('mode', '?')}), {len(doc['entries'])} entries")
    for e in doc["entries"]:
        bucket = json.dumps(e["bucket"], sort_keys=True) \
            if e["bucket"] else "platform-wide"
        print_(f"  * [{e['platform']}] {e['form']} {bucket}: "
               f"{json.dumps(e['params'], sort_keys=True)} — "
               f"{e['trial_us']}us tuned vs {e['default_us']}us default "
               f"({e['speedup']}x, {e['trials']} trials)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--bench-dir", default=None,
                    help="directory holding the BENCH_*.json records "
                         "(default: repo root)")
    ap.add_argument("--trace", default=None,
                    help="summarize this telemetry Chrome-trace JSON "
                         "(top spans, instant counts)")
    ap.add_argument("--trace-metrics", default=None,
                    help="summarize this telemetry metrics JSONL "
                         "(per-priority TTFT/TPOT percentiles, SLO "
                         "counters)")
    ap.add_argument("--lint", default=None,
                    help="summarize this scripts/check_static.py --json "
                         "report (per-rule counts, jaxpr comm stats)")
    ap.add_argument("--tuning", nargs="?", const="", default=None,
                    help="summarize an autotuner TUNING.json (winning "
                         "params + defaults-vs-tuned deltas); with no "
                         "path, the committed/REPRO_TUNING_PATH table")
    args = ap.parse_args(argv)
    try:
        if args.trace:
            trace_summary(args.trace)
        if args.trace_metrics:
            metrics_summary(args.trace_metrics)
        if args.lint:
            lint_summary(args.lint)
        if args.tuning is not None:
            tuning_summary(args.tuning or None)
        if args.trace or args.trace_metrics or args.lint \
                or args.tuning is not None:
            return
        bench_json_summary(bench_dir=args.bench_dir)
    except BenchJsonError as e:
        print(f"[report] ERROR: {e}", file=sys.stderr)
        sys.exit(1)

    for mesh in ([args.mesh] if args.mesh else ["16x16", "2x16x16"]):
        recs = load_records(mesh=mesh, tag=args.tag)
        if not recs:
            continue
        print(f"\n### Mesh {mesh} ({'512' if mesh == '2x16x16' else '256'} "
              f"chips){' — ' + args.tag if args.tag else ''}\n")
        print(HEADER)
        order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                 "long_500k": 3}
        for r in sorted(recs, key=lambda r: (r["arch"],
                                             order.get(r["shape"], 9))):
            print(fmt(r))

    # collective breakdown for the most collective-bound cells
    recs = load_records(mesh="16x16", tag=args.tag)
    coll_bound = [r for r in recs if r["dominant"] == "collective_s"]
    if coll_bound:
        print("\n### Most collective-bound cells (16x16)\n")
        for r in sorted(coll_bound,
                        key=lambda r: -r["roofline"]["collective_s"])[:6]:
            kinds = {k: v for k, v in r["collectives"].items()
                     if v.get("count")}
            print(f"* **{r['arch']} × {r['shape']}** "
                  f"({r['roofline']['collective_s']:.3f}s): " +
                  ", ".join(f"{k}: {v['bytes']/2**20:.0f} MiB × "
                            f"{v['count']:.0f}" for k, v in kinds.items()))


if __name__ == "__main__":
    main()
