"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts, plus a summary of the committed BENCH_*.json
perf-trajectory records (both serving traces, decode throughput, ...).

    PYTHONPATH=src python -m benchmarks.report [--mesh 16x16] [--tag TAG]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from collections import defaultdict

from benchmarks.common import REPO_ROOT
from benchmarks.roofline import load_records


def gib(b):
    return b / 2 ** 30


def fmt(rec):
    rl = rec["roofline"]
    dom = {"compute_s": "compute", "memory_s": "memory",
           "collective_s": "collective"}[rec["dominant"]]
    mem = rec["memory"].get("total_bytes", 0)
    return (f"| {rec['arch']} | {rec['shape']} | {rec['attention_kind']} "
            f"| {rec['flops_per_device']:.2e} | {gib(mem):.1f} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | {dom} "
            f"| {rec.get('useful_flops_ratio', 0):.2f} "
            f"| {rec['compile_s']:.0f}s |")


HEADER = ("| arch | shape | attn | FLOPs/dev | mem GiB/dev | compute s "
          "| memory s | collective s | dominant | useful | compile |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def bench_json_summary(out=None):
    """Pretty-print the committed BENCH_*.json records. The serving record
    carries THREE traces: `mixed` (continuous vs static scheduling),
    `long_prompt` (chunked vs monolithic admission prefill), and
    `overload` (2x-oversubscribed SLO trace: sheds, preemptions,
    high-priority deadline latency). Written to stderr by default so
    `report > section.md` (the EXPERIMENTS.md workflow) keeps only the
    tables on stdout."""
    out = out if out is not None else sys.stderr
    print_ = lambda *a: print(*a, file=out)
    paths = sorted(glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json")))
    if not paths:
        return
    print_("\n### Committed perf trajectory (BENCH_*.json)\n")
    for path in paths:
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        with open(path) as f:
            rec = json.load(f)
        print_(f"* **{name}**")
        if name == "serving":
            mixed = rec.get("mixed")
            if mixed:
                print_(f"  * mixed trace ({mixed['mode']}): continuous "
                      f"{mixed['continuous']['tok_per_s']} tok/s vs static "
                      f"{mixed['static']['tok_per_s']} tok/s "
                      f"({mixed['speedup']}x, occupancy "
                      f"{mixed['continuous']['mean_occupancy']})")
            lp = rec.get("long_prompt")
            if lp:
                print_(f"  * long-prompt trace ({lp['mode']}, lens "
                      f"{lp['long_prompt_lens']}, chunk "
                      f"{lp['prefill_chunk']}): chunked vs monolithic "
                      f"admission {lp['speedup_cold']}x cold / "
                      f"{lp['speedup_warm']}x warm "
                      f"({lp['chunked']['tok_per_s_cold']} vs "
                      f"{lp['monolithic']['tok_per_s_cold']} tok/s cold)")
            ov = rec.get("overload")
            if ov:
                hi = ov["high_priority"]
                print_(f"  * overload trace ({ov['mode']}, "
                      f"{ov['oversubscription']}x oversubscribed, queue "
                      f"bound {ov['max_queue']}): {ov['sheds']} sheds "
                      f"{ov['shed_reasons']}, {ov['preemptions']} "
                      f"preemptions; high-priority {hi['completed']}/"
                      f"{hi['n']} completed, p50 latency "
                      f"{hi['p50_latency_ticks']} ticks, "
                      f"{hi['deadline_misses']} deadline misses "
                      f"(occupancy {ov['mean_occupancy']})")
        elif name == "train_step":
            sh = rec.get("shape", {})
            print_(f"  * train step ({rec['mode']}, S={sh.get('seq')}, "
                   f"{sh.get('slots_total')} compressed slots): fused "
                   f"backward {rec['step_ms_fused']}ms vs "
                   f"reference-recompute {rec['step_ms_reference']}ms "
                   f"({rec['speedup_fused_over_reference']}x)")
            mrec = rec.get("mesh")
            if mrec:
                print_(f"  * sharded plan ({mrec['spec']}, "
                       f"{mrec['devices']} forced host devices, "
                       f"S={mrec['shape'].get('seq')}): "
                       f"{mrec['step_ms_sharded']}ms sharded vs "
                       f"{mrec['step_ms_single_shard']}ms single-shard "
                       f"({mrec['sharded_over_single']}x on this CPU "
                       f"container; meaningful scaling needs real chips)")
        else:
            scalars = {k: v for k, v in rec.items()
                       if not isinstance(v, (dict, list))}
            print_(f"  * {json.dumps(scalars, sort_keys=True)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    bench_json_summary()

    for mesh in ([args.mesh] if args.mesh else ["16x16", "2x16x16"]):
        recs = load_records(mesh=mesh, tag=args.tag)
        if not recs:
            continue
        print(f"\n### Mesh {mesh} ({'512' if mesh == '2x16x16' else '256'} "
              f"chips){' — ' + args.tag if args.tag else ''}\n")
        print(HEADER)
        order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                 "long_500k": 3}
        for r in sorted(recs, key=lambda r: (r["arch"],
                                             order.get(r["shape"], 9))):
            print(fmt(r))

    # collective breakdown for the most collective-bound cells
    recs = load_records(mesh="16x16", tag=args.tag)
    coll_bound = [r for r in recs if r["dominant"] == "collective_s"]
    if coll_bound:
        print("\n### Most collective-bound cells (16x16)\n")
        for r in sorted(coll_bound,
                        key=lambda r: -r["roofline"]["collective_s"])[:6]:
            kinds = {k: v for k, v in r["collectives"].items()
                     if v.get("count")}
            print(f"* **{r['arch']} × {r['shape']}** "
                  f"({r['roofline']['collective_s']:.3f}s): " +
                  ", ".join(f"{k}: {v['bytes']/2**20:.0f} MiB × "
                            f"{v['count']:.0f}" for k, v in kinds.items()))


if __name__ == "__main__":
    main()
