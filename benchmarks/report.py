"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.report [--mesh 16x16] [--tag TAG]
"""
from __future__ import annotations

import argparse
from collections import defaultdict

from benchmarks.roofline import load_records


def gib(b):
    return b / 2 ** 30


def fmt(rec):
    rl = rec["roofline"]
    dom = {"compute_s": "compute", "memory_s": "memory",
           "collective_s": "collective"}[rec["dominant"]]
    mem = rec["memory"].get("total_bytes", 0)
    return (f"| {rec['arch']} | {rec['shape']} | {rec['attention_kind']} "
            f"| {rec['flops_per_device']:.2e} | {gib(mem):.1f} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | {dom} "
            f"| {rec.get('useful_flops_ratio', 0):.2f} "
            f"| {rec['compile_s']:.0f}s |")


HEADER = ("| arch | shape | attn | FLOPs/dev | mem GiB/dev | compute s "
          "| memory s | collective s | dominant | useful | compile |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    for mesh in ([args.mesh] if args.mesh else ["16x16", "2x16x16"]):
        recs = load_records(mesh=mesh, tag=args.tag)
        if not recs:
            continue
        print(f"\n### Mesh {mesh} ({'512' if mesh == '2x16x16' else '256'} "
              f"chips){' — ' + args.tag if args.tag else ''}\n")
        print(HEADER)
        order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                 "long_500k": 3}
        for r in sorted(recs, key=lambda r: (r["arch"],
                                             order.get(r["shape"], 9))):
            print(fmt(r))

    # collective breakdown for the most collective-bound cells
    recs = load_records(mesh="16x16", tag=args.tag)
    coll_bound = [r for r in recs if r["dominant"] == "collective_s"]
    if coll_bound:
        print("\n### Most collective-bound cells (16x16)\n")
        for r in sorted(coll_bound,
                        key=lambda r: -r["roofline"]["collective_s"])[:6]:
            kinds = {k: v for k, v in r["collectives"].items()
                     if v.get("count")}
            print(f"* **{r['arch']} × {r['shape']}** "
                  f"({r['roofline']['collective_s']:.3f}s): " +
                  ", ".join(f"{k}: {v['bytes']/2**20:.0f} MiB × "
                            f"{v['count']:.0f}" for k, v in kinds.items()))


if __name__ == "__main__":
    main()
