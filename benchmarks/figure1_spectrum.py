"""Paper Figure 1: spectrum analysis of the context-mapping matrix P.

Trains a small MLM encoder briefly, then SVDs P = softmax(QKᵀ/√d) per
layer/head and reports the normalized cumulative singular value at rank n/4
(the paper's 128-of-512 heatmap, scaled) — trained attention is low-rank, and
higher layers are MORE skewed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.core import low_rank
from repro.data import DataState, SyntheticCorpus, make_mlm_batch
from repro.models import layers as L
from repro.models import model as M
from repro.optim import adamw_init
from repro.train.trainer import make_train_step


def _train_small_encoder(steps: int, seq: int):
    cfg = dataclasses.replace(get_smoke_config("linformer-paper"),
                              dtype="float32", num_layers=4,
                              max_seq_len=seq)
    cfg = cfg.with_attention_kind("standard")   # analyze FULL attention's P
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimizerConfig(lr=2e-3, warmup_steps=5, total_steps=steps)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    for s in range(steps):
        b = jax.tree.map(jnp.asarray, make_mlm_batch(
            corpus, DataState(0, s), batch=8, seq=seq))
        params, opt, metrics = step(params, opt, b)
    return cfg, params, corpus


def _per_layer_qk(cfg, params, tokens):
    """Recompute per-layer (q, k) head tensors for spectrum analysis."""
    from repro.models.attention import _qkv
    x = L.embed_tokens(params["embed"]["tok"], tokens)
    if "pos" in params["embed"]:
        x = x + params["embed"]["pos"][:x.shape[1]][None]
    out = []
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        normed = L.rms_norm(lp["ln1"], x)
        q, k, v = _qkv(lp["attn"], normed, cfg.attention, None)
        out.append((q, k))
        from repro.models.transformer import apply_block
        x, _ = apply_block(lp, x, cfg, shared_lin=None, ctx=None)
    return out


def run(quick: bool = True):
    seq = 128
    steps = 30 if quick else 200
    cfg, params, corpus = _train_small_encoder(steps, seq)
    b = make_mlm_batch(corpus, DataState(0, 9999), batch=2, seq=seq)
    qks = _per_layer_qk(cfg, params, jnp.asarray(b["tokens"]))
    rank = seq // 4
    energies = []
    for li, (q, k) in enumerate(qks):
        es = []
        for h in range(cfg.attention.num_heads):
            P = low_rank.context_mapping(q[0, :, h], k[0, :, h])
            es.append(float(low_rank.energy_at_rank(P, rank)))
        e = float(np.mean(es))
        energies.append(e)
        emit(f"figure1/layer{li}/energy_at_rank{rank}", 0.0, f"energy={e:.4f}")
    emit("figure1/all_layers_low_rank", 0.0,
         f"min_energy={min(energies):.4f} (paper: long-tail spectrum)")
    # paper observation: higher layers at least as skewed as lower ones
    emit("figure1/higher_vs_lower", 0.0,
         f"first={energies[0]:.4f} last={energies[-1]:.4f}")
    return {"energies": energies}


if __name__ == "__main__":
    run(quick=False)
