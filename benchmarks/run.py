"""Benchmark harness — one module per paper table/figure.

``python -m benchmarks.run [--full]`` prints ``name,us_per_call,derived`` CSV
lines per benchmark (quick mode by default; --full uses paper-scale settings
where the container allows).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (autotune, decode_throughput, figure1_spectrum,
                            figure3_pretrain, roofline, serving_throughput,
                            table1_complexity, table2_downstream,
                            table3_efficiency, train_step)
    benches = {
        "table1_complexity": table1_complexity.run,
        "figure1_spectrum": figure1_spectrum.run,
        "figure3_pretrain": figure3_pretrain.run,
        "table2_downstream": table2_downstream.run,
        "table3_efficiency": table3_efficiency.run,
        "roofline": roofline.run,
        "decode_throughput": decode_throughput.run,
        # fused Pallas backward vs reference-recompute training step;
        # records BENCH_train_step.json
        "train_step": train_step.run,
        # both serving traces (mixed continuous-vs-static + long-prompt
        # chunked-vs-monolithic admission); records BENCH_serving.json
        "serving_throughput": serving_throughput.run,
    }
    # single-trace serving aliases, --only selectable (CSV only — a partial
    # run never clobbers the committed two-trace BENCH_serving.json)
    aliases = {
        "serving_mixed":
            lambda quick: serving_throughput.run(quick, trace="mixed"),
        "serving_long_prompt":
            lambda quick: serving_throughput.run(quick, trace="long_prompt"),
        # offline autotuner (repro/tune): full mode regenerates the
        # committed TUNING.json; quick mode sweeps toy shapes, so its
        # table goes to a scratch path rather than clobbering it
        "autotune": lambda quick: autotune.run(
            quick, out=("/tmp/tuning_smoke.json" if quick else None)),
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in {**benches, **aliases}.items()
                   if k in keep}

    failures = 0
    for name, fn in benches.items():
        print(f"# === {name} ===")
        t0 = time.time()
        try:
            fn(quick=quick)
            print(f"# {name} done in {time.time() - t0:.1f}s")
        except Exception:
            failures += 1
            print(f"# {name} FAILED")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
