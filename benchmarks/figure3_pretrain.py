"""Paper Figure 3: pretraining validation perplexity.

(a/b) standard Transformer vs Linformer across projected dimension k;
(c) the three parameter-sharing strategies; (d) longer sequence with fixed k.
Small-scale MLM on the synthetic corpus; the paper's claim reproduced is
RELATIVE: Linformer ppl tracks the standard Transformer's as k grows, and
sharing strategies are nearly free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.configs.base import LinformerConfig, OptimizerConfig
from repro.data import DataState, SyntheticCorpus, make_mlm_batch
from repro.models import model as M
from repro.optim import adamw_init
from repro.train.trainer import make_train_step


def _pretrain(cfg, steps, seq, batch=8, seed=0, val_batches=4,
              return_params=False):
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    ocfg = OptimizerConfig(lr=2e-3, warmup_steps=10, total_steps=steps)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg))
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    for s in range(steps):
        b = jax.tree.map(jnp.asarray, make_mlm_batch(
            corpus, DataState(0, s), batch=batch, seq=seq))
        params, opt, metrics = step(params, opt, b)
    # validation perplexity on held-out shard
    losses = []
    for v in range(val_batches):
        b = jax.tree.map(jnp.asarray, make_mlm_batch(
            corpus, DataState(0, 10_000 + v), batch=batch, seq=seq, shard=7))
        _, m = M.loss_fn(params, cfg, b)
        losses.append(float(m["loss"]))
    ppl = float(np.exp(np.mean(losses)))
    if return_params:
        return ppl, params
    return ppl


def _cfg(seq, kind="linformer", k=16, sharing="layerwise"):
    base = dataclasses.replace(get_smoke_config("linformer-paper"),
                               dtype="float32", max_seq_len=seq)
    att = dataclasses.replace(
        base.attention, kind=kind,
        linformer=LinformerConfig(k=k, sharing=sharing))
    return dataclasses.replace(base, attention=att)


def run(quick: bool = True):
    steps = 60 if quick else 400
    seq = 128
    out = {}

    ppl_std = _pretrain(_cfg(seq, kind="standard"), steps, seq)
    emit("figure3/standard", 0.0, f"val_ppl={ppl_std:.3f}")
    out["standard"] = ppl_std

    # (a) effect of projected dimension k
    for k in (4, 16, 64):
        ppl = _pretrain(_cfg(seq, k=k), steps, seq)
        emit(f"figure3/linformer_k{k}", 0.0,
             f"val_ppl={ppl:.3f} vs_std={ppl / ppl_std:.3f}")
        out[f"k{k}"] = ppl

    # (c) sharing strategies at fixed k
    for sharing in ("headwise", "kv", "layerwise"):
        ppl = _pretrain(_cfg(seq, k=16, sharing=sharing), steps, seq)
        emit(f"figure3/sharing_{sharing}", 0.0, f"val_ppl={ppl:.3f}")
        out[f"sharing_{sharing}"] = ppl

    # (d) longer sequence, fixed k
    ppl_long = _pretrain(_cfg(seq * 2, k=16), steps, seq * 2)
    emit("figure3/double_seq_fixed_k", 0.0,
         f"val_ppl={ppl_long:.3f} (paper: ppl ~flat as n grows, k fixed)")
    out["double_seq"] = ppl_long
    return out


if __name__ == "__main__":
    run(quick=False)
