"""Offline kernel/scheduler autotuner CLI — regenerates TUNING.json.

Sweeps the tunable knobs per (platform, form, shape bucket) by timing
the real fused entry points and live serve loops (`repro.tune.autotune`)
and writes the winners to the committed tuning table:

    python -m benchmarks.autotune              # full sweep -> TUNING.json
    python -m benchmarks.autotune --smoke \\
        --out /tmp/tuning_smoke.json           # gate-speed, small shapes

Emits one ``autotune/<form>,us,params`` CSV line per winning entry (the
``emit`` convention shared by every benchmark). ``--smoke`` shrinks the
sweep to the scripts/check.sh gate budget — its table is schema-valid
and loadable (the gate points REPRO_TUNING_PATH at it) but tuned at toy
shapes, so it is written to --out, never committed. Telemetry spans per
trial and ``autotune_trials_total`` export via --trace-out/--metrics-out
like the serving bench.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import emit
from repro.tune import autotune as autotune_lib
from repro.tune.table import default_path


def run(quick: bool = True, out: str = None, telemetry=None):
    mode = "smoke" if quick else "full"
    table = autotune_lib.build_table(mode, telemetry=telemetry)
    # save() re-validates and raises on schema violations
    path = table.save(out if out is not None else default_path())
    for e in table.entries:
        emit(f"autotune/{e['form']}", e["trial_us"],
             f"{json.dumps(e['params'], sort_keys=True)} "
             f"speedup={e['speedup']}")
    print(f"# {len(table.entries)} entries -> {path}")
    return table


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="gate-speed sweep at toy shapes (table goes to "
                         "--out, not the committed TUNING.json)")
    ap.add_argument("--out", default=None,
                    help="write the table here instead of the default "
                         "TUNING.json location")
    ap.add_argument("--trace-out", default=None,
                    help="export a Chrome-trace/Perfetto JSON of the "
                         "per-trial autotune spans to this path")
    ap.add_argument("--metrics-out", default=None,
                    help="export the metrics dump (autotune_trials_total) "
                         "as JSONL")
    args = ap.parse_args()
    telemetry = None
    if args.trace_out or args.metrics_out:
        from repro.telemetry import Telemetry
        telemetry = Telemetry()
    run(quick=args.smoke, out=args.out, telemetry=telemetry)
    if telemetry is not None and args.trace_out:
        telemetry.export_trace(args.trace_out,
                               metadata={"bench": "autotune"})
        print(f"# trace -> {args.trace_out}")
    if telemetry is not None and args.metrics_out:
        telemetry.export_metrics_jsonl(args.metrics_out)
        print(f"# metrics -> {args.metrics_out}")
