"""Paper Table 1: per-layer complexity vs sequence length.

Measures one attention layer's forward wall-time across n with d fixed, for
standard softmax attention (O(n²)) vs exact Linformer (O(n·k)), and fits the
scaling exponent — the paper's central complexity claim, verified empirically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, fit_scaling_exponent, time_fn
from repro.core import exact_linformer_attention
from repro.models.attention import standard_attention


def run(quick: bool = True):
    Dh, H, B, k = 32, 4, 1, 64
    ns = [256, 512, 1024, 2048] if quick else [256, 512, 1024, 2048, 4096,
                                               8192]
    t_std, t_lin = [], []
    for n in ns:
        ks = jax.random.split(jax.random.PRNGKey(n), 4)
        q = jax.random.normal(ks[0], (B, n, H, Dh))
        kk = jax.random.normal(ks[1], (B, n, H, Dh))
        v = jax.random.normal(ks[2], (B, n, H, Dh))
        E = jax.random.normal(ks[3], (n, k)) * (1.0 / jnp.sqrt(k))

        std = jax.jit(functools.partial(standard_attention, causal=False))
        lin = jax.jit(exact_linformer_attention)
        us_std = time_fn(std, q, kk, v)
        us_lin = time_fn(lin, q, kk, v, E, E)
        t_std.append(us_std)
        t_lin.append(us_lin)
        emit(f"table1/standard/n{n}", us_std)
        emit(f"table1/linformer_k{k}/n{n}", us_lin,
             f"speedup={us_std / us_lin:.2f}x")
    e_std = fit_scaling_exponent(ns, t_std)
    e_lin = fit_scaling_exponent(ns, t_lin)
    emit("table1/scaling_exponent/standard", 0.0, f"exponent={e_std:.2f}")
    emit("table1/scaling_exponent/linformer", 0.0, f"exponent={e_lin:.2f}")
    return {"exp_std": e_std, "exp_lin": e_lin}


if __name__ == "__main__":
    run(quick=False)
