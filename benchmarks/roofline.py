"""Roofline report: reads the dry-run artifacts and prints the per-cell
three-term roofline table (compute / memory / collective seconds, dominant
term, MODEL_FLOPS ratio). See EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_records(mesh: str = None, tag: str = ""):
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if "skipped" in r:
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def run(quick: bool = True):
    recs = load_records(mesh="16x16")
    if not recs:
        emit("roofline/no_artifacts", 0.0, "run repro.launch.dryrun first")
        return {}
    hdr = ("arch,shape,compute_s,memory_s,collective_s,dominant,"
           "useful_flops_ratio,mem_GiB")
    print(f"# roofline(16x16): {hdr}")
    worst = None
    for r in recs:
        rl = r["roofline"]
        dom = r["dominant"]
        frac = rl["compute_s"] / max(max(rl.values()), 1e-12)
        name = f"roofline/{r['arch']}/{r['shape']}"
        emit(name, 0.0,
             f"compute={rl['compute_s']:.4f}s memory={rl['memory_s']:.4f}s "
             f"coll={rl['collective_s']:.4f}s dom={dom} "
             f"roofline_frac={frac:.3f} "
             f"useful={r.get('useful_flops_ratio', 0):.2f} "
             f"mem={r['memory'].get('total_bytes', 0) / 2**30:.1f}GiB")
        if worst is None or frac < worst[1]:
            worst = (name, frac)
    if worst:
        emit("roofline/worst_cell", 0.0,
             f"{worst[0]} roofline_frac={worst[1]:.3f}")
    return {"n_cells": len(recs)}


if __name__ == "__main__":
    run()
