"""Re-run the HLO cost analysis over saved .hlo.gz artifacts and update the
.json roofline fields in place — lets hlo_cost.py evolve without recompiling
80 cells.

    PYTHONPATH=src python -m benchmarks.reanalyze
"""
from __future__ import annotations

import glob
import gzip
import json
import os

from repro.launch import hlo_cost
from repro.launch import mesh as mesh_lib

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def main():
    n = 0
    for hf in sorted(glob.glob(os.path.join(ART, "*.hlo.gz"))):
        jf = hf[:-7] + ".json"
        if not os.path.exists(jf):
            continue
        with gzip.open(hf, "rt") as f:
            a = hlo_cost.analyze_text(f.read())
        with open(jf) as f:
            rec = json.load(f)
        bmin, bup = a["bytes_min"], a["bytes"]
        rec["flops_per_device"] = a["flops"]
        rec["bytes_lower_per_device"] = bmin
        rec["bytes_upper_per_device"] = bup
        rec["bytes_accessed_per_device"] = (max(bmin, 1.0) *
                                            max(bup, 1.0)) ** 0.5
        rec["collectives"] = a["collectives"]
        rec["collective_bytes_per_device"] = a["collective_bytes"]
        rec["hlo_cost_warnings"] = a["warnings"]
        rl = {
            "compute_s": a["flops"] / mesh_lib.PEAK_FLOPS_BF16,
            "memory_s": rec["bytes_accessed_per_device"] / mesh_lib.HBM_BW,
            "collective_s": a["collective_bytes"] / mesh_lib.ICI_BW,
        }
        rec["roofline"] = rl
        rec["dominant"] = max(rl, key=rl.get)
        if rec.get("flops_per_device"):
            rec["useful_flops_ratio"] = (rec["model_flops_per_chip"] /
                                         rec["flops_per_device"])
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"[reanalyze] updated {n} artifacts")


if __name__ == "__main__":
    main()
