"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict

import jax
import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (µs) of a jit'd callable (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def fit_scaling_exponent(ns, ts) -> float:
    """Least-squares slope of log(t) vs log(n)."""
    ln, lt = np.log(np.asarray(ns, float)), np.log(np.asarray(ts, float))
    return float(np.polyfit(ln, lt, 1)[0])


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def write_bench_json(name: str, payload: Dict) -> str:
    """Persist a benchmark's result as BENCH_<name>.json at the repo root —
    the committed perf-trajectory record (one file per benchmark, overwritten
    each run so the git history carries the trend)."""
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
