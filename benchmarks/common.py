"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (µs) of a jit'd callable (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def fit_scaling_exponent(ns, ts) -> float:
    """Least-squares slope of log(t) vs log(n)."""
    ln, lt = np.log(np.asarray(ns, float)), np.log(np.asarray(ts, float))
    return float(np.polyfit(ln, lt, 1)[0])


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
