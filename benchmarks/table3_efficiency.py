"""Paper Table 3: inference-time efficiency — time saved and memory saved of
Linformer vs the standard Transformer across (n, k).

Time: measured wall-time of a full encoder forward (layerwise sharing, as the
paper benchmarks). Memory: decode-cache bytes for the causal variant plus
attention-activation bytes for the encoder — reported as ratios like the
paper's "x-fold" table.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from benchmarks.figure3_pretrain import _cfg
from repro.models import model as M


def run(quick: bool = True):
    ns = [256, 512, 1024] if quick else [256, 512, 1024, 2048, 4096]
    ks = [32, 64] if quick else [32, 64, 128, 256]
    out = {}
    for n in ns:
        cfg_std = _cfg(n, kind="standard")
        params_std = M.init_params(jax.random.PRNGKey(0), cfg_std)
        toks = jnp.ones((1, n), jnp.int32)
        fwd_std = jax.jit(lambda p, t, c=cfg_std: M.forward(
            p, c, {"tokens": t})[0])
        us_std = time_fn(fwd_std, params_std, toks)
        for k in ks:
            if k >= n:
                continue
            cfg_lin = _cfg(n, k=k)
            params_lin = M.init_params(jax.random.PRNGKey(0), cfg_lin)
            fwd_lin = jax.jit(lambda p, t, c=cfg_lin: M.forward(
                p, c, {"tokens": t})[0])
            us_lin = time_fn(fwd_lin, params_lin, toks)
            speedup = us_std / us_lin
            # activation memory of the attention map: n^2 vs n*k
            mem_saved = n / k
            out[(n, k)] = speedup
            emit(f"table3/n{n}_k{k}", us_lin,
                 f"time_saved={speedup:.2f}x attn_mem_saved={mem_saved:.1f}x")
    # decode-cache compression (the serving-side memory claim)
    from repro.configs import get_config
    cfg = get_config("qwen3-8b")
    lin = cfg.attention.linformer
    for n in (32768, 524288):
        full = n
        comp = lin.block_size + (n // lin.block_size) * lin.block_slots
        emit(f"table3/decode_cache_n{n}", 0.0,
             f"full_slots={full} compressed_slots={comp} "
             f"saved={full / comp:.1f}x")
    return out


if __name__ == "__main__":
    run(quick=False)
