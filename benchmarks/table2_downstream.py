"""Paper Table 2: downstream task performance after finetuning.

Pretrains small encoders (standard vs Linformer variants) with MLM, then
finetunes a classifier head on a synthetic sentiment-like task (class is
determined by which token-frequency band dominates the sequence — requires
aggregating context, not trivial unigram peeking at one position).
Reproduced claim: Linformer finetunes on par with the standard Transformer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from benchmarks.figure3_pretrain import _cfg, _pretrain
from repro.configs.base import OptimizerConfig
from repro.data import DataState, SyntheticCorpus, make_mlm_batch
from repro.data.pipeline import VOCAB_RESERVED
from repro.models import layers as L
from repro.models import model as M
from repro.optim import adamw_init, adamw_update, clip_by_global_norm


def make_classification_batch(rng: np.random.Generator, vocab: int,
                              batch: int, seq: int):
    """Label 1 sequences draw 70% of tokens from the upper vocab half."""
    labels = rng.integers(0, 2, batch)
    half = (vocab - VOCAB_RESERVED) // 2
    toks = np.zeros((batch, seq), np.int64)
    for i, y in enumerate(labels):
        hi_frac = 0.7 if y else 0.3
        hi = rng.random(seq) < hi_frac
        toks[i] = np.where(
            hi, rng.integers(VOCAB_RESERVED + half, vocab, seq),
            rng.integers(VOCAB_RESERVED, VOCAB_RESERVED + half, seq))
    return jnp.asarray(toks, jnp.int32), jnp.asarray(labels, jnp.int32)


def _encode(params, cfg, tokens):
    """Mean-pooled final hidden state (classification feature)."""
    batch = {"tokens": tokens}
    from repro.models.transformer import embed_inputs, apply_block
    x = embed_inputs(params, cfg, batch, None)

    def body(carry, lp):
        h, a = carry
        h2, a2 = apply_block(lp, h, cfg, shared_lin=params.get(
            "shared", {}).get("lin"), ctx=None)
        return (h2, a + a2), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["layers"])
    x = L.rms_norm(params["final_norm"], x)
    return x.mean(axis=1)


def finetune_and_eval(cfg, params, steps=60, seed=0):
    rng = np.random.default_rng(seed)
    D = cfg.d_model
    head = {"w": jnp.zeros((D, 2)), "b": jnp.zeros((2,))}
    state = {"enc": params, "head": head}
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=steps,
                           weight_decay=0.0)
    opt = adamw_init(state, ocfg)

    def loss_fn(st, toks, ys):
        feats = _encode(st["enc"], cfg, toks)
        logits = feats @ st["head"]["w"] + st["head"]["b"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.take_along_axis(logp, ys[:, None], 1).mean()

    @jax.jit
    def step(st, op, toks, ys):
        loss, g = jax.value_and_grad(loss_fn)(st, toks, ys)
        g, _ = clip_by_global_norm(g, 1.0)
        st, op = adamw_update(g, op, st, ocfg, jnp.asarray(1e-3))
        return st, op, loss

    for s in range(steps):
        toks, ys = make_classification_batch(rng, cfg.vocab_size, 16, 64)
        state, opt, loss = step(state, opt, toks, ys)

    # eval
    correct = total = 0
    eval_rng = np.random.default_rng(seed + 999)
    for _ in range(8):
        toks, ys = make_classification_batch(eval_rng, cfg.vocab_size, 16, 64)
        feats = _encode(state["enc"], cfg, toks)
        pred = jnp.argmax(feats @ state["head"]["w"] + state["head"]["b"], -1)
        correct += int((pred == ys).sum())
        total += int(ys.size)
    return correct / total


def run(quick: bool = True):
    pre_steps = 40 if quick else 250
    ft_steps = 40 if quick else 150
    seq = 128
    out = {}
    variants = [
        ("standard", _cfg(seq, kind="standard")),
        ("linformer_k16", _cfg(seq, k=16)),
        ("linformer_k16_kv", _cfg(seq, k=16, sharing="kv")),
        ("linformer_k32_layer", _cfg(seq, k=32, sharing="layerwise")),
    ]
    for name, cfg in variants:
        _, params = _pretrain(cfg, pre_steps, seq, return_params=True)
        acc = finetune_and_eval(cfg, params, steps=ft_steps)
        out[name] = acc
        emit(f"table2/{name}", 0.0, f"accuracy={acc:.3f}")
    emit("table2/parity", 0.0,
         f"linformer_vs_standard_gap="
         f"{out['linformer_k16'] - out['standard']:+.3f}")
    return out


if __name__ == "__main__":
    run(quick=False)
