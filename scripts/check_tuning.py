#!/usr/bin/env python
"""Tuning-table gate (scripts/check.sh step): validate autotuner output.

    python -m benchmarks.autotune --smoke --out /tmp/tuning_smoke.json
    python scripts/check_tuning.py /tmp/tuning_smoke.json TUNING.json

For every table given, assert what the runtime silently assumes:

  * the document passes `repro.tune.table.validate_doc` (schema version,
    known forms/params, positive-int knob values, pow2 shape buckets,
    platform-wide scalars with a null bucket);
  * the table LOADS through the real runtime path (`TuningTable.load`
    keeps the entries rather than falling back to an empty table —
    load() never raises, so a malformed committed table would otherwise
    degrade to defaults without a word);
  * each entry's recorded speedup is consistent with its measured
    trial_us/default_us (the committed evidence is self-consistent);
  * a lookup of each entry's own bucket finds the entry (the bucket keys
    round-trip through the subset-match resolution the kernels use).

A listed table that does not exist is a finding — EXCEPT with
``--missing-ok`` where a missing path is skipped (the committed
TUNING.json may not exist yet on a fresh branch). Exit 0 clean, 1 with
one line per violation, 2 on usage (scripts/_checklib.py convention).
``--json OUT.json`` writes the machine-readable report.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _checklib  # noqa: E402

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
from repro.tune.table import TuningTable, validate_doc  # noqa: E402


def check_table(path: str, findings: list) -> int:
    """Validate one table file; returns the number of entries checked."""
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except OSError as e:
        findings.append(_checklib.finding(
            f"cannot read table: {e}", path=path))
        return 0
    except json.JSONDecodeError as e:
        findings.append(_checklib.finding(
            f"malformed JSON: {e}", path=path))
        return 0
    errs = validate_doc(doc)
    if errs:
        for err in errs:
            findings.append(_checklib.finding(
                f"schema violation: {err}", path=path))
        return 0
    table = TuningTable.load(path)
    if len(table.entries) != len(doc.get("entries", [])):
        findings.append(_checklib.finding(
            f"runtime load kept {len(table.entries)} of "
            f"{len(doc['entries'])} entries — the serving path would "
            "silently fall back to defaults", path=path))
        return 0
    for i, e in enumerate(doc["entries"]):
        want = round(e["default_us"] / e["trial_us"], 3)
        if abs(e["speedup"] - want) > 0.002:
            findings.append(_checklib.finding(
                f"entry {i} ({e['form']}): recorded speedup "
                f"{e['speedup']} != default_us/trial_us = {want}",
                path=path))
        got = table.lookup(e["form"], platform=e["platform"],
                           **(e["bucket"] or {}))
        if got != e["params"]:
            findings.append(_checklib.finding(
                f"entry {i} ({e['form']}, bucket {e['bucket']}): lookup "
                "of the entry's own bucket resolves to different params "
                "— the entry is dead (shadowed by an earlier duplicate)",
                path=path))
    return len(doc["entries"])


def main(argv) -> int:
    json_out = None
    missing_ok = False
    paths = []
    it = iter(argv)
    for a in it:
        if a == "--json":
            json_out = next(it, None)
            if json_out is None:
                return _checklib.usage(
                    "check_tuning.py [--missing-ok] [--json OUT] "
                    "TABLE.json [...]")
        elif a == "--missing-ok":
            missing_ok = True
        else:
            paths.append(a)
    if not paths:
        return _checklib.usage(
            "check_tuning.py [--missing-ok] [--json OUT] TABLE.json [...]")
    findings: list = []
    checked = 0
    for path in paths:
        if missing_ok and not os.path.exists(path):
            continue
        checked += check_table(path, findings)
    return _checklib.report(
        "check_tuning", findings, checked=checked,
        ok_msg=f"{checked} entries across {len(paths)} table(s) valid",
        json_path=json_out)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
