#!/usr/bin/env python
"""Docs link check: every repo file referenced from README.md or docs/*.md
must exist, so the docs cannot silently rot as the tree moves.

Checked references:
  * markdown links whose target is a relative path (not http/#anchor)
  * anchored links (`file.md#heading-slug` or in-page `#heading-slug`):
    the target file must exist AND contain a heading whose GitHub slug
    matches the anchor
  * backtick-quoted tokens that look like repo paths (contain a '/' and a
    known suffix, e.g. `src/repro/serving/engine.py`, `docs/serving.md`)
  * `python -m pkg.module` invocations in fenced blocks / backticks

Run from anywhere: paths resolve against the repo root.

    python scripts/check_docs.py [--json OUT.json]

Exit 0 clean / 1 missing references / 2 usage
(scripts/_checklib.py convention).
"""
from __future__ import annotations

import os
import re
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _checklib  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_GLOBS = ["README.md", "docs"]
PATH_SUFFIXES = (".py", ".sh", ".md", ".json", ".txt", ".ini")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#]+)\)")
ANCHOR_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]*)#([^)\s]+)\)")
TICK_RE = re.compile(r"`([^`\s]+)`")
MODULE_RE = re.compile(r"python -m ([A-Za-z0-9_.]+)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.+?)\s*#*\s*$")


def github_slug(heading: str) -> str:
    """GitHub's heading -> anchor id: drop markdown/punctuation, lowercase,
    spaces to hyphens (hyphens/underscores survive)."""
    text = heading.replace("`", "").strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_anchors(path: str) -> set[str]:
    """All anchor ids a markdown file exposes (duplicate headings get the
    GitHub -1/-2 suffixes). Fenced code blocks are skipped so a `# comment`
    inside ```...``` is not mistaken for a heading."""
    anchors: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in open(path):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(1))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def doc_files():
    for entry in DOC_GLOBS:
        path = os.path.join(ROOT, entry)
        if os.path.isfile(path):
            yield path
        elif os.path.isdir(path):
            for name in sorted(os.listdir(path)):
                if name.endswith(".md"):
                    yield os.path.join(path, name)


def looks_like_repo_path(tok: str) -> bool:
    if not tok.endswith(PATH_SUFFIXES):
        return False
    # needs a directory part OR be a well-known root file
    return "/" in tok or tok in ("README.md", "ROADMAP.md", "CHANGES.md",
                                 "PAPER.md", "PAPERS.md", "SNIPPETS.md",
                                 "pytest.ini")


def module_to_path(mod: str) -> str | None:
    """repro.* modules live under src/; benchmarks.* at the root."""
    rel = mod.replace(".", "/")
    for cand in (f"src/{rel}.py", f"{rel}.py",
                 f"src/{rel}/__init__.py", f"{rel}/__init__.py"):
        if os.path.exists(os.path.join(ROOT, cand)):
            return cand
    return None


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            return _checklib.usage("check_docs.py [--json OUT.json]")
        del argv[i:i + 2]
    if argv:
        return _checklib.usage("check_docs.py [--json OUT.json]")
    missing = []
    checked = 0
    for doc in doc_files():
        rel_doc = os.path.relpath(doc, ROOT)
        base = os.path.dirname(doc)
        text = open(doc).read()
        refs = set()
        for m in LINK_RE.finditer(text):
            target = m.group(1).strip()
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            refs.add((target, True))          # links resolve doc-relative
        for m in TICK_RE.finditer(text):
            tok = m.group(1).strip().removeprefix("./")
            if looks_like_repo_path(tok):
                refs.add((tok, False))        # path tokens are repo-relative
        for target, doc_relative in sorted(refs):
            checked += 1
            # docs shorthand `serving/engine.py` means src/repro/...
            roots = [ROOT, os.path.join(ROOT, "src"),
                     os.path.join(ROOT, "src", "repro")]
            if doc_relative:
                roots.insert(0, base)
            if not any(os.path.exists(os.path.join(r, target))
                       for r in roots):
                missing.append(f"{rel_doc}: {target}")
        for m in ANCHOR_LINK_RE.finditer(text):
            target, anchor = m.group(1).strip(), m.group(2).strip()
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            checked += 1
            page = doc if not target else None
            if page is None:
                for r in (base, ROOT):
                    cand = os.path.join(r, target)
                    if os.path.isfile(cand):
                        page = cand
                        break
            if page is None:
                missing.append(f"{rel_doc}: {target}#{anchor} (no such file)")
            elif anchor not in heading_anchors(page):
                missing.append(f"{rel_doc}: {target}#{anchor} "
                               f"(no heading with that slug)")
        for m in MODULE_RE.finditer(text):
            mod = m.group(1)
            if mod.split(".")[0] not in ("repro", "benchmarks"):
                continue                       # only this repo's modules
            checked += 1
            if module_to_path(mod) is None:
                missing.append(f"{rel_doc}: python -m {mod}")
    return _checklib.report(
        "check_docs", [_checklib.finding(m) for m in missing],
        ok_msg=f"{checked} doc references OK", checked=checked,
        json_path=json_path)


if __name__ == "__main__":
    sys.exit(main())
