"""Shared CLI convention for the `scripts/check_*.py` gates.

Every checker (check_docs, check_trace, check_static) speaks the same
dialect so check.sh and CI wrappers can treat them uniformly:

* exit codes: 0 = clean, 1 = findings, 2 = usage error (EXIT_* below);
* findings are dicts with at least a ``msg`` key (optional ``rule``,
  ``path``, ``line`` render as a clickable prefix);
* ``--json PATH`` writes a machine-readable report
  ``{"check", "ok", "checked", "findings", ...}`` (PATH ``-`` = stdout);
  `benchmarks/report.py --lint` consumes check_static's.

See docs/static-analysis.md §Exit codes.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional

EXIT_OK = 0
EXIT_FINDINGS = 1
EXIT_USAGE = 2


def finding(msg: str, *, rule: Optional[str] = None,
            path: Optional[str] = None,
            line: Optional[int] = None) -> Dict[str, object]:
    out: Dict[str, object] = {"msg": msg}
    if rule is not None:
        out["rule"] = rule
    if path is not None:
        out["path"] = path
    if line is not None:
        out["line"] = line
    return out


def format_finding(f: Dict[str, object]) -> str:
    bits = []
    if f.get("rule"):
        bits.append(str(f["rule"]))
    if f.get("path"):
        loc = str(f["path"])
        if f.get("line"):
            loc += f":{f['line']}"
        bits.append(loc)
    prefix = " ".join(bits)
    return f"{prefix}: {f['msg']}" if prefix else str(f["msg"])


def report(name: str, findings: List[Dict[str, object]], *,
           ok_msg: str = "OK", checked: Optional[int] = None,
           json_path: Optional[str] = None,
           extra: Optional[Dict[str, object]] = None) -> int:
    """Emit the check's verdict (human + optional JSON); return the exit
    code per the convention above."""
    if json_path:
        doc: Dict[str, object] = {"check": name, "ok": not findings,
                                  "findings": findings}
        if checked is not None:
            doc["checked"] = checked
        if extra:
            doc.update(extra)
        text = json.dumps(doc, indent=2, sort_keys=True)
        if json_path == "-":
            # the JSON doc IS the stdout output; humans read the file mode
            print(text)
            return EXIT_FINDINGS if findings else EXIT_OK
        with open(json_path, "w") as fh:
            fh.write(text + "\n")
    if findings:
        print(f"{name}: FAILED ({len(findings)} findings):",
              file=sys.stderr)
        for f in findings:
            print(f"  {format_finding(f)}", file=sys.stderr)
        return EXIT_FINDINGS
    print(f"{name}: {ok_msg}")
    return EXIT_OK


def usage(text: str) -> int:
    print(f"usage: {text}", file=sys.stderr)
    return EXIT_USAGE
