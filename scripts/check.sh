#!/usr/bin/env bash
# One-entry-point smoke gate for builders:
#   1. tier-1 test suite (ROADMAP.md "Tier-1 verify")
#   2. the central-complexity-claim benchmark as a quick perf canary
# Usage: scripts/check.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
# The 7 deselected tests have failed since the seed (JAX version drift:
# shard_map() rejects check_vma; see ROADMAP.md "Open items"). They are
# deselected — not ignored as a module — so the gate stays green on a
# healthy tree while still catching NEW distributed regressions. Drop the
# deselects when the drift fix lands.
python -m pytest -x -q \
    --deselect tests/test_distributed.py::test_moe_shard_map_matches_local \
    --deselect tests/test_distributed.py::test_moe_weight_stationary_decode_matches_local \
    --deselect tests/test_distributed.py::test_tiny_mesh_train_step_compiles_with_shardings \
    --deselect tests/test_distributed.py::test_seq_parallel_linformer_matches_exact \
    --deselect tests/test_distributed.py::test_compressed_cross_pod_gradients_track_exact \
    --deselect tests/test_distributed.py::test_trainer_with_compressed_pod_grads_end_to_end \
    --deselect tests/test_distributed.py::test_param_sharding_rules

echo "== smoke benchmark: table1_complexity =="
python -m benchmarks.run --only table1_complexity

echo "== check.sh: all gates passed =="
