#!/usr/bin/env bash
# One-entry-point smoke gate for builders:
#   1. docs link check (every file referenced from README/docs exists)
#   1b. repro-lint: the two-layer static-analysis gate (AST rules
#      RL000-RL006 + jaxpr audits JX001-JX003, docs/static-analysis.md)
#      with its machine-readable report summarized by report.py --lint
#   2. tier-1 test suite (ROADMAP.md "Tier-1 verify")
#   3. the seeded fault-injection suite: deterministic slot-step / NaN-
#      logits / snapshot-corruption faults must all be detected,
#      quarantined, and recovered byte-identically (REPRO_FAULT_SEED
#      re-seeds the randomized schedule leg)
#   4. the central-complexity-claim benchmark as a quick perf canary
#   4b. the autotune smoke sweep: benchmarks/autotune.py --smoke must
#      produce a schema-valid tuning table (scripts/check_tuning.py —
#      which also validates the committed TUNING.json), and the serving
#      smoke run then consumes it via REPRO_TUNING_PATH, proving the
#      runtime lookup path on a freshly generated table
#   5. the four-trace serving benchmark (--smoke): the mixed continuous-
#      vs-static trace, the long-prompt chunked-admission-prefill trace,
#      the equal-arena-bytes capacity trace (paged-int8 must hold >= 3x
#      the resident requests of dense-fp32 — asserted in-run), AND the
#      oversubscribed overload trace (sheds + preemption + high-priority
#      deadline latency), all recorded in BENCH_serving.json (the perf
#      trajectory)
#   6. the train-step benchmark (--smoke): fused Pallas backward vs
#      reference-recompute, recording BENCH_train_step.json
#   7. the forced-8-device leg: the attention-plan parity suite (fused
#      kernels under shard_map on tp/sp/tp×sp meshes == single-device ==
#      reference, plus the preempt/snapshot-restore parity legs, dense
#      AND paged/quantized) and the
#      sharded train-step benchmark (--mesh tp=2, recorded under the
#      "mesh" key of BENCH_train_step.json)
#   8. telemetry smoke: re-run the overload trace with --trace-out /
#      --metrics-out and validate the exports with scripts/check_trace.py
#      (full request lifecycle, preemption leg, BOTH shed reasons,
#      per-priority TTFT/TPOT histograms — docs/observability.md)
# Usage: scripts/check.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs link check =="
python scripts/check_docs.py

echo "== static analysis: repro-lint (AST + jaxpr) =="
python scripts/check_static.py --json /tmp/repro_lint.json
python -m benchmarks.report --lint /tmp/repro_lint.json

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== fault injection: seeded recovery suite (REPRO_FAULT_SEED=7) =="
REPRO_FAULT_SEED=7 python -m pytest -q tests/test_serving_faults.py

echo "== smoke benchmark: table1_complexity =="
python -m benchmarks.run --only table1_complexity

echo "== smoke benchmark: autotune (kernel/scheduler sweep -> tuning table) =="
python -m benchmarks.autotune --smoke --out /tmp/tuning_smoke.json
python scripts/check_tuning.py /tmp/tuning_smoke.json
python scripts/check_tuning.py --missing-ok TUNING.json

echo "== smoke benchmark: serving_throughput (mixed + long-prompt + capacity + overload) =="
REPRO_TUNING_PATH=/tmp/tuning_smoke.json python -m benchmarks.serving_throughput --smoke

echo "== smoke benchmark: train_step (fused vs reference backward) =="
python -m benchmarks.train_step --smoke

echo "== forced-8-device smoke: attention-plan parity suite =="
python -m pytest -q tests/test_attention_plan.py

echo "== forced-8-device smoke benchmark: train_step --mesh tp=2 =="
python -m benchmarks.train_step --smoke --mesh tp=2

echo "== telemetry smoke: overload trace export + check_trace =="
python -m benchmarks.serving_throughput --smoke --trace overload \
    --trace-out /tmp/overload_trace.json \
    --metrics-out /tmp/overload_metrics.jsonl
python scripts/check_trace.py /tmp/overload_trace.json \
    /tmp/overload_metrics.jsonl

echo "== check.sh: all gates passed =="
