#!/usr/bin/env bash
# One-entry-point smoke gate for builders:
#   1. tier-1 test suite (ROADMAP.md "Tier-1 verify")
#   2. the central-complexity-claim benchmark as a quick perf canary
#   3. the continuous-batching serving benchmark (--smoke) so the scheduler
#      path is exercised and BENCH_serving.json records the perf trajectory
# Usage: scripts/check.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke benchmark: table1_complexity =="
python -m benchmarks.run --only table1_complexity

echo "== smoke benchmark: serving_throughput =="
python -m benchmarks.serving_throughput --smoke

echo "== check.sh: all gates passed =="
