#!/usr/bin/env python
"""Telemetry smoke-gate validator (scripts/check.sh step): given the
trace + metrics exported by the oversubscribed overload serving
benchmark,

    python -m benchmarks.serving_throughput --smoke --trace overload \
        --trace-out /tmp/overload_trace.json \
        --metrics-out /tmp/overload_metrics.jsonl
    python scripts/check_trace.py /tmp/overload_trace.json \
        /tmp/overload_metrics.jsonl

assert the export is Perfetto-loadable and actually contains the SLO
story the overload trace is designed to exercise
(docs/observability.md):

  * trace: a valid Chrome-trace JSON with the full request lifecycle —
    request_queued / request_admitted / request_first_token /
    request_retired instants, the preemption leg (request_snapshot +
    request_preempted + request_restored), request_shed markers for BOTH
    shed reasons (queue_full overflow AND a provably-infeasible
    deadline), and the per-chunk decode_chunk scheduler spans.
  * metrics JSONL: per-priority TTFT histograms (ticks AND wall ms),
    per-priority TPOT histograms, queue-wait histograms, and the
    shed-attribution counter labelled reason=deadline_infeasible.

Exit 0 on success, 1 with one line per missing fact, 2 on usage
errors (scripts/_checklib.py convention). `--json OUT.json` writes the
machine-readable report.
"""
from __future__ import annotations

import json
import os
import sys
from collections import defaultdict

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import _checklib  # noqa: E402

REQUIRED_INSTANTS = (
    "request_queued", "request_admitted", "request_first_token",
    "request_retired", "request_shed", "request_snapshot",
    "request_preempted", "request_restored",
)
REQUIRED_SPANS = ("decode_chunk", "serve")
# (metric, label-subset) pairs that must exist with count > 0
REQUIRED_HISTOGRAMS = (
    ("serving_ttft_ticks", {"priority": "0"}),
    ("serving_ttft_ticks", {"priority": "2"}),
    ("serving_ttft_ms", {"priority": "0"}),
    ("serving_tpot_ms", {"priority": "0"}),
    ("serving_tpot_ms", {"priority": "2"}),
    ("serving_queue_wait_ticks", {"priority": "0"}),
)


def check_trace(path: str, problems: list) -> None:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        problems.append(f"trace {path}: unreadable ({e})")
        return
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        problems.append(f"trace {path}: no traceEvents array")
        return
    by_ph = defaultdict(lambda: defaultdict(int))
    shed_reasons = set()
    for e in events:
        by_ph[e.get("ph")][e.get("name")] += 1
        if e.get("ph") == "i" and e.get("name") == "request_shed":
            shed_reasons.add(e.get("args", {}).get("reason"))
    for name in REQUIRED_INSTANTS:
        if not by_ph["i"].get(name):
            problems.append(f"trace: no {name!r} instant event")
    for name in REQUIRED_SPANS:
        if not by_ph["X"].get(name):
            problems.append(f"trace: no {name!r} span")
    for reason in ("queue_full", "deadline_infeasible"):
        if reason not in shed_reasons:
            problems.append(f"trace: no request_shed with reason={reason!r} "
                            f"(saw {sorted(shed_reasons)})")
    # every event Perfetto needs timestamped is
    for e in events:
        if e.get("ph") in ("X", "i") and "ts" not in e:
            problems.append(f"trace: {e.get('name')!r} event without ts")
            break


def check_metrics(path: str, problems: list) -> None:
    recs = []
    try:
        with open(path) as f:
            for i, line in enumerate(f):
                if line.strip():
                    try:
                        recs.append(json.loads(line))
                    except json.JSONDecodeError as e:
                        problems.append(f"metrics {path} line {i + 1}: "
                                        f"malformed JSON ({e})")
                        return
    except OSError as e:
        problems.append(f"metrics {path}: unreadable ({e})")
        return
    hists = [r for r in recs if r.get("type") == "histogram"]
    for name, want in REQUIRED_HISTOGRAMS:
        hit = [r for r in hists if r.get("metric") == name
               and all(r.get("labels", {}).get(k) == v
                       for k, v in want.items())
               and r.get("count", 0) > 0]
        if not hit:
            problems.append(f"metrics: no populated histogram {name} "
                            f"with labels ⊇ {want}")
    sheds = [r for r in recs if r.get("metric") == "serving_shed_events_total"
             and r.get("labels", {}).get("reason") == "deadline_infeasible"
             and r.get("value", 0) > 0]
    if not sheds:
        problems.append("metrics: no serving_shed_events_total counter "
                        "with reason=deadline_infeasible and value > 0")
    if not any(r.get("kind") == "plan_attribution" for r in recs):
        problems.append("metrics: no plan_attribution record")


def main(argv) -> int:
    argv = list(argv)
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            return _checklib.usage(
                "check_trace.py TRACE.json METRICS.jsonl [--json OUT.json]")
        del argv[i:i + 2]
    if len(argv) != 2:
        return _checklib.usage(
            "check_trace.py TRACE.json METRICS.jsonl [--json OUT.json]")
    problems: list = []
    check_trace(argv[0], problems)
    check_metrics(argv[1], problems)
    return _checklib.report(
        "check_trace", [_checklib.finding(p) for p in problems],
        ok_msg=f"{argv[0]} + {argv[1]} OK (lifecycle, preemption, "
               "both shed reasons, SLO histograms)",
        json_path=json_path)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
