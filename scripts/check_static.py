#!/usr/bin/env python
"""repro-lint driver: the two-layer static-analysis gate (check.sh step).

Layer 1 (`repro.analysis.astlint`) parses every tracked file under src/
and enforces the source-level invariants RL000–RL006 (dispatch purity,
host-sync discipline, kernel contracts, donation safety, spec hygiene,
no stray artifacts/prints). Layer 2 (`repro.analysis.jaxpr_audit`)
traces tiny canonical instances of the stack's entry points and checks
the PROGRAM-level invariants JX001–JX003 (host-effect-free decode body,
collective bytes == comm-cost model, no f64 widening on the decode
path). Rule catalog + waiver pragma grammar: docs/static-analysis.md.

    python scripts/check_static.py [--json OUT.json] [--no-jaxpr]
                                   [--baseline scripts/static_baseline.json]

Exit 0 when every finding is empty or baselined, 1 on new findings,
2 on usage errors (scripts/_checklib.py convention). The shipped
baseline is EMPTY — the tree is lint-clean; the baseline mechanism
exists so a future genuine-but-deferred violation can land without
turning the gate red for everyone else.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import _checklib  # noqa: E402

DEFAULT_BASELINE = os.path.join(ROOT, "scripts", "static_baseline.json")


def load_baseline(path: str):
    if not os.path.exists(path):
        return set()
    with open(path) as fh:
        return set(json.load(fh))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="check_static.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable report here ('-' = "
                         "stdout); benchmarks/report.py --lint reads it")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the (slower) jaxpr audit layer")
    ap.add_argument("--baseline", metavar="PATH", default=DEFAULT_BASELINE,
                    help="accepted-findings file (list of finding keys)")
    try:
        args = ap.parse_args(argv)
    except SystemExit as e:
        return _checklib.EXIT_USAGE if e.code not in (0, None) else 0

    from repro.analysis import astlint

    res = astlint.lint_tree(ROOT)
    findings = list(res.findings)
    rules = dict(astlint.RULES)
    stats = {"files": res.files_checked, "pragmas": res.pragmas_used}

    if not args.no_jaxpr:
        from repro.analysis import jaxpr_audit
        audit = jaxpr_audit.run_audit()
        findings.extend(audit.findings)
        rules.update(jaxpr_audit.JX_RULES)
        stats["jaxpr"] = audit.stats

    baseline = load_baseline(args.baseline)
    fresh = [f for f in findings if f.key not in baseline]
    n_baselined = len(findings) - len(fresh)

    layers = "ast" if args.no_jaxpr else "ast+jaxpr"
    ok_msg = (f"{res.files_checked} files, {res.pragmas_used} pragmas, "
              f"{n_baselined} baselined — {layers} clean")
    return _checklib.report(
        "check_static", [f.as_dict() for f in fresh],
        ok_msg=ok_msg, checked=res.files_checked, json_path=args.json,
        extra={"stats": stats, "baselined": n_baselined, "rules": rules})


if __name__ == "__main__":
    sys.exit(main())
