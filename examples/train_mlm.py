"""Paper-faithful end-to-end driver: MLM-pretrain a Linformer encoder
(the paper's RoBERTa-style setup, Figure 3) with checkpointing/auto-resume.

Defaults train a ~10M-param model for a few hundred steps on CPU; pass
--layers/--d-model/--steps to scale up (e.g. ~100M: --layers 12 --d-model 768
--seq 512 on real hardware).

    PYTHONPATH=src python examples/train_mlm.py --steps 200 --k 16
"""
import argparse
import dataclasses

from repro.configs.linformer_paper import CONFIG as PAPER_CONFIG
from repro.configs.base import (AttentionConfig, LinformerConfig, MLPConfig,
                                OptimizerConfig, TrainConfig)
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--k", type=int, default=32,
                    help="Linformer projected dimension")
    ap.add_argument("--sharing", default="layerwise",
                    choices=["none", "headwise", "kv", "layerwise"])
    ap.add_argument("--attention", default="linformer",
                    choices=["linformer", "standard"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_mlm_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        PAPER_CONFIG,
        num_layers=args.layers,
        d_model=args.d_model,
        vocab_size=args.vocab,
        max_seq_len=args.seq,
        dtype="float32",
        remat="none",
        attention=AttentionConfig(
            kind=args.attention,
            num_heads=args.heads,
            num_kv_heads=args.heads,
            head_dim=args.d_model // args.heads,
            causal=False,
            use_rope=False,
            linformer=LinformerConfig(k=args.k, sharing=args.sharing),
        ),
        mlp=MLPConfig(d_ff=4 * args.d_model, activation="gelu"),
    )
    n_params = cfg.param_count_estimate
    print(f"MLM pretraining: {args.attention} k={args.k} "
          f"sharing={args.sharing} ~{n_params/1e6:.1f}M params")

    tcfg = TrainConfig(
        seq_len=args.seq, global_batch=args.batch, steps=args.steps,
        log_every=max(args.steps // 10, 1), checkpoint_every=args.steps // 2,
        checkpoint_dir=args.ckpt_dir,
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=args.steps // 10,
                                  total_steps=args.steps))
    trainer = Trainer(cfg, tcfg)   # auto-resumes if a checkpoint exists
    metrics = trainer.run()
    print(f"done: loss={metrics['loss']:.4f} ppl={metrics['perplexity']:.2f}")


if __name__ == "__main__":
    main()
