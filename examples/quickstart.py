"""Quickstart: build a small Linformer causal LM, train it briefly on the
synthetic corpus, checkpoint, and generate text — the whole public API in
~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.serving import ServingEngine
from repro.train import Trainer


def main():
    # 1. a reduced qwen3-style decoder with blockwise-causal Linformer attention
    cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype="float32")
    print(f"model: {cfg.name} | attention: {cfg.attention.kind} "
          f"(block={cfg.attention.linformer.block_size}, "
          f"r={cfg.attention.linformer.block_slots})")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainConfig(
            seq_len=64, global_batch=8, steps=60, log_every=20,
            checkpoint_every=30, checkpoint_dir=ckpt_dir,
            optimizer=OptimizerConfig(lr=2e-3, warmup_steps=10,
                                      total_steps=60))
        trainer = Trainer(cfg, tcfg)
        metrics = trainer.run()
        print(f"final loss: {metrics['loss']:.3f} "
              f"(ppl {metrics['perplexity']:.1f})")

        # 2. serve the trained model with the compressed Linformer cache
        engine = ServingEngine(trainer._params, cfg, max_seq=128,
                               cache_dtype=jnp.float32)
        prompts = [[1, 10, 20, 30], [1, 42, 42, 42]]
        outs = engine.serve(prompts, max_new_tokens=12)
        for p, o in zip(prompts, outs):
            print(f"prompt {p} -> generated {o}")
        print(f"decode cache: {engine.cache_bytes(2)} bytes "
              f"(compressed; standard cache would be larger)")


if __name__ == "__main__":
    main()
