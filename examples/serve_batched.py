"""Batched serving example: mixed-length requests through the scheduler,
comparing the Linformer compressed decode cache against the standard
full-KV baseline on the same weights.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import ServingEngine


def main():
    cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(4, cfg.vocab_size, rng.choice([8, 8, 16])))
               for _ in range(6)]
    print(f"{len(prompts)} requests, lengths {[len(p) for p in prompts]}")

    # Linformer compressed-cache engine
    eng = ServingEngine(params, cfg, max_seq=256, cache_dtype=jnp.float32)
    t0 = time.perf_counter()
    outs = eng.serve(prompts, max_new_tokens=16, max_batch=4)
    dt = time.perf_counter() - t0
    for i, o in enumerate(outs):
        print(f"  req{i}: {len(o)} tokens -> {o[:8]}...")
    print(f"linformer engine: {dt:.2f}s, cache={eng.cache_bytes(4)} B")

    # standard-attention baseline on the SAME weights (E/F simply unused)
    cfg_std = cfg.with_attention_kind("standard")
    eng_std = ServingEngine(params, cfg_std, max_seq=256,
                            cache_dtype=jnp.float32)
    t0 = time.perf_counter()
    eng_std.serve(prompts, max_new_tokens=16, max_batch=4)
    dt_std = time.perf_counter() - t0
    print(f"standard engine:  {dt_std:.2f}s, cache={eng_std.cache_bytes(4)} B")
    print(f"cache compression: {eng_std.cache_bytes(4) / eng.cache_bytes(4):.1f}x")


if __name__ == "__main__":
    main()
