"""Batched serving example: mixed-length requests through the
continuous-batching scheduler (slot pool + streaming completions) against
the static bucketed baseline, and the Linformer compressed decode cache
against the standard full-KV baseline on the same weights.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import ServingEngine


def main():
    cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(4, cfg.vocab_size, rng.choice([8, 8, 16])))
               for _ in range(6)]
    budgets = [int(b) for b in rng.choice([4, 8, 16], len(prompts))]
    print(f"{len(prompts)} requests, prompt lengths "
          f"{[len(p) for p in prompts]}, budgets {budgets}")

    # continuous batching: 3-slot pool over 6 requests, streaming completions
    eng = ServingEngine(params, cfg, max_seq=256, cache_dtype=jnp.float32,
                        decode_chunk=8)
    done_order = []
    t0 = time.perf_counter()
    outs, sched = eng.serve(
        prompts, budgets, max_batch=3,
        on_complete=lambda rid, toks: done_order.append(rid),
        return_scheduler=True)
    dt = time.perf_counter() - t0
    for i, o in enumerate(outs):
        print(f"  req{i}: {len(o)} tokens -> {o[:8]}...")
    print(f"continuous (3 slots): {dt:.2f}s, completion order {done_order}, "
          f"mean occupancy {sched.stats.mean_occupancy:.2f}")

    # static bucketed baseline — identical outputs, more row-steps
    t0 = time.perf_counter()
    outs_static = eng.serve_static(prompts, budgets, max_batch=3)
    dt_static = time.perf_counter() - t0
    assert outs == outs_static, "continuous/static outputs diverged"
    print(f"static bucketed:      {dt_static:.2f}s, outputs identical")

    # chunked admission: a long prompt streams into its slot 32 tokens per
    # round (PREFILLING state) instead of stalling the pool for one big
    # forward; short requests keep decoding and finish first
    eng_ck = ServingEngine(params, cfg, max_seq=256, cache_dtype=jnp.float32,
                           decode_chunk=8, prefill_chunk=32)
    long_prompt = list(rng.integers(4, cfg.vocab_size, 160))
    done_order.clear()
    outs_ck, sched_ck = eng_ck.serve(
        [long_prompt] + prompts, [8] + budgets, max_batch=3,
        on_complete=lambda rid, toks: done_order.append(rid),
        return_scheduler=True)
    assert outs_ck[1:] == outs, "chunked admission changed short outputs"
    print(f"chunked admission: all {len(prompts) + 1} prompts "
          f"({sched_ck.stats.prefill_tokens} prompt tokens, one of them "
          f"160 tokens long) streamed in via "
          f"{sched_ck.stats.prefill_forwards} batched prefill launches; "
          f"completion order {done_order} (the long request rid=0 "
          f"finishes last — it prefilled while the others decoded)")

    # standard-attention baseline on the SAME weights (E/F simply unused)
    cfg_std = cfg.with_attention_kind("standard")
    eng_std = ServingEngine(params, cfg_std, max_seq=256,
                            cache_dtype=jnp.float32)
    eng_std.serve(prompts, budgets, max_batch=3)
    print(f"cache compression: "
          f"{eng_std.cache_bytes(4) / eng.cache_bytes(4):.1f}x "
          f"(compressed {eng.cache_bytes(4)} B vs full "
          f"{eng_std.cache_bytes(4)} B at batch 4)")


if __name__ == "__main__":
    main()
