"""Long-context decode with the compressed Linformer cache — the technique's
serving-side payoff. Prefills an 8k-token context (parallel, block-compressed
on the fly) and decodes with a cache of c + r·(n/c) slots instead of n.

    PYTHONPATH=src python examples/long_context_decode.py --context 8192
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import LinformerConfig
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--context", type=int, default=8192)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    base = get_smoke_config("qwen3-8b")
    cfg = dataclasses.replace(
        base, dtype="float32", max_seq_len=args.context * 2,
        attention=dataclasses.replace(
            base.attention,
            linformer=LinformerConfig(k=64, sharing="layerwise",
                                      block_size=256, block_slots=16)))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    c = cfg.attention.linformer.block_size
    r = cfg.attention.linformer.block_slots

    rng = np.random.default_rng(0)
    ctx_tokens = jnp.asarray(
        rng.integers(4, cfg.vocab_size, (1, args.context)), jnp.int32)

    max_seq = args.context + args.new_tokens + c
    t0 = time.perf_counter()
    prefill = jax.jit(lambda p, t: M.forward(
        p, cfg, {"tokens": t}, return_cache=True, cache_max_seq=max_seq,
        cache_dtype=jnp.float32))
    logits, _, cache = prefill(params, ctx_tokens)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    comp_slots = (args.context // c) * r
    print(f"prefill {args.context} tokens in {t_prefill:.2f}s -> "
          f"compressed cache: {comp_slots} slots + {c} raw "
          f"(vs {args.context} full-KV slots, "
          f"{args.context / (comp_slots + c):.1f}x smaller)")

    decode = jax.jit(lambda p, b, ca: M.decode_step(p, cfg, b, ca))
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    outs = []
    for _ in range(args.new_tokens):
        lg, cache = decode(params, {"tokens": cur}, cache)
        cur = jnp.argmax(lg[:, 0], -1)[:, None].astype(jnp.int32)
        outs.append(int(cur[0, 0]))
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    print(f"decoded {args.new_tokens} tokens in {dt:.2f}s "
          f"({dt / args.new_tokens * 1e3:.1f} ms/token) -> {outs[:10]}...")
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    full_bytes = (2 * cfg.num_layers * max_seq *
                  cfg.attention.num_kv_heads * cfg.attention.head_dim * 4)
    print(f"cache bytes: {cache_bytes} (full-KV baseline would be "
          f"{full_bytes}, {full_bytes / cache_bytes:.1f}x)")


if __name__ == "__main__":
    main()
