"""Sequence-projection Pallas kernel (TPU target): K̄ = EᵀK.

A tall-skinny reduction over the sequence axis: (k × n)·(n × Dh). The kernel
tiles n into `block_s`-row VMEM blocks and accumulates the (k × Dh) result in
a fp32 VMEM scratch accumulator, emitting once on the final sequence block —
one HBM write of k×Dh instead of n/block_s partial writes.

Grid: (B·H, S / block_s) — the s axis is the innermost (fastest) so the
accumulator lives across the s sweep of each (b,h).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, e_ref, out_ref, acc_ref, *, n_s: int):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                    # (bs, Dh)
    e = e_ref[...]                                  # (bs, K)
    acc_ref[...] += jax.lax.dot_general(
        e, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (K, Dh)

    @pl.when(s_idx == n_s - 1)
    def _emit():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def seq_projection(
    x: jax.Array,       # (B, H, S, Dh) keys or values
    E: jax.Array,       # (S, K)
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, Dh = x.shape
    K = E.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    x3 = x.reshape(B * H, S, Dh)
    n_s = S // bs

    out = pl.pallas_call(
        functools.partial(_kernel, n_s=n_s),
        grid=(B * H, n_s),
        in_specs=[
            pl.BlockSpec((1, bs, Dh), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((bs, K), lambda bh, s: (s, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, Dh), lambda bh, s: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, K, Dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((K, Dh), jnp.float32)],
        interpret=interpret,
    )(x3, E)
    return out.reshape(B, H, K, Dh)
