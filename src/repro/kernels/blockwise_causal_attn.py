"""Fused blockwise-causal Linformer attention Pallas kernel (TPU target).

One grid step computes one query block (c tokens of one (batch, head)):
joint softmax over [own block, causal | compressed slots of previous blocks].
The compressed K̄/V̄ (M = (S/c)·r slots) are pinned in VMEM — at r/c = 16/256
compression, a 32k-token context compresses to 2048 slots × Dh (512 KiB bf16),
far under VMEM; raw K/V of the own block are streamed per grid step.

Grid: (B·H, nb). Blocks:
  q, k_loc, v_loc : (1, c, Dh)   — block `n` of the sequence
  k̄, v̄           : (1, M, Dh)   — pinned
  out             : (1, c, Dh)

GQA: K/V carry their native Hkv heads; the index maps route grid row
b·H + h to kv row b·Hkv + h//G (G = H/Hkv), so grouped query heads share
one kv stream without any jnp.repeat materialization in HBM.

Causality: local scores use a (c, c) lower-triangular mask; global scores
mask slots whose owning block ≥ the current grid block (slot i belongs to
block i // r).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, kl_ref, vl_ref, kbar_ref, vbar_ref, out_ref, *,
            scale: float, r: int):
    n = pl.program_id(1)
    q = q_ref[0]                                    # (c, Dh)
    kl = kl_ref[0]
    vl = vl_ref[0]
    kbar = kbar_ref[0]                              # (M, Dh)
    vbar = vbar_ref[0]
    c = q.shape[0]
    M = kbar.shape[0]

    s_loc = jax.lax.dot_general(
        q, kl, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (c, c)
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    s_loc = jnp.where(ti >= si, s_loc, NEG_INF)

    s_glob = jax.lax.dot_general(
        q, kbar, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (c, M)
    slot_blk = jax.lax.broadcasted_iota(jnp.int32, (c, M), 1) // r
    s_glob = jnp.where(slot_blk < n, s_glob, NEG_INF)

    m = jnp.maximum(jnp.max(s_loc, -1, keepdims=True),
                    jnp.max(s_glob, -1, keepdims=True))
    p_loc = jnp.exp(s_loc - m)
    p_glob = jnp.exp(s_glob - m)
    denom = jnp.sum(p_loc, -1, keepdims=True) + jnp.sum(p_glob, -1,
                                                        keepdims=True)
    out = jax.lax.dot_general(
        (p_loc / denom).astype(vl.dtype), vl, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out += jax.lax.dot_general(
        (p_glob / denom).astype(vbar.dtype), vbar, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[0] = out.astype(out_ref.dtype)


def _prefix_kernel(q_ref, kl_ref, vl_ref, ck_ref, cv_ref, nb0_ref, out_ref, *,
                   scale: float, r: int):
    """Chunk-prefill variant of `_kernel`: the compressed operand is the
    SLOT-RESIDENT cache buffer (full M_total = (max_seq/c)·r slots, pinned)
    and the visibility cut shifts by the row's start block nb0 — grid block
    n of the chunk is absolute block nb0 + n, so it sees slots of blocks
    < nb0 + n. nb0 arrives as a per-row (1, 1) int32 block (SMEM-friendly
    scalar layout; interpret mode reads it directly)."""
    n = pl.program_id(1)
    nb0 = nb0_ref[0, 0]
    q = q_ref[0]                                    # (c, Dh)
    kl = kl_ref[0]
    vl = vl_ref[0]
    ck = ck_ref[0]                                  # (M, Dh)
    cv = cv_ref[0]
    c = q.shape[0]
    M = ck.shape[0]

    s_loc = jax.lax.dot_general(
        q, kl, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (c, c)
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    s_loc = jnp.where(ti >= si, s_loc, NEG_INF)

    s_glob = jax.lax.dot_general(
        q, ck, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (c, M)
    slot_blk = jax.lax.broadcasted_iota(jnp.int32, (c, M), 1) // r
    s_glob = jnp.where(slot_blk < n + nb0, s_glob, NEG_INF)

    m = jnp.maximum(jnp.max(s_loc, -1, keepdims=True),
                    jnp.max(s_glob, -1, keepdims=True))
    p_loc = jnp.exp(s_loc - m)
    p_glob = jnp.exp(s_glob - m)
    denom = jnp.sum(p_loc, -1, keepdims=True) + jnp.sum(p_glob, -1,
                                                        keepdims=True)
    out = jax.lax.dot_general(
        (p_loc / denom).astype(vl.dtype), vl, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out += jax.lax.dot_general(
        (p_glob / denom).astype(cv.dtype), cv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[0] = out.astype(out_ref.dtype)


def blockwise_causal_prefix_attn(
    q: jax.Array,        # (B, H, P, Dh) — one prefill chunk of queries
    k: jax.Array,        # (B, Hkv, P, Dh) — chunk keys (local, exact)
    v: jax.Array,
    comp_k: jax.Array,   # (B, Hkv, M, Dh) — slot-resident compressed cache
    comp_v: jax.Array,   #                   (chunk's own blocks already folded)
    start_blocks: jax.Array,   # (B,) int32 — per-row absolute start block
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    """Blockwise-causal attention for a prefill chunk at a nonzero per-row
    start offset, against the slot-resident compressed cache.

    Same grid/GQA routing as :func:`blockwise_causal_attn`, but the pinned
    compressed operand is the cache's FULL (M_total, Dh) slot buffer and the
    causality cut is shifted per row by `start_blocks` (passed as a (B, 1)
    int32 scalar block). M_total = (max_seq/c)·r must fit in VMEM — the same
    compression budget the decode kernel already pins.
    """
    B, H, P, Dh = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    c = block_size
    assert P % c == 0, (P, c)
    nb = P // c
    M = comp_k.shape[2]
    q3 = q.reshape(B * H, P, Dh)
    k3 = k.reshape(B * Hkv, P, Dh)
    v3 = v.reshape(B * Hkv, P, Dh)
    ck3 = comp_k.reshape(B * Hkv, M, Dh)
    cv3 = comp_v.reshape(B * Hkv, M, Dh)
    nb0 = jnp.asarray(start_blocks, jnp.int32).reshape(B, 1)

    def kv_row(bh):
        return (bh // H) * Hkv + (bh % H) // G

    out = pl.pallas_call(
        functools.partial(_prefix_kernel, scale=scale, r=block_slots),
        grid=(B * H, nb),
        in_specs=[
            pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
            pl.BlockSpec((1, 1), lambda bh, n: (bh // H, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, P, Dh), q.dtype),
        interpret=interpret,
    )(q3, k3, v3, ck3, cv3, nb0)
    return out.reshape(B, H, P, Dh)


def blockwise_causal_attn(
    q: jax.Array,       # (B, H, S, Dh)
    k: jax.Array,       # (B, Hkv, S, Dh) — native kv heads, H % Hkv == 0
    v: jax.Array,
    kbar: jax.Array,    # (B, Hkv, M, Dh)  compressed slots, M = (S/c)*r
    vbar: jax.Array,
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, Dh = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    c = block_size
    assert S % c == 0
    nb = S // c
    M = kbar.shape[2]
    assert M == nb * block_slots, (M, nb, block_slots)
    q3 = q.reshape(B * H, S, Dh)
    k3 = k.reshape(B * Hkv, S, Dh)
    v3 = v.reshape(B * Hkv, S, Dh)
    kb3 = kbar.reshape(B * Hkv, M, Dh)
    vb3 = vbar.reshape(B * Hkv, M, Dh)

    # grid row b·H + h reads kv row b·Hkv + h//G — the GQA group share
    # happens in the index map, never as a repeated HBM tensor.
    def kv_row(bh):
        return (bh // H) * Hkv + (bh % H) // G

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, r=block_slots),
        grid=(B * H, nb),
        in_specs=[
            pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
        interpret=interpret,
    )(q3, k3, v3, kb3, vb3)
    return out.reshape(B, H, S, Dh)
