"""Fused blockwise-causal Linformer attention Pallas kernels (TPU target).

Forward — one grid step computes one query block (c tokens of one
(batch, head)): joint softmax over [own block, causal | compressed slots of
previous blocks]. The compressed K̄/V̄ (M = (S/c)·r slots) are pinned in
VMEM — at r/c = 16/256 compression, a 32k-token context compresses to 2048
slots × Dh (512 KiB bf16), far under VMEM; raw K/V of the own block are
streamed per grid step.

Grid: (B·H, nb). Blocks:
  q, k_loc, v_loc : (1, c, Dh)   — block `n` of the sequence
  k̄, v̄           : (1, M, Dh)   — pinned
  out             : (1, c, Dh)

GQA: K/V carry their native Hkv heads; the index maps route grid row
b·H + h to kv row b·Hkv + h//G (G = H/Hkv), so grouped query heads share
one kv stream without any jnp.repeat materialization in HBM.

Causality: local scores use a (c, c) lower-triangular mask; global scores
mask slots whose owning block ≥ the current grid block (slot i belongs to
block i // r).

Backward (`blockwise_causal_attn_bwd`) — same per-query-block decomposition,
on the grid (B·Hkv, nb, G) with the GQA group axis innermost: the joint
softmax is RECOMPUTED from the forward's saved per-row residuals (row max
`m` and denominator — the flash-attention trick, no stored probabilities),
then the five blockwise matmuls produce dq, dk_loc/dv_loc and dk̄/dv̄.
dk_loc/dv_loc (shared by the G query heads of a group) and dk̄/dv̄ (shared
additionally across the nb query blocks) accumulate in fp32 VMEM scratch
across consecutive grid steps and are emitted on each accumulator's last
contributing step — the inner axes sweep every contributor of a kv row
consecutively, so no output block is ever revisited after a flush, and GQA
still never repeats K/V in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _joint_scores(q, kl, kbar, blk_cut, scale, r):
    """Masked fp32 scores of one query block: local (c, c) causal scores and
    global (c, M) scores over compressed slots of blocks < blk_cut."""
    c = q.shape[0]
    M = kbar.shape[0]
    s_loc = jax.lax.dot_general(
        q, kl, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (c, c)
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    s_loc = jnp.where(ti >= si, s_loc, NEG_INF)

    s_glob = jax.lax.dot_general(
        q, kbar, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (c, M)
    slot_blk = jax.lax.broadcasted_iota(jnp.int32, (c, M), 1) // r
    s_glob = jnp.where(slot_blk < blk_cut, s_glob, NEG_INF)
    return s_loc, s_glob


def _attend_block(q, kl, vl, kbar, vbar, n, scale, r):
    """One query block's joint-softmax attention: returns (out fp32, m,
    denom) — the single forward body shared by the plain and
    residual-emitting kernels, so grad-time primal and inference forward can
    never diverge."""
    s_loc, s_glob = _joint_scores(q, kl, kbar, n, scale, r)
    m = jnp.maximum(jnp.max(s_loc, -1, keepdims=True),
                    jnp.max(s_glob, -1, keepdims=True))
    p_loc = jnp.exp(s_loc - m)
    p_glob = jnp.exp(s_glob - m)
    denom = jnp.sum(p_loc, -1, keepdims=True) + jnp.sum(p_glob, -1,
                                                        keepdims=True)
    out = jax.lax.dot_general(
        (p_loc / denom).astype(vl.dtype), vl, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out += jax.lax.dot_general(
        (p_glob / denom).astype(vbar.dtype), vbar, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out, m, denom


def _kernel(q_ref, kl_ref, vl_ref, kbar_ref, vbar_ref, out_ref, *,
            scale: float, r: int):
    n = pl.program_id(1)
    out, _, _ = _attend_block(q_ref[0], kl_ref[0], vl_ref[0], kbar_ref[0],
                              vbar_ref[0], n, scale, r)
    out_ref[0] = out.astype(out_ref.dtype)


def _kernel_res(q_ref, kl_ref, vl_ref, kbar_ref, vbar_ref,
                out_ref, m_ref, denom_ref, *, scale: float, r: int):
    """Forward variant that also emits the softmax residuals (per-row max and
    denominator, fp32) the fused backward recomputes the probabilities from."""
    n = pl.program_id(1)
    out, m, denom = _attend_block(q_ref[0], kl_ref[0], vl_ref[0],
                                  kbar_ref[0], vbar_ref[0], n, scale, r)
    out_ref[0] = out.astype(out_ref.dtype)
    m_ref[0] = m[:, 0]
    denom_ref[0] = denom[:, 0]


def _prefix_kernel(q_ref, kl_ref, vl_ref, ck_ref, cv_ref, nb0_ref, out_ref, *,
                   scale: float, r: int):
    """Chunk-prefill/sequence-parallel variant of `_kernel`: the compressed
    operand is a FULL slot buffer (the slot-resident cache, or the gathered
    sequence-parallel prefix — pinned either way) and the visibility cut
    shifts by the row's start block nb0 — grid block n of the chunk is
    absolute block nb0 + n, so it sees slots of blocks < nb0 + n. nb0
    arrives as a per-row (1, 1) int32 block (SMEM-friendly scalar layout;
    interpret mode reads it directly). Shares `_attend_block` with the
    offset-zero training kernel so the two forms can never diverge."""
    n = pl.program_id(1)
    nb0 = nb0_ref[0, 0]
    out, _, _ = _attend_block(q_ref[0], kl_ref[0], vl_ref[0], ck_ref[0],
                              cv_ref[0], n + nb0, scale, r)
    out_ref[0] = out.astype(out_ref.dtype)


def _prefix_kernel_res(q_ref, kl_ref, vl_ref, ck_ref, cv_ref, nb0_ref,
                       out_ref, m_ref, denom_ref, *, scale: float, r: int):
    """`_prefix_kernel` that also emits the softmax residuals (per-row max
    and denominator, fp32) — what makes the prefix form trainable: the fused
    backward recomputes the joint probabilities from them."""
    n = pl.program_id(1)
    nb0 = nb0_ref[0, 0]
    out, m, denom = _attend_block(q_ref[0], kl_ref[0], vl_ref[0], ck_ref[0],
                                  cv_ref[0], n + nb0, scale, r)
    out_ref[0] = out.astype(out_ref.dtype)
    m_ref[0] = m[:, 0]
    denom_ref[0] = denom[:, 0]


def _prefix_kernel_q(q_ref, kl_ref, vl_ref, ck_ref, cv_ref, cks_ref, cvs_ref,
                     nb0_ref, out_ref, *, scale: float, r: int):
    """Quantized-cache variant of `_prefix_kernel`: the pinned compressed
    operand arrives int8/fp8 with per-slot fp32 scales and is dequantized IN
    VMEM before the shared `_attend_block` body; the chunk's own local K/V
    are activations and stay full precision. fp32 compute throughout (the
    dequantized prefix is fp32, and lax.dot_general needs matching operand
    dtypes)."""
    n = pl.program_id(1)
    nb0 = nb0_ref[0, 0]
    ck = ck_ref[0].astype(jnp.float32) * cks_ref[...][0][:, None]
    cv = cv_ref[0].astype(jnp.float32) * cvs_ref[...][0][:, None]
    out, _, _ = _attend_block(
        q_ref[0].astype(jnp.float32), kl_ref[0].astype(jnp.float32),
        vl_ref[0].astype(jnp.float32), ck, cv, n + nb0, scale, r)
    out_ref[0] = out.astype(out_ref.dtype)


def blockwise_causal_prefix_attn_q(
    q: jax.Array,        # (B, H, P, Dh) — one prefill chunk of queries
    k: jax.Array,        # (B, Hkv, P, Dh) — chunk keys (local, exact)
    v: jax.Array,
    comp_k: jax.Array,   # (B, Hkv, M, Dh) int8/fp8 page gather
    comp_v: jax.Array,
    comp_k_s: jax.Array,  # (B, Hkv, M) fp32 per-slot scales
    comp_v_s: jax.Array,
    start_blocks: jax.Array,   # (B,) int32 — per-row absolute start block
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    """Quantized-cache sibling of :func:`blockwise_causal_prefix_attn`: same
    grid and GQA routing, the pinned compressed operand stays in its storage
    dtype until the in-VMEM dequant. Forward-only — the paged cache is a
    serving structure, never differentiated through."""
    B, H, P, Dh = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    c = block_size
    assert P % c == 0, (P, c)
    nb = P // c
    M = comp_k.shape[2]
    q3 = q.reshape(B * H, P, Dh)
    k3 = k.reshape(B * Hkv, P, Dh)
    v3 = v.reshape(B * Hkv, P, Dh)
    ck3 = comp_k.reshape(B * Hkv, M, Dh)
    cv3 = comp_v.reshape(B * Hkv, M, Dh)
    cks = comp_k_s.astype(jnp.float32).reshape(B * Hkv, M)
    cvs = comp_v_s.astype(jnp.float32).reshape(B * Hkv, M)
    nb0 = jnp.asarray(start_blocks, jnp.int32).reshape(B, 1)

    def kv_row(bh):
        return (bh // H) * Hkv + (bh % H) // G

    out = pl.pallas_call(
        functools.partial(_prefix_kernel_q, scale=scale, r=block_slots),
        grid=(B * H, nb),
        in_specs=[
            pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
            pl.BlockSpec((1, M), lambda bh, n: (kv_row(bh), 0)),
            pl.BlockSpec((1, M), lambda bh, n: (kv_row(bh), 0)),
            pl.BlockSpec((1, 1), lambda bh, n: (bh // H, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, P, Dh), q.dtype),
        interpret=interpret,
    )(q3, k3, v3, ck3, cv3, cks, cvs, nb0)
    return out.reshape(B, H, P, Dh)


def blockwise_causal_prefix_attn(
    q: jax.Array,        # (B, H, P, Dh) — one prefill chunk of queries
    k: jax.Array,        # (B, Hkv, P, Dh) — chunk keys (local, exact)
    v: jax.Array,
    comp_k: jax.Array,   # (B, Hkv, M, Dh) — slot-resident compressed cache
    comp_v: jax.Array,   #                   (chunk's own blocks already folded)
    start_blocks: jax.Array,   # (B,) int32 — per-row absolute start block
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: bool = False,
    return_residuals: bool = False,
):
    """Blockwise-causal attention for a query chunk at a nonzero per-row
    start offset, against a full compressed slot buffer (the slot-resident
    cache during chunked prefill, or the all-gathered prefix under sequence
    parallelism).

    Same grid/GQA routing as :func:`blockwise_causal_attn`, but the pinned
    compressed operand is the FULL (M_total, Dh) slot buffer and the
    causality cut is shifted per row by `start_blocks` (passed as a (B, 1)
    int32 scalar block). M_total = (max_seq/c)·r must fit in VMEM — the same
    compression budget the decode kernel already pins. With
    ``return_residuals=True`` also emits the joint softmax's per-row
    (m, denom), each (B, H, P) fp32 — the residuals
    :func:`blockwise_causal_attn_bwd` consumes (with the same
    `start_blocks`) to run the fused backward of this offset form.
    """
    B, H, P, Dh = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    c = block_size
    assert P % c == 0, (P, c)
    nb = P // c
    M = comp_k.shape[2]
    q3 = q.reshape(B * H, P, Dh)
    k3 = k.reshape(B * Hkv, P, Dh)
    v3 = v.reshape(B * Hkv, P, Dh)
    ck3 = comp_k.reshape(B * Hkv, M, Dh)
    cv3 = comp_v.reshape(B * Hkv, M, Dh)
    nb0 = jnp.asarray(start_blocks, jnp.int32).reshape(B, 1)

    def kv_row(bh):
        return (bh // H) * Hkv + (bh % H) // G

    in_specs = [
        pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
        pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
        pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
        pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
        pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
        pl.BlockSpec((1, 1), lambda bh, n: (bh // H, 0)),
    ]
    if return_residuals:
        out, m, denom = pl.pallas_call(
            functools.partial(_prefix_kernel_res, scale=scale, r=block_slots),
            grid=(B * H, nb),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
                pl.BlockSpec((1, c), lambda bh, n: (bh, n)),
                pl.BlockSpec((1, c), lambda bh, n: (bh, n)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((B * H, P, Dh), q.dtype),
                jax.ShapeDtypeStruct((B * H, P), jnp.float32),
                jax.ShapeDtypeStruct((B * H, P), jnp.float32),
            ],
            interpret=interpret,
        )(q3, k3, v3, ck3, cv3, nb0)
        return (out.reshape(B, H, P, Dh), m.reshape(B, H, P),
                denom.reshape(B, H, P))
    out = pl.pallas_call(
        functools.partial(_prefix_kernel, scale=scale, r=block_slots),
        grid=(B * H, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, P, Dh), q.dtype),
        interpret=interpret,
    )(q3, k3, v3, ck3, cv3, nb0)
    return out.reshape(B, H, P, Dh)


def blockwise_causal_attn(
    q: jax.Array,       # (B, H, S, Dh)
    k: jax.Array,       # (B, Hkv, S, Dh) — native kv heads, H % Hkv == 0
    v: jax.Array,
    kbar: jax.Array,    # (B, Hkv, M, Dh)  compressed slots, M = (S/c)*r
    vbar: jax.Array,
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: bool = False,
    return_residuals: bool = False,
):
    """Fused blockwise-causal attention forward.

    With ``return_residuals=True`` also returns the joint softmax's per-row
    max `m` and denominator (each (B, H, S) fp32) — the residuals
    :func:`blockwise_causal_attn_bwd` recomputes the probabilities from.
    """
    B, H, S, Dh = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    c = block_size
    assert S % c == 0
    nb = S // c
    M = kbar.shape[2]
    assert M == nb * block_slots, (M, nb, block_slots)
    q3 = q.reshape(B * H, S, Dh)
    k3 = k.reshape(B * Hkv, S, Dh)
    v3 = v.reshape(B * Hkv, S, Dh)
    kb3 = kbar.reshape(B * Hkv, M, Dh)
    vb3 = vbar.reshape(B * Hkv, M, Dh)

    # grid row b·H + h reads kv row b·Hkv + h//G — the GQA group share
    # happens in the index map, never as a repeated HBM tensor.
    def kv_row(bh):
        return (bh // H) * Hkv + (bh % H) // G

    in_specs = [
        pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
        pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
        pl.BlockSpec((1, c, Dh), lambda bh, n: (kv_row(bh), n, 0)),
        pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
        pl.BlockSpec((1, M, Dh), lambda bh, n: (kv_row(bh), 0, 0)),
    ]
    if return_residuals:
        out, m, denom = pl.pallas_call(
            functools.partial(_kernel_res, scale=scale, r=block_slots),
            grid=(B * H, nb),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
                pl.BlockSpec((1, c), lambda bh, n: (bh, n)),
                pl.BlockSpec((1, c), lambda bh, n: (bh, n)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
                jax.ShapeDtypeStruct((B * H, S), jnp.float32),
                jax.ShapeDtypeStruct((B * H, S), jnp.float32),
            ],
            interpret=interpret,
        )(q3, k3, v3, kb3, vb3)
        return (out.reshape(B, H, S, Dh), m.reshape(B, H, S),
                denom.reshape(B, H, S))
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, r=block_slots),
        grid=(B * H, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, c, Dh), lambda bh, n: (bh, n, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
        interpret=interpret,
    )(q3, k3, v3, kb3, vb3)
    return out.reshape(B, H, S, Dh)


def _bwd_kernel(q_ref, kl_ref, vl_ref, kbar_ref, vbar_ref, m_ref, d_ref,
                do_ref, nb0_ref, dq_ref, dkl_ref, dvl_ref, dkb_ref, dvb_ref,
                dkl_acc, dvl_acc, dkb_acc, dvb_acc, *,
                scale: float, r: int, nb: int, G: int):
    """One grid step = one (kv head, query block, group member): recompute the
    joint probabilities from the saved (m, denom) residuals, then the five
    blockwise matmuls. Grid is (B·Hkv, nb, G) with the group axis INNERMOST,
    so every contributor to a kv-row accumulator runs on consecutive steps:
    dk_loc/dv_loc accumulate over the G group members of query block n, and
    dk̄/dv̄ over all nb·G steps of the kv row — fp32 scratch, emitted on each
    accumulator's last contributing step. nb0 shifts the visibility cut for
    the offset (prefix / sequence-parallel) form — zero in the offset-free
    training form; slots at or beyond the shifted cut recompute to P = 0 and
    contribute nothing, so the full-buffer accumulators stay exact."""
    n = pl.program_id(1)
    g = pl.program_id(2)
    nb0 = nb0_ref[0, 0]

    @pl.when(jnp.logical_and(n == 0, g == 0))
    def _init_glob():
        dkb_acc[...] = jnp.zeros_like(dkb_acc)
        dvb_acc[...] = jnp.zeros_like(dvb_acc)

    @pl.when(g == 0)
    def _init_loc():
        dkl_acc[...] = jnp.zeros_like(dkl_acc)
        dvl_acc[...] = jnp.zeros_like(dvl_acc)

    q = q_ref[0]                                     # (c, Dh)
    kl = kl_ref[0]
    kbar = kbar_ref[0]                               # (M, Dh)
    vl32 = vl_ref[0].astype(jnp.float32)
    vbar32 = vbar_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)               # (c, Dh)
    m = m_ref[...].reshape(-1, 1)                    # (c, 1) fp32
    denom = d_ref[...].reshape(-1, 1)

    # native-dtype score recompute — bit-identical to the forward's scores,
    # so p = exp(s − m)/denom reproduces the forward's exact probabilities
    s_loc, s_glob = _joint_scores(q, kl, kbar, n + nb0, scale, r)
    q32 = q.astype(jnp.float32)
    kl32 = kl.astype(jnp.float32)
    kbar32 = kbar.astype(jnp.float32)
    p_loc = jnp.exp(s_loc - m) / denom               # (c, c) joint probs
    p_glob = jnp.exp(s_glob - m) / denom             # (c, M)

    # dv = Pᵀ·do (masked entries have P = 0, so they contribute nothing)
    dvl_acc[...] += jax.lax.dot_general(
        p_loc, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (c, Dh)
    dvb_acc[...] += jax.lax.dot_general(
        p_glob, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (M, Dh)

    # dS = P ∘ (dP − rowsum(dP ∘ P)) over the JOINT row
    dp_loc = jax.lax.dot_general(
        do, vl32, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (c, c)
    dp_glob = jax.lax.dot_general(
        do, vbar32, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (c, M)
    delta = (jnp.sum(dp_loc * p_loc, -1, keepdims=True)
             + jnp.sum(dp_glob * p_glob, -1, keepdims=True))
    ds_loc = p_loc * (dp_loc - delta)
    ds_glob = p_glob * (dp_glob - delta)

    dq = jax.lax.dot_general(
        ds_loc, kl32, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dq += jax.lax.dot_general(
        ds_glob, kbar32, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dq_ref[0] = (dq * scale).astype(dq_ref.dtype)

    dkl_acc[...] += jax.lax.dot_general(
        ds_loc, q32, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (c, Dh)
    dkb_acc[...] += jax.lax.dot_general(
        ds_glob, q32, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (M, Dh)

    @pl.when(g == G - 1)
    def _emit_loc():
        dkl_ref[0] = dkl_acc[...]
        dvl_ref[0] = dvl_acc[...]

    @pl.when(jnp.logical_and(n == nb - 1, g == G - 1))
    def _emit_glob():
        dkb_ref[0] = dkb_acc[...]
        dvb_ref[0] = dvb_acc[...]


def blockwise_causal_attn_bwd(
    q: jax.Array,       # (B, H, S, Dh)
    k: jax.Array,       # (B, Hkv, S, Dh) — native kv heads
    v: jax.Array,
    kbar: jax.Array,    # (B, Hkv, M, Dh)  compressed slots, M = (S/c)*r
    vbar: jax.Array,
    m: jax.Array,       # (B, H, S) fp32 — forward's joint-softmax row max
    denom: jax.Array,   # (B, H, S) fp32 — forward's joint-softmax denominator
    do: jax.Array,      # (B, H, S, Dh) — output cotangent
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: bool = False,
    start_blocks: jax.Array = None,   # (B,) int32 — offset (prefix) form
):
    """Fused Pallas backward of :func:`blockwise_causal_attn` — and, with
    `start_blocks`, of :func:`blockwise_causal_prefix_attn`.

    Returns ``(dq, dk_loc, dv_loc, dkbar, dvbar)`` — dq in q's dtype,
    everything else fp32 (the accumulation dtype): dk_loc/dv_loc are the
    gradients through the LOCAL (own-block, exact) attention; dk̄/dv̄ are the
    compressed-slot gradients the caller chains through the linear
    `compress_blocks` VJP to reach dk/dv/dE/dF. No (S × nb·r) global score
    tensor ever hits HBM — scores live one query block at a time, exactly
    like the forward.

    With ``start_blocks`` (the offset form) the query chunk starts at
    per-row absolute block nb0[b] and kbar/vbar are a FULL slot buffer
    (M ≥ (nb0 + S/c)·r): dk̄/dv̄ cover the whole buffer, with exact zeros on
    slots this chunk's queries never see — under sequence parallelism those
    partial buffers are what the all-gather transpose psum-reduces across
    shards.
    """
    B, H, S, Dh = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    c = block_size
    assert S % c == 0
    nb = S // c
    M = kbar.shape[2]
    if start_blocks is None:
        assert M == nb * block_slots, (M, nb, block_slots)
        start_blocks = jnp.zeros((B,), jnp.int32)
    nb0 = jnp.asarray(start_blocks, jnp.int32).reshape(B, 1)
    q3 = q.reshape(B * H, S, Dh)
    k3 = k.reshape(B * Hkv, S, Dh)
    v3 = v.reshape(B * Hkv, S, Dh)
    kb3 = kbar.reshape(B * Hkv, M, Dh)
    vb3 = vbar.reshape(B * Hkv, M, Dh)
    m3 = m.reshape(B * H, S)
    d3 = denom.reshape(B * H, S)
    do3 = do.reshape(B * H, S, Dh)

    # kv row bkv, group member g ↔ query row (bkv//Hkv)·H + (bkv%Hkv)·G + g —
    # the forward's kv_row routing inverted (per-step index math, no HBM
    # repeat of K/V or the compressed slots).
    def q_row(bkv, g):
        return (bkv // Hkv) * H + (bkv % Hkv) * G + g

    dq, dkl, dvl, dkb, dvb = pl.pallas_call(
        functools.partial(_bwd_kernel, scale=scale, r=block_slots, nb=nb,
                          G=G),
        grid=(B * Hkv, nb, G),
        in_specs=[
            pl.BlockSpec((1, c, Dh), lambda bkv, n, g: (q_row(bkv, g), n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bkv, n, g: (bkv, n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bkv, n, g: (bkv, n, 0)),
            pl.BlockSpec((1, M, Dh), lambda bkv, n, g: (bkv, 0, 0)),
            pl.BlockSpec((1, M, Dh), lambda bkv, n, g: (bkv, 0, 0)),
            pl.BlockSpec((1, c), lambda bkv, n, g: (q_row(bkv, g), n)),
            pl.BlockSpec((1, c), lambda bkv, n, g: (q_row(bkv, g), n)),
            pl.BlockSpec((1, c, Dh), lambda bkv, n, g: (q_row(bkv, g), n, 0)),
            pl.BlockSpec((1, 1), lambda bkv, n, g: (bkv // Hkv, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, Dh), lambda bkv, n, g: (q_row(bkv, g), n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bkv, n, g: (bkv, n, 0)),
            pl.BlockSpec((1, c, Dh), lambda bkv, n, g: (bkv, n, 0)),
            pl.BlockSpec((1, M, Dh), lambda bkv, n, g: (bkv, 0, 0)),
            pl.BlockSpec((1, M, Dh), lambda bkv, n, g: (bkv, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
            jax.ShapeDtypeStruct((B * Hkv, S, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, S, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, M, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B * Hkv, M, Dh), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((c, Dh), jnp.float32),
            pltpu.VMEM((c, Dh), jnp.float32),
            pltpu.VMEM((M, Dh), jnp.float32),
            pltpu.VMEM((M, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3, kb3, vb3, m3, d3, do3, nb0)
    return (dq.reshape(B, H, S, Dh), dkl.reshape(B, Hkv, S, Dh),
            dvl.reshape(B, Hkv, S, Dh), dkb.reshape(B, Hkv, M, Dh),
            dvb.reshape(B, Hkv, M, Dh))
