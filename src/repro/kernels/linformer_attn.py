"""Fused Linformer attention Pallas kernel (TPU target).

Computes out = softmax(Q·K̄ᵀ/√d) · V̄ with K̄,V̄ the sequence-compressed
(k × Dh) keys/values.

TPU adaptation (DESIGN.md §3): because k ≤ 512, the ENTIRE compressed K̄/V̄
per head fits in VMEM (512×128 bf16 = 128 KiB), so the kernel pins them and
streams Q blocks — exact one-pass softmax with no flash-style online
renormalization. Score matmuls are (bq × Dh)·(Dh × k) and (bq × k)·(k × Dh):
both MXU-aligned when bq, Dh, k are multiples of 128 (the paper's k = 128/256
already are).

Grid: (B·H, S / bq). Block shapes:
  q    (1, bq, Dh)   — streamed per grid step
  k̄,v̄  (1, k,  Dh)   — pinned (same block for every s-step)
  out  (1, bq, Dh)

`decode_attn` is the single-token decode variant used by the
continuous-batching decode path: the raw ring-buffer block and the
compressed prefix slots stay TWO pinned operands (no per-step HBM
concatenate — the cache-residency contract), each with a per-row (B, ·)
additive validity bias (0 for attendable slots, NEG_INF otherwise — every
row sits at its own position); the softmax normalizes over their
concatenated scores inside the kernel.

The multi-token sibling — a prefill CHUNK at a nonzero per-row start
offset against the same slot-resident compressed cache (the serving
scheduler's chunked-admission path) — is
blockwise_causal_attn.blockwise_causal_prefix_attn, wrapped by
ops.fused_chunk_prefill_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_attend(q, kbar, vbar, scale):
    s = jax.lax.dot_general(
        q, kbar, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (bq, k)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jax.lax.dot_general(
        p.astype(vbar.dtype), vbar, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel(q_ref, kbar_ref, vbar_ref, out_ref, *, scale: float):
    out = _softmax_attend(q_ref[0], kbar_ref[0], vbar_ref[0], scale)
    out_ref[0] = out.astype(out_ref.dtype)


def linformer_attn(
    q: jax.Array,       # (B, H, S, Dh)
    kbar: jax.Array,    # (B, H, K, Dh)
    vbar: jax.Array,    # (B, H, K, Dh)
    *,
    scale: float,
    block_q: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, Dh = q.shape
    K = kbar.shape[2]
    bq = min(block_q, S)
    assert S % bq == 0, (S, bq)
    q3 = q.reshape(B * H, S, Dh)
    k3 = kbar.reshape(B * H, K, Dh)
    v3 = vbar.reshape(B * H, K, Dh)

    grid = (B * H, S // bq)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, K, Dh), lambda bh, s: (bh, 0, 0)),
            pl.BlockSpec((1, K, Dh), lambda bh, s: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda bh, s: (bh, s, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(B, H, S, Dh)


# ---------------------------------------------------------------------------
# Single-token decode kernel: [raw block | compressed prefix] as two pinned
# operands (cache residency — no per-step HBM concatenate)
# ---------------------------------------------------------------------------


def _attend_pinned(q, rk, rv, ck, cv, bl, bg, scale):
    """Array-level decode attend over the two pinned operands: one-pass
    softmax across the concatenated [raw block | compressed prefix] scores.
    Shared by the dense and the dequant-in-kernel quantized variants."""
    s_loc = jax.lax.dot_general(
        q, rk, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale + bl
    s_glob = jax.lax.dot_general(
        q, ck, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale + bg
    s = jnp.concatenate([s_loc, s_glob], axis=-1)            # (G, c + M)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    c = rk.shape[0]
    out = jax.lax.dot_general(
        p[:, :c].astype(rv.dtype), rv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out += jax.lax.dot_general(
        p[:, c:].astype(cv.dtype), cv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out


def _decode_kernel(q_ref, rk_ref, rv_ref, ck_ref, cv_ref, bl_ref, bg_ref,
                   out_ref, *, scale: float):
    out = _attend_pinned(q_ref[0], rk_ref[0], rv_ref[0], ck_ref[0],
                         cv_ref[0], bl_ref[...], bg_ref[...], scale)
    out_ref[0] = out.astype(out_ref.dtype)


def _decode_kernel_q(q_ref, rk_ref, rv_ref, ck_ref, cv_ref,
                     rks_ref, rvs_ref, cks_ref, cvs_ref,
                     bl_ref, bg_ref, out_ref, *, scale: float):
    """Quantized-cache decode kernel: operands arrive int8/fp8 with per-token
    (ring) / per-slot (pages) fp32 scales and are dequantized IN VMEM —
    HBM traffic for the two pinned caches shrinks with the storage dtype."""
    rk = rk_ref[0].astype(jnp.float32) * rks_ref[...][0][:, None]
    rv = rv_ref[0].astype(jnp.float32) * rvs_ref[...][0][:, None]
    ck = ck_ref[0].astype(jnp.float32) * cks_ref[...][0][:, None]
    cv = cv_ref[0].astype(jnp.float32) * cvs_ref[...][0][:, None]
    out = _attend_pinned(q_ref[0].astype(jnp.float32), rk, rv, ck, cv,
                         bl_ref[...], bg_ref[...], scale)
    out_ref[0] = out.astype(out_ref.dtype)


def decode_attn(
    q: jax.Array,        # (B, Hkv, G, Dh) — GQA group folded into the q axis
    raw_k: jax.Array,    # (B, Hkv, c, Dh) — raw ring buffer, pinned
    raw_v: jax.Array,
    comp_k: jax.Array,   # (B, Hkv, M, Dh) — compressed slots, pinned
    comp_v: jax.Array,
    bias_loc: jax.Array,   # (B, c) fp32: 0 attendable / NEG_INF masked
    bias_glob: jax.Array,  # (B, M) fp32
    *,
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, G, Dh = q.shape
    c, M = raw_k.shape[2], comp_k.shape[2]
    grid = (B * Hkv,)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, c), lambda bh: (bh // Hkv, 0)),
            pl.BlockSpec((1, M), lambda bh: (bh // Hkv, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, Dh), lambda bh: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(q.reshape(B * Hkv, G, Dh), raw_k.reshape(B * Hkv, c, Dh),
      raw_v.reshape(B * Hkv, c, Dh), comp_k.reshape(B * Hkv, M, Dh),
      comp_v.reshape(B * Hkv, M, Dh), bias_loc.astype(jnp.float32),
      bias_glob.astype(jnp.float32))
    return out.reshape(B, Hkv, G, Dh)


def decode_attn_q(
    q: jax.Array,        # (B, Hkv, G, Dh) — GQA group folded into the q axis
    raw_k: jax.Array,    # (B, Hkv, c, Dh) int8/fp8 ring, pinned
    raw_v: jax.Array,
    comp_k: jax.Array,   # (B, Hkv, M, Dh) int8/fp8 page gather, pinned
    comp_v: jax.Array,
    raw_k_s: jax.Array,  # (B, Hkv, c) fp32 per-token scales
    raw_v_s: jax.Array,
    comp_k_s: jax.Array,  # (B, Hkv, M) fp32 per-slot scales
    comp_v_s: jax.Array,
    bias_loc: jax.Array,   # (B, c) fp32: 0 attendable / NEG_INF masked
    bias_glob: jax.Array,  # (B, M) fp32
    *,
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    """Quantized-cache sibling of :func:`decode_attn`: identical grid and
    pinning, four extra per-(row, head) scale operands, dequantization
    in-kernel (VMEM) — HBM traffic for the two pinned caches shrinks with
    the storage dtype. Forward-only: serving decode never differentiates
    through the cache."""
    B, Hkv, G, Dh = q.shape
    c, M = raw_k.shape[2], comp_k.shape[2]
    grid = (B * Hkv,)
    kv3 = lambda x, n: x.reshape(B * Hkv, n, Dh)
    sc2 = lambda x, n: x.astype(jnp.float32).reshape(B * Hkv, n)
    out = pl.pallas_call(
        functools.partial(_decode_kernel_q, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, c, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, M, Dh), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, c), lambda bh: (bh, 0)),
            pl.BlockSpec((1, c), lambda bh: (bh, 0)),
            pl.BlockSpec((1, M), lambda bh: (bh, 0)),
            pl.BlockSpec((1, M), lambda bh: (bh, 0)),
            pl.BlockSpec((1, c), lambda bh: (bh // Hkv, 0)),
            pl.BlockSpec((1, M), lambda bh: (bh // Hkv, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, Dh), lambda bh: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(q.reshape(B * Hkv, G, Dh), kv3(raw_k, c), kv3(raw_v, c),
      kv3(comp_k, M), kv3(comp_v, M), sc2(raw_k_s, c), sc2(raw_v_s, c),
      sc2(comp_k_s, M), sc2(comp_v_s, M), bias_loc.astype(jnp.float32),
      bias_glob.astype(jnp.float32))
    return out.reshape(B, Hkv, G, Dh)
