"""Fused Linformer attention Pallas kernel (TPU target).

Computes out = softmax(Q·K̄ᵀ/√d) · V̄ with K̄,V̄ the sequence-compressed
(k × Dh) keys/values.

TPU adaptation (DESIGN.md §3): because k ≤ 512, the ENTIRE compressed K̄/V̄
per head fits in VMEM (512×128 bf16 = 128 KiB), so the kernel pins them and
streams Q blocks — exact one-pass softmax with no flash-style online
renormalization. Score matmuls are (bq × Dh)·(Dh × k) and (bq × k)·(k × Dh):
both MXU-aligned when bq, Dh, k are multiples of 128 (the paper's k = 128/256
already are).

Grid: (B·H, S / bq). Block shapes:
  q    (1, bq, Dh)   — streamed per grid step
  k̄,v̄  (1, k,  Dh)   — pinned (same block for every s-step)
  out  (1, bq, Dh)

An optional additive score `bias` (k,) supports slot-validity masking (0 for
attendable slots, NEG_INF otherwise) — used by the single-token decode path,
where the attendable prefix of [raw block | compressed slots] depends on the
current position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_attend(q, kbar, vbar, scale, bias=None):
    s = jax.lax.dot_general(
        q, kbar, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (bq, k)
    if bias is not None:
        s = s + bias
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jax.lax.dot_general(
        p.astype(vbar.dtype), vbar, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel(q_ref, kbar_ref, vbar_ref, out_ref, *, scale: float):
    out = _softmax_attend(q_ref[0], kbar_ref[0], vbar_ref[0], scale)
    out_ref[0] = out.astype(out_ref.dtype)


def _kernel_bias(q_ref, kbar_ref, vbar_ref, bias_ref, out_ref, *,
                 scale: float):
    out = _softmax_attend(q_ref[0], kbar_ref[0], vbar_ref[0], scale,
                          bias=bias_ref[...])                # bias (1, k)
    out_ref[0] = out.astype(out_ref.dtype)


def linformer_attn(
    q: jax.Array,       # (B, H, S, Dh)
    kbar: jax.Array,    # (B, H, K, Dh)
    vbar: jax.Array,    # (B, H, K, Dh)
    *,
    scale: float,
    block_q: int = 256,
    bias: "jax.Array | None" = None,  # optional (K,) additive score bias (fp32)
    interpret: bool = False,
) -> jax.Array:
    B, H, S, Dh = q.shape
    K = kbar.shape[2]
    bq = min(block_q, S)
    assert S % bq == 0, (S, bq)
    q3 = q.reshape(B * H, S, Dh)
    k3 = kbar.reshape(B * H, K, Dh)
    v3 = vbar.reshape(B * H, K, Dh)

    grid = (B * H, S // bq)
    in_specs = [
        pl.BlockSpec((1, bq, Dh), lambda bh, s: (bh, s, 0)),
        pl.BlockSpec((1, K, Dh), lambda bh, s: (bh, 0, 0)),
        pl.BlockSpec((1, K, Dh), lambda bh, s: (bh, 0, 0)),
    ]
    operands = [q3, k3, v3]
    kernel = functools.partial(_kernel, scale=scale)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, K), lambda bh, s: (0, 0)))
        operands.append(bias.astype(jnp.float32).reshape(1, K))
        kernel = functools.partial(_kernel_bias, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, Dh), lambda bh, s: (bh, s, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dh), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(B, H, S, Dh)
