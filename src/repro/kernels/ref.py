"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linformer_attn_ref(q: jax.Array, kbar: jax.Array, vbar: jax.Array,
                       scale: float) -> jax.Array:
    """softmax(q·k̄ᵀ·scale)·v̄.  q: (B,H,S,Dh); kbar/vbar: (B,H,K,Dh)."""
    s = jnp.einsum("bhsd,bhkd->bhsk", q, kbar).astype(jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhsk,bhkd->bhsd", p.astype(q.dtype), vbar)


def seq_projection_ref(x: jax.Array, E: jax.Array) -> jax.Array:
    """K̄ = EᵀK over the sequence axis. x: (B,H,S,Dh); E: (S,K) → (B,H,K,Dh).
    Accumulation in fp32 (matches the kernel's accumulator)."""
    out = jnp.einsum("bhsd,sk->bhkd", x.astype(jnp.float32),
                     E.astype(jnp.float32))
    return out.astype(x.dtype)


def blockwise_causal_ref(q, k, v, E, F, *, block_size, scale=None):
    """Oracle for the fused blockwise-causal kernel: thin wrapper around the
    core implementation with the kernel's (B,H,S,Dh) layout."""
    from repro.core.causal import blockwise_causal_attention
    to_core = lambda x: jnp.moveaxis(x, 1, 2)        # (B,H,S,D)->(B,S,H,D)
    out = blockwise_causal_attention(
        to_core(q), to_core(k), to_core(v), E, F,
        block_size=block_size, scale=scale)
    return jnp.moveaxis(out, 2, 1)
