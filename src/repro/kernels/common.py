"""Shared kernel-wrapper plumbing: layout moves, backend resolution, grid
sizing and the VMEM fail-fast budgets.

One home for the helpers both `kernels/ops.py` (the jit'd shard-local kernel
wrappers) and `parallel/plan.py` (the mesh-aware execution plan) consume —
previously private copies inside ops.py that the plan would have had to
duplicate. Everything here is shape/string logic with no Pallas dependency.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

BACKENDS = ("reference", "fused")
BACKWARD_IMPLS = ("fused", "reference")

# VMEM budgets for operands the kernels pin whole per grid step
# (docs/kernels.md "Known limits"). Exceeding them used to compile anyway and
# blow VMEM (or silently thrash) at runtime — now the wrappers fail fast.
MAX_EXACT_K = 512          # exact form: compressed length of k̄/v̄
MAX_PINNED_SLOTS = 4096    # causal/decode/chunk forms: M = (max_seq/c)·r

# Grids tile the sequence into blocks that must divide it evenly; blocks
# below this floor degrade the grid to near-per-row steps (S=509 prime would
# mean a 509-step grid per (batch, head) — pathological in interpret mode and
# a compile-size bomb on TPU), so `divisor_block` refuses them.
MIN_DIVISOR_BLOCK = 8

# Hand-picked perf defaults for the tunable grid knobs — the fallbacks the
# tuning table (repro/tune/table.py, committed TUNING.json) overrides per
# (platform, form, shape bucket). This module is the ONE place these
# literals live (repro-lint RL006): call sites take them from the table
# lookup or leave the kwarg unset.
DEFAULT_BLOCK_Q = 256        # fused_linformer_attention query tile
DEFAULT_BLOCK_S = 512        # fused_seq_projection sequence tile
DEFAULT_Q_CHUNK_BLOCKS = 8   # chunked reference causal form, query blocks


def auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def resolve_backend(backend: str = "auto") -> str:
    """Resolve an `AttentionConfig.backend` knob to a concrete backend.

    "auto" per platform: TPU -> fused (Mosaic-compiled); CPU -> fused in
    interpret mode (the kernel logic is the validated default path on this
    container); any other platform (e.g. GPU, which has no Mosaic lowering
    and where interpret mode would be pathologically slow) -> reference.
    """
    if backend in BACKENDS:
        return backend
    if backend != "auto":
        raise ValueError(
            f"unknown attention backend {backend!r}; "
            f"expected 'auto' or one of {BACKENDS}")
    return "fused" if jax.default_backend() in ("tpu", "cpu") else "reference"


def resolve_backward_impl(backward_impl: str) -> str:
    if backward_impl not in BACKWARD_IMPLS:
        raise ValueError(
            f"unknown backward_impl {backward_impl!r}; "
            f"expected one of {BACKWARD_IMPLS}")
    return backward_impl


def divisor_block(size: int, preferred: int) -> int:
    """Largest block ≤ preferred that divides `size` (kernels tile evenly).

    Fails fast instead of silently degrading: a sequence length whose largest
    usable divisor is tiny (prime/odd S) would otherwise quietly emit a
    degenerate near-per-row grid. A sub-floor block is only refused when it
    also means a blown-up grid (> MIN_DIVISOR_BLOCK steps) — tiny sequences
    that fit in a handful of blocks are fine."""
    b = max(1, min(preferred, size))
    while size % b:
        b -= 1
    if b < MIN_DIVISOR_BLOCK and size // b > MIN_DIVISOR_BLOCK:
        raise ValueError(
            f"sequence length {size} has no block divisor in "
            f"[{MIN_DIVISOR_BLOCK}, {preferred}] — the kernel grid would "
            f"degrade to {b}-row blocks ({size // b} grid steps per "
            f"(batch, head)). Pad or trim the sequence so it has a divisor "
            f"≥ {MIN_DIVISOR_BLOCK} (any multiple of {MIN_DIVISOR_BLOCK} "
            f"works), or use backend='reference' for this shape.")
    return b


def to_kernel_layout(x):         # (B,S,H,D) -> (B,H,S,D)
    return jnp.moveaxis(x, 2, 1)


def from_kernel_layout(x):
    return jnp.moveaxis(x, 1, 2)


def repeat_kv(x, H):             # (B,Hkv,K,D) -> (B,H,K,D)
    Hkv = x.shape[1]
    if Hkv == H:
        return x
    return jnp.repeat(x, H // Hkv, axis=1)
