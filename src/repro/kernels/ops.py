"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced Python, validating the exact TPU program logic. On TPU
they compile through Mosaic. `interpret=None` auto-detects.

Layout note: kernels use (B, H, S, Dh); the model uses (B, S, H, Dh). These
wrappers accept model layout and handle GQA head repetition for the
compressed operands (cheap: K is small).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import blockwise_causal_attn as bca
from repro.kernels import linformer_attn as la
from repro.kernels import ref
from repro.kernels import seq_projection as sp
from repro.core.causal import compress_blocks


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _to_kernel_layout(x):        # (B,S,H,D) -> (B,H,S,D)
    return jnp.moveaxis(x, 2, 1)


def _from_kernel_layout(x):
    return jnp.moveaxis(x, 1, 2)


def _repeat_kv(x, H):            # (B,Hkv,K,D) -> (B,H,K,D)
    Hkv = x.shape[1]
    if Hkv == H:
        return x
    return jnp.repeat(x, H // Hkv, axis=1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _linformer_attn_diff(q, kbar, vbar, scale, block_q, interpret):
    """Differentiable fused attention: Pallas forward, analytic backward.

    Backward derivation (per head; P = softmax(S), S = q·k̄ᵀ·scale,
    o = P·v̄):  dv̄ = Pᵀ·do;  dP = do·v̄ᵀ;  dS = P ∘ (dP − rowsum(dP∘P));
    dq = dS·k̄·scale;  dk̄ = dSᵀ·q·scale. The P recompute is one small
    (S × k) matmul — cheaper than storing it."""
    kb = _repeat_kv(kbar, q.shape[1])
    vb = _repeat_kv(vbar, q.shape[1])
    return la.linformer_attn(q, kb, vb, scale=scale, block_q=block_q,
                             interpret=interpret)


def _lin_fwd(q, kbar, vbar, scale, block_q, interpret):
    out = _linformer_attn_diff(q, kbar, vbar, scale, block_q, interpret)
    return out, (q, kbar, vbar)


def _lin_bwd(scale, block_q, interpret, res, do):
    q, kbar, vbar = res
    H, Hkv = q.shape[1], kbar.shape[1]
    G = H // Hkv
    kb = _repeat_kv(kbar, H)
    vb = _repeat_kv(vbar, H)
    s = jnp.einsum("bhsd,bhkd->bhsk", q, kb).astype(jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    do32 = do.astype(jnp.float32)
    dv = jnp.einsum("bhsk,bhsd->bhkd", p, do32)
    dp = jnp.einsum("bhsd,bhkd->bhsk", do32, vb.astype(jnp.float32))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhsk,bhkd->bhsd", ds, kb.astype(jnp.float32)) * scale
    dk = jnp.einsum("bhsk,bhsd->bhkd", ds, q.astype(jnp.float32)) * scale
    # fold the GQA head-repeat: sum grads over the query-group axis
    B, _, K, Dh = kbar.shape
    dk = dk.reshape(B, Hkv, G, K, Dh).sum(2)
    dv = dv.reshape(B, Hkv, G, K, Dh).sum(2)
    return (dq.astype(q.dtype), dk.astype(kbar.dtype), dv.astype(vbar.dtype))


_linformer_attn_diff.defvjp(_lin_fwd, _lin_bwd)


@functools.partial(jax.jit, static_argnames=("scale", "block_q", "interpret"))
def fused_linformer_attention(
    q: jax.Array,        # (B, S, H, Dh) model layout
    kbar: jax.Array,     # (B, K, Hkv, Dh)
    vbar: jax.Array,
    *,
    scale: float,
    block_q: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    qk = _to_kernel_layout(q)
    kb = _to_kernel_layout(kbar)
    vb = _to_kernel_layout(vbar)
    out = _linformer_attn_diff(qk, kb, vb, scale, block_q,
                               _auto_interpret(interpret))
    return _from_kernel_layout(out)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def fused_seq_projection(
    x: jax.Array,        # (B, S, H, Dh)
    E: jax.Array,        # (S, K)
    *,
    block_s: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    out = sp.seq_projection(_to_kernel_layout(x), E, block_s=block_s,
                            interpret=_auto_interpret(interpret))
    return _from_kernel_layout(out)        # (B, K, H, Dh)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "block_slots", "scale", "interpret"))
def fused_blockwise_causal_attention(
    q: jax.Array,        # (B, S, H, Dh)
    k: jax.Array,        # (B, S, Hkv, Dh)
    v: jax.Array,
    E: jax.Array,        # (c, r)
    F: jax.Array,
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    nb = S // block_size
    kbar = compress_blocks(k.reshape(B, nb, block_size, Hkv, Dh), E)
    vbar = compress_blocks(v.reshape(B, nb, block_size, Hkv, Dh), F)
    kbar = kbar.reshape(B, nb * block_slots, Hkv, Dh)
    vbar = vbar.reshape(B, nb * block_slots, Hkv, Dh)
    G = H // Hkv
    rep = lambda x: _repeat_kv(_to_kernel_layout(x), H)
    out = bca.blockwise_causal_attn(
        _to_kernel_layout(q), rep(k), rep(v), rep(kbar), rep(vbar),
        block_size=block_size, block_slots=block_slots, scale=scale,
        interpret=_auto_interpret(interpret))
    return _from_kernel_layout(out)
