"""Jit'd public wrappers for the Pallas kernels + attention-backend dispatch.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced Python, validating the exact TPU program logic. On TPU
they compile through Mosaic. `interpret=None` auto-detects.

Backend dispatch rules (`resolve_backend`, consumed by models/attention.py):

* ``backend="auto"`` (the `AttentionConfig` default) resolves to ``"fused"``
  on every platform: Mosaic-compiled on TPU, interpret-mode on CPU — the
  model forward, trainer, and serving engine therefore exercise the exact
  TPU program logic by default.
* ``"fused"`` / ``"reference"`` force the Pallas kernels or the pure-jnp
  einsum implementations respectively.
* Within the fused path, `fused_seq_projection` handles only the paper's
  shared linear E ∈ R^{S×K}; per-head (Hkv, S, K) or conv/pool projections
  fall back to the reference projection while the attention itself stays
  fused (models/attention.py applies this rule).

All fused ops are trainable END TO END in the fused path:
`fused_linformer_attention` carries an analytic custom VJP;
`fused_seq_projection` is linear (analytic VJP below);
`fused_blockwise_causal_attention` has a fused Pallas backward
(`bca.blockwise_causal_attn_bwd`): the forward saves the joint softmax's
per-row (m, denom) residuals, the backward recomputes the probabilities from
them and runs the five blockwise matmuls on the forward's grid, and dE/dF
chain through the linear `compress_blocks` VJP in plain jnp. The pre-existing
reference-recompute backward is kept behind ``backward_impl="reference"`` as
the parity/testing oracle (it re-runs the pure-jnp reference under jax.vjp —
same math, 2× the attention work and, below CHUNKED_ATTENTION_MIN_SEQ, a full
(B, H, S, nb·r) global score tensor in HBM).

Layout note: kernels use (B, H, S, Dh); the model uses (B, S, H, Dh). These
wrappers accept model layout and handle GQA head repetition for the
compressed operands (cheap: K is small). The single-token decode wrapper
`fused_decode_attention` instead folds the GQA group axis into the kernel's
query-sequence axis, so K/V are never repeated; the blockwise-causal
wrappers route grouped query heads to their kv row via the grid index maps.

Every wrapper here is SHARD-LOCAL: shapes are whatever one device holds, and
the fail-fast checks below validate those local shapes. Whether a wrapper is
called on full arrays (single device) or per-shard inside a `shard_map`
manual region is decided in exactly one place — the mesh-aware
`parallel/plan.py` AttentionPlan — never here and never at call sites.

Known limits (docs/kernels.md has the full list): `fused_decode_attention`
is inference-only (no VJP); pinned compressed operands must fit VMEM —
fail-fast enforced here: K ≤ MAX_EXACT_K for the exact form,
M = (max_seq/c)·r ≤ MAX_PINNED_SLOTS for the causal/decode/chunk forms;
blockwise-causal forms need S % block_size == 0 (serving routes the
remainder through the decode path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import blockwise_causal_attn as bca
from repro.kernels import linformer_attn as la
from repro.kernels import ref
from repro.kernels import seq_projection as sp
from repro.kernels.common import (BACKENDS, BACKWARD_IMPLS, DEFAULT_BLOCK_Q,
                                  DEFAULT_BLOCK_S, MAX_EXACT_K,
                                  MAX_PINNED_SLOTS, MIN_DIVISOR_BLOCK,
                                  auto_interpret as _auto_interpret,
                                  divisor_block as _divisor_block,
                                  from_kernel_layout as _from_kernel_layout,
                                  repeat_kv as _repeat_kv,
                                  resolve_backend,
                                  to_kernel_layout as _to_kernel_layout)
from repro.core.causal import (CHUNKED_ATTENTION_MIN_SEQ,
                               blockwise_causal_attention,
                               blockwise_causal_attention_chunked,
                               blockwise_causal_prefix_attention,
                               chunked_attention_min_seq,
                               compress_blocks)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _linformer_attn_diff(q, kbar, vbar, scale, block_q, interpret):
    """Differentiable fused attention: Pallas forward, analytic backward.

    Backward derivation (per head; P = softmax(S), S = q·k̄ᵀ·scale,
    o = P·v̄):  dv̄ = Pᵀ·do;  dP = do·v̄ᵀ;  dS = P ∘ (dP − rowsum(dP∘P));
    dq = dS·k̄·scale;  dk̄ = dSᵀ·q·scale. The P recompute is one small
    (S × k) matmul — cheaper than storing it."""
    kb = _repeat_kv(kbar, q.shape[1])
    vb = _repeat_kv(vbar, q.shape[1])
    return la.linformer_attn(q, kb, vb, scale=scale, block_q=block_q,
                             interpret=interpret)


def _lin_fwd(q, kbar, vbar, scale, block_q, interpret):
    out = _linformer_attn_diff(q, kbar, vbar, scale, block_q, interpret)
    return out, (q, kbar, vbar)


def _lin_bwd(scale, block_q, interpret, res, do):
    q, kbar, vbar = res
    H, Hkv = q.shape[1], kbar.shape[1]
    G = H // Hkv
    kb = _repeat_kv(kbar, H)
    vb = _repeat_kv(vbar, H)
    s = jnp.einsum("bhsd,bhkd->bhsk", q, kb).astype(jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    do32 = do.astype(jnp.float32)
    dv = jnp.einsum("bhsk,bhsd->bhkd", p, do32)
    dp = jnp.einsum("bhsd,bhkd->bhsk", do32, vb.astype(jnp.float32))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhsk,bhkd->bhsd", ds, kb.astype(jnp.float32)) * scale
    dk = jnp.einsum("bhsk,bhsd->bhkd", ds, q.astype(jnp.float32)) * scale
    # fold the GQA head-repeat: sum grads over the query-group axis
    B, _, K, Dh = kbar.shape
    dk = dk.reshape(B, Hkv, G, K, Dh).sum(2)
    dv = dv.reshape(B, Hkv, G, K, Dh).sum(2)
    return (dq.astype(q.dtype), dk.astype(kbar.dtype), dv.astype(vbar.dtype))


_linformer_attn_diff.defvjp(_lin_fwd, _lin_bwd)


@functools.partial(jax.jit, static_argnames=("scale", "block_q", "interpret"))
def fused_linformer_attention(
    q: jax.Array,        # (B, S, H, Dh) model layout
    kbar: jax.Array,     # (B, K, Hkv, Dh)
    vbar: jax.Array,
    *,
    scale: float,
    block_q: int = DEFAULT_BLOCK_Q,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Exact (bidirectional) Linformer attention through the Pallas kernel:
    softmax(q·k̄ᵀ·scale)·v̄ over the K compressed slots.

    Shapes/dtypes: model layout — q (B, S, H, Dh); kbar/vbar (B, K, Hkv,
    Dh) with K ≤ MAX_EXACT_K so the whole compressed operand pins in VMEM
    (scores fp32, output in q's dtype). GQA kv heads are repeated to H for
    the compressed operands (cheap: K is small). Trainable — analytic custom
    VJP (`_lin_bwd`); `block_q` shrinks to the largest divisor of S.
    `block_q` partitions the independent query rows only — output is
    bit-identical across values; the plan layer passes the tuned value
    (repro/tune/table.py)."""
    K = kbar.shape[1]
    if K > MAX_EXACT_K:
        raise ValueError(
            f"fused_linformer_attention pins the whole compressed k̄/v̄ in "
            f"VMEM, which requires K ≤ {MAX_EXACT_K}; got K={K}. Lower the "
            f"Linformer projected dimension (the paper uses 128–256) or "
            f"use backend='reference' for this shape.")
    qk = _to_kernel_layout(q)
    kb = _to_kernel_layout(kbar)
    vb = _to_kernel_layout(vbar)
    out = _linformer_attn_diff(qk, kb, vb, scale,
                               _divisor_block(q.shape[1], block_q),
                               _auto_interpret(interpret))
    return _from_kernel_layout(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _seq_projection_diff(xk, E, block_s, interpret):
    """Differentiable fused projection (kernel layout). The op is linear:
    out = Eᵀ·x, so dx = E·dout and dE = Σ_{b,h} x·doutᵀ."""
    return sp.seq_projection(xk, E, block_s=block_s, interpret=interpret)


def _sp_fwd(xk, E, block_s, interpret):
    return _seq_projection_diff(xk, E, block_s, interpret), (xk, E)


def _sp_bwd(block_s, interpret, res, do):
    xk, E = res
    do32 = do.astype(jnp.float32)
    dx = jnp.einsum("bhkd,sk->bhsd", do32, E.astype(jnp.float32))
    dE = jnp.einsum("bhsd,bhkd->sk", xk.astype(jnp.float32), do32)
    return dx.astype(xk.dtype), dE.astype(E.dtype)


_seq_projection_diff.defvjp(_sp_fwd, _sp_bwd)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def fused_seq_projection(
    x: jax.Array,        # (B, S, H, Dh)
    E: jax.Array,        # (S, K)
    *,
    block_s: int = DEFAULT_BLOCK_S,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused sequence-axis projection out = Eᵀ·x: (B, S, H, Dh) × (S, K)
    → (B, K, H, Dh) — the paper's shared linear compression of K/V.
    Handles ONLY the shared 2-D E (per-head / conv / pool projections go
    through the reference ops; models/attention.py applies this rule).
    Linear, so trainable with an analytic VJP. `block_s` tiles the
    reduction's sequence axis (a perf knob; it regroups the fp32
    accumulation, so last-ulp output differences across values are
    possible); the plan layer passes the tuned value."""
    out = _seq_projection_diff(_to_kernel_layout(x), E,
                               _divisor_block(x.shape[1], block_s),
                               _auto_interpret(interpret))
    return _from_kernel_layout(out)        # (B, K, H, Dh)


def _compress_kv(x, W, block_size, block_slots):
    """(B, S, Hkv, Dh) × E/F → (B, nb·r, Hkv, Dh) compressed slots."""
    B, S, Hkv, Dh = x.shape
    nb = S // block_size
    xbar = compress_blocks(x.reshape(B, nb, block_size, Hkv, Dh), W)
    return xbar.reshape(B, nb * block_slots, Hkv, Dh)


def _blockwise_causal_fused(q, k, v, E, F, block_size, block_slots, scale,
                            interpret, return_residuals=False):
    kbar = _compress_kv(k, E, block_size, block_slots)
    vbar = _compress_kv(v, F, block_size, block_slots)
    # K/V keep their native Hkv heads: the kernel's index maps route each
    # grouped query head to its kv row (no G-fold jnp.repeat in HBM).
    out = bca.blockwise_causal_attn(
        _to_kernel_layout(q), _to_kernel_layout(k), _to_kernel_layout(v),
        _to_kernel_layout(kbar), _to_kernel_layout(vbar),
        block_size=block_size, block_slots=block_slots, scale=scale,
        interpret=interpret, return_residuals=return_residuals)
    if return_residuals:
        out, m, denom = out
        return _from_kernel_layout(out), kbar, vbar, m, denom
    return _from_kernel_layout(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _blockwise_causal_diff(q, k, v, E, F, block_size, block_slots, scale,
                           interpret, backward_impl):
    """Differentiable fused blockwise-causal attention: Pallas forward AND
    (by default) Pallas backward. The forward saves the joint softmax's
    per-row (m, denom) residuals; `_bca_bwd` recomputes the probabilities
    from them inside `bca.blockwise_causal_attn_bwd` and chains dE/dF
    through the linear `compress_blocks` VJP. ``backward_impl="reference"``
    keeps the old reference-recompute backward as the parity oracle."""
    return _blockwise_causal_fused(q, k, v, E, F, block_size, block_slots,
                                   scale, interpret)


def _bca_fwd(q, k, v, E, F, block_size, block_slots, scale, interpret,
             backward_impl):
    # repro-lint: allow[RL001] impl already resolved by the plan layer
    if backward_impl == "reference":
        out = _blockwise_causal_fused(q, k, v, E, F, block_size, block_slots,
                                      scale, interpret)
        return out, (q, k, v, E, F)
    out, kbar, vbar, m, denom = _blockwise_causal_fused(
        q, k, v, E, F, block_size, block_slots, scale, interpret,
        return_residuals=True)
    return out, (q, k, v, E, F, kbar, vbar, m, denom)


def _bca_bwd_reference(block_size, block_slots, scale, res, do):
    """Reference-recompute backward (parity oracle): jax.vjp over the
    pure-jnp reference — identical math, but a second unfused attention
    pass, switching to the memory-bounded chunked form at long S (the plain
    form materializes the full (…, S, nb·r) global score tensor, which the
    fused path exists to avoid)."""
    q, k, v, E, F = res
    ref_fn = (blockwise_causal_attention_chunked
              if q.shape[1] >= chunked_attention_min_seq()
              else blockwise_causal_attention)
    _, vjp = jax.vjp(
        lambda q_, k_, v_, E_, F_: ref_fn(
            q_, k_, v_, E_, F_, block_size=block_size, scale=scale),
        q, k, v, E, F)
    return vjp(do)


def _bca_bwd(block_size, block_slots, scale, interpret, backward_impl, res,
             do):
    # repro-lint: allow[RL001] impl already resolved by the plan layer
    if backward_impl == "reference":
        return _bca_bwd_reference(block_size, block_slots, scale, res, do)
    q, k, v, E, F, kbar, vbar, m, denom = res
    dq_k, dkl_k, dvl_k, dkb_k, dvb_k = bca.blockwise_causal_attn_bwd(
        _to_kernel_layout(q), _to_kernel_layout(k), _to_kernel_layout(v),
        _to_kernel_layout(kbar), _to_kernel_layout(vbar), m, denom,
        _to_kernel_layout(do), block_size=block_size,
        block_slots=block_slots, scale=scale, interpret=interpret)
    dq = _from_kernel_layout(dq_k)
    dk_loc = _from_kernel_layout(dkl_k)          # (B, S, Hkv, Dh) fp32
    dv_loc = _from_kernel_layout(dvl_k)
    dkbar = _from_kernel_layout(dkb_k)           # (B, nb·r, Hkv, Dh) fp32
    dvbar = _from_kernel_layout(dvb_k)
    # dk̄/dv̄ → (dk, dE) / (dv, dF) through the linear compress_blocks VJP
    # (plain jnp — the compression is a small per-block matmul).
    _, vjp_k = jax.vjp(
        lambda k_, E_: _compress_kv(k_, E_, block_size, block_slots), k, E)
    dk_comp, dE = vjp_k(dkbar.astype(kbar.dtype))
    _, vjp_v = jax.vjp(
        lambda v_, F_: _compress_kv(v_, F_, block_size, block_slots), v, F)
    dv_comp, dF = vjp_v(dvbar.astype(vbar.dtype))
    dk = (dk_loc + dk_comp.astype(jnp.float32)).astype(k.dtype)
    dv = (dv_loc + dv_comp.astype(jnp.float32)).astype(v.dtype)
    return dq, dk, dv, dE, dF


_blockwise_causal_diff.defvjp(_bca_fwd, _bca_bwd)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "block_slots", "scale", "interpret", "backward_impl"))
def fused_blockwise_causal_attention(
    q: jax.Array,        # (B, S, H, Dh)
    k: jax.Array,        # (B, S, Hkv, Dh)
    v: jax.Array,
    E: jax.Array,        # (c, r) or (Hkv, c, r)
    F: jax.Array,
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: Optional[bool] = None,
    backward_impl: str = "fused",
) -> jax.Array:
    """Causal training/prefill attention through the Pallas kernels.

    Trainable end to end: `backward_impl="fused"` (default) runs the Pallas
    backward from saved (m, denom) residuals; `"reference"` recomputes
    through the pure-jnp reference VJP (the parity/testing oracle)."""
    if backward_impl not in BACKWARD_IMPLS:
        raise ValueError(
            f"unknown backward_impl {backward_impl!r}; "
            f"expected one of {BACKWARD_IMPLS}")
    S = q.shape[1]
    if S % block_size != 0:
        raise ValueError(
            f"S={S} must be a multiple of block_size={block_size}")
    M = (S // block_size) * block_slots
    if M > MAX_PINNED_SLOTS:
        raise ValueError(
            f"fused_blockwise_causal_attention pins all M = (S/c)·r "
            f"= ({S}/{block_size})·{block_slots} = {M} compressed slots in "
            f"VMEM per grid step, which requires M ≤ {MAX_PINNED_SLOTS}. "
            f"Raise block_size, lower block_slots, or use "
            f"backend='reference' for this shape.")
    return _blockwise_causal_diff(q, k, v, E, F, block_size, block_slots,
                                  scale, _auto_interpret(interpret),
                                  backward_impl)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def _chunk_prefill_diff(q, k, v, comp_k, comp_v, nb0f, block_size,
                        block_slots, scale, interpret, backward_impl):
    """Differentiable prefix-form attention. The per-row start block rides
    as an fp32 array (`nb0f`) purely so custom_vjp has an ordinary zero
    cotangent to return for it — it is cast back to int32 before the kernel
    sees it (the offset itself is of course not differentiable)."""
    out = bca.blockwise_causal_prefix_attn(
        _to_kernel_layout(q), _to_kernel_layout(k), _to_kernel_layout(v),
        _to_kernel_layout(comp_k), _to_kernel_layout(comp_v),
        nb0f.astype(jnp.int32), block_size=block_size,
        block_slots=block_slots, scale=scale, interpret=interpret)
    return _from_kernel_layout(out)


def _cp_fwd(q, k, v, comp_k, comp_v, nb0f, block_size, block_slots, scale,
            interpret, backward_impl):
    # repro-lint: allow[RL001] impl already resolved by the plan layer
    if backward_impl == "reference":
        out = _chunk_prefill_diff(q, k, v, comp_k, comp_v, nb0f, block_size,
                                  block_slots, scale, interpret,
                                  backward_impl)
        return out, (q, k, v, comp_k, comp_v, nb0f, None, None)
    out, m, denom = bca.blockwise_causal_prefix_attn(
        _to_kernel_layout(q), _to_kernel_layout(k), _to_kernel_layout(v),
        _to_kernel_layout(comp_k), _to_kernel_layout(comp_v),
        nb0f.astype(jnp.int32), block_size=block_size,
        block_slots=block_slots, scale=scale, interpret=interpret,
        return_residuals=True)
    return (_from_kernel_layout(out),
            (q, k, v, comp_k, comp_v, nb0f, m, denom))


def _cp_bwd(block_size, block_slots, scale, interpret, backward_impl, res,
            do):
    q, k, v, comp_k, comp_v, nb0f, m, denom = res
    nb0 = nb0f.astype(jnp.int32)
    # repro-lint: allow[RL001] impl already resolved by the plan layer
    if backward_impl == "reference":
        _, vjp = jax.vjp(
            lambda q_, k_, v_, ck_, cv_: blockwise_causal_prefix_attention(
                q_, k_, v_, ck_, cv_, nb0, block_size=block_size,
                block_slots=block_slots, scale=scale),
            q, k, v, comp_k, comp_v)
        dq, dk, dv, dck, dcv = vjp(do)
        return dq, dk, dv, dck, dcv, jnp.zeros_like(nb0f)
    dq_k, dkl_k, dvl_k, dck_k, dcv_k = bca.blockwise_causal_attn_bwd(
        _to_kernel_layout(q), _to_kernel_layout(k), _to_kernel_layout(v),
        _to_kernel_layout(comp_k), _to_kernel_layout(comp_v), m, denom,
        _to_kernel_layout(do), block_size=block_size,
        block_slots=block_slots, scale=scale, interpret=interpret,
        start_blocks=nb0)
    # comp_k/comp_v are independent primal inputs here (a cache buffer, or
    # the gathered sequence-parallel prefix): their cotangent is the raw
    # full-buffer dk̄/dv̄ — exact zeros on slots this chunk never sees —
    # and any chaining back into k/v (compress_blocks, all-gather) belongs
    # to the caller's autodiff.
    return (_from_kernel_layout(dq_k),
            _from_kernel_layout(dkl_k).astype(k.dtype),
            _from_kernel_layout(dvl_k).astype(v.dtype),
            _from_kernel_layout(dck_k).astype(comp_k.dtype),
            _from_kernel_layout(dcv_k).astype(comp_v.dtype),
            jnp.zeros_like(nb0f))


_chunk_prefill_diff.defvjp(_cp_fwd, _cp_bwd)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "block_slots", "scale", "interpret", "backward_impl"))
def fused_chunk_prefill_attention(
    q: jax.Array,        # (B, P, H, Dh) — one query chunk, model layout
    k: jax.Array,        # (B, P, Hkv, Dh) — the chunk's own keys
    v: jax.Array,
    comp_k: jax.Array,   # (B, M, Hkv, Dh) — full compressed slot buffer
    comp_v: jax.Array,   #   with the chunk's own blocks already folded in
    start_blocks: jax.Array,   # (B,) int — per-row absolute start block
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: Optional[bool] = None,
    backward_impl: str = "fused",
) -> jax.Array:
    """Blockwise-causal attention for a query chunk starting at a nonzero
    per-row offset — the chunked-admission prefill path, and (per-shard,
    with the gathered compressed prefix as the slot buffer) the
    sequence-parallel training form that `parallel/plan.py` runs inside
    shard_map.

    Shapes/dtypes: model layout in and out — q (B, P, H, Dh) with
    P % block_size == 0; k/v carry native Hkv GQA heads (index-map routing,
    no HBM repeat); comp_k/comp_v are FULL slot buffers (the cache's
    M = (max_seq/block_size)·block_slots rows, or the gathered (S/c)·r
    prefix), pinned per grid step like the decode kernel's compressed
    operand. Row b's query block j attends [its own block, causally |
    compressed slots of absolute blocks < start_blocks[b] + j] —
    `start_blocks` is traced (one compile serves every offset), which is
    what makes fixed-size chunk compiles reusable across a prompt and
    across rows of a batched admission round.

    Trainable end to end since PR 5: `backward_impl="fused"` (default) runs
    the offset-aware Pallas backward from saved (m, denom) residuals;
    `"reference"` recomputes through the pure-jnp prefix reference VJP (the
    parity oracle). Gradients flow to q/k/v AND to comp_k/comp_v (the
    full-buffer dk̄/dv̄, exact zeros on invisible slots) — sequence
    parallelism chains the latter through the all-gather transpose.
    """
    if backward_impl not in BACKWARD_IMPLS:
        raise ValueError(
            f"unknown backward_impl {backward_impl!r}; "
            f"expected one of {BACKWARD_IMPLS}")
    if q.shape[1] % block_size != 0:
        raise ValueError(
            f"P={q.shape[1]} must be a multiple of block_size={block_size}")
    M = comp_k.shape[1]
    if M > MAX_PINNED_SLOTS:
        raise ValueError(
            f"fused_chunk_prefill_attention pins the full M = "
            f"(max_seq/c)·r = {M}-slot compressed cache buffer in VMEM per "
            f"grid step, which requires M ≤ {MAX_PINNED_SLOTS}. Raise "
            f"block_size, lower block_slots or max_seq, or use "
            f"backend='reference' for this cache shape.")
    nb0f = jnp.asarray(start_blocks).astype(jnp.float32)
    return _chunk_prefill_diff(q, k, v, comp_k, comp_v, nb0f, block_size,
                               block_slots, scale,
                               _auto_interpret(interpret), backward_impl)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def fused_decode_attention(
    q_t: jax.Array,        # (B, 1, H, Dh) — one decode token per row
    raw_k: jax.Array,      # (B, c, Hkv, Dh) — raw ring buffer (resident)
    raw_v: jax.Array,
    comp_k: jax.Array,     # (B, M, Hkv, Dh) — compressed slots (resident)
    comp_v: jax.Array,
    bias_loc: jax.Array,   # (B, c) fp32 — 0 attendable, NEG_INF masked
    bias_glob: jax.Array,  # (B, M) fp32
    *,
    scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Single-token GQA decode attention through the fused kernel.

    Instead of repeating K/V to the query head count, the GQA group axis is
    folded into the kernel's query-sequence axis: q (B, 1, Hkv·G, Dh) is
    viewed as (B, Hkv, G, Dh) — G queries per kv head, all sharing that
    head's raw + compressed slots. The raw block and the compressed prefix
    stay TWO pinned kernel operands (cache residency: no per-step HBM
    concatenate of the caches), each with a PER-ROW additive validity bias
    (the raw ring prefix ≤ pos[b] and the blk[b]·r completed slots), so one
    kernel handles every per-row (pos, blk) combination — the contract the
    continuous-batching scheduler relies on.
    """
    B, _, H, Dh = q_t.shape
    Hkv = raw_k.shape[2]
    G = H // Hkv
    M = comp_k.shape[1]
    if M > MAX_PINNED_SLOTS:
        raise ValueError(
            f"fused_decode_attention pins the full M = (max_seq/c)·r = "
            f"{M}-slot compressed cache buffer in VMEM, which requires "
            f"M ≤ {MAX_PINNED_SLOTS}. Raise block_size, lower block_slots "
            f"or max_seq, or use backend='reference' for this cache shape.")
    qk = q_t.reshape(B, Hkv, G, Dh)             # kernel layout: S-axis = G
    out = la.decode_attn(
        qk, _to_kernel_layout(raw_k), _to_kernel_layout(raw_v),
        _to_kernel_layout(comp_k), _to_kernel_layout(comp_v),
        bias_loc, bias_glob, scale=scale,
        interpret=_auto_interpret(interpret))
    return out.reshape(B, 1, H, Dh)


def _scales_to_kernel_layout(s: jax.Array) -> jax.Array:
    """(B, N, Hkv) per-token/per-slot scales → kernel layout (B, Hkv, N)."""
    return jnp.transpose(s, (0, 2, 1))


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def fused_decode_attention_q(
    q_t: jax.Array,        # (B, 1, H, Dh) — one decode token per row
    raw_k: jax.Array,      # (B, c, Hkv, Dh) int8/fp8 quantized ring
    raw_v: jax.Array,
    raw_k_s: jax.Array,    # (B, c, Hkv) fp32 per-token per-head scales
    raw_v_s: jax.Array,
    comp_k: jax.Array,     # (B, M, Hkv, Dh) int8/fp8 page-gathered slots
    comp_v: jax.Array,
    comp_k_s: jax.Array,   # (B, M, Hkv) fp32 per-slot per-head scales
    comp_v_s: jax.Array,
    bias_loc: jax.Array,   # (B, c) fp32 — 0 attendable, NEG_INF masked
    bias_glob: jax.Array,  # (B, M) fp32
    *,
    scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Quantized-cache sibling of :func:`fused_decode_attention`: same GQA
    group fold and two-pinned-operand cache residency, with the ring and the
    page-gathered compressed slots arriving in their storage dtype plus
    per-(row, head) fp32 scales, dequantized inside the kernel (VMEM) — the
    HBM read of both pinned caches shrinks with the storage dtype.
    Forward-only, like the dense decode wrapper (inference path)."""
    B, _, H, Dh = q_t.shape
    Hkv = raw_k.shape[2]
    G = H // Hkv
    M = comp_k.shape[1]
    if M > MAX_PINNED_SLOTS:
        raise ValueError(
            f"fused_decode_attention_q pins the full M = (max_pages·r) = "
            f"{M}-slot page gather in VMEM, which requires "
            f"M ≤ {MAX_PINNED_SLOTS}. Raise block_size, lower block_slots "
            f"or max_seq, or use backend='reference' for this cache shape.")
    qk = q_t.reshape(B, Hkv, G, Dh)             # kernel layout: S-axis = G
    out = la.decode_attn_q(
        qk, _to_kernel_layout(raw_k), _to_kernel_layout(raw_v),
        _to_kernel_layout(comp_k), _to_kernel_layout(comp_v),
        _scales_to_kernel_layout(raw_k_s), _scales_to_kernel_layout(raw_v_s),
        _scales_to_kernel_layout(comp_k_s),
        _scales_to_kernel_layout(comp_v_s),
        bias_loc, bias_glob, scale=scale,
        interpret=_auto_interpret(interpret))
    return out.reshape(B, 1, H, Dh)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "block_slots", "scale", "interpret"))
def fused_chunk_prefill_attention_q(
    q: jax.Array,        # (B, P, H, Dh) — one query chunk, model layout
    k: jax.Array,        # (B, P, Hkv, Dh) — the chunk's own keys (exact)
    v: jax.Array,
    comp_k: jax.Array,   # (B, M, Hkv, Dh) int8/fp8 page-gathered slot buffer
    comp_v: jax.Array,
    comp_k_s: jax.Array,  # (B, M, Hkv) fp32 per-slot per-head scales
    comp_v_s: jax.Array,
    start_blocks: jax.Array,   # (B,) int — per-row absolute start block
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Quantized-cache sibling of :func:`fused_chunk_prefill_attention`: the
    pinned compressed operand is the page-gathered quantized slot buffer
    plus per-slot scales, dequantized inside the kernel; the chunk's own
    local K/V are activations and stay full precision. Forward-only — the
    paged cache is a serving structure, never differentiated through."""
    if q.shape[1] % block_size != 0:
        raise ValueError(
            f"P={q.shape[1]} must be a multiple of block_size={block_size}")
    M = comp_k.shape[1]
    if M > MAX_PINNED_SLOTS:
        raise ValueError(
            f"fused_chunk_prefill_attention_q pins the full M = "
            f"(max_pages·r) = {M}-slot page gather in VMEM per grid step, "
            f"which requires M ≤ {MAX_PINNED_SLOTS}. Raise block_size, "
            f"lower block_slots or max_seq, or use backend='reference' for "
            f"this cache shape.")
    out = bca.blockwise_causal_prefix_attn_q(
        _to_kernel_layout(q), _to_kernel_layout(k), _to_kernel_layout(v),
        _to_kernel_layout(comp_k), _to_kernel_layout(comp_v),
        _scales_to_kernel_layout(comp_k_s),
        _scales_to_kernel_layout(comp_v_s),
        jnp.asarray(start_blocks, jnp.int32), block_size=block_size,
        block_slots=block_slots, scale=scale,
        interpret=_auto_interpret(interpret))
    return _from_kernel_layout(out)
