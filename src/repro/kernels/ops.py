"""Jit'd public wrappers for the Pallas kernels + attention-backend dispatch.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced Python, validating the exact TPU program logic. On TPU
they compile through Mosaic. `interpret=None` auto-detects.

Backend dispatch rules (`resolve_backend`, consumed by models/attention.py):

* ``backend="auto"`` (the `AttentionConfig` default) resolves to ``"fused"``
  on every platform: Mosaic-compiled on TPU, interpret-mode on CPU — the
  model forward, trainer, and serving engine therefore exercise the exact
  TPU program logic by default.
* ``"fused"`` / ``"reference"`` force the Pallas kernels or the pure-jnp
  einsum implementations respectively.
* Within the fused path, `fused_seq_projection` handles only the paper's
  shared linear E ∈ R^{S×K}; per-head (Hkv, S, K) or conv/pool projections
  fall back to the reference projection while the attention itself stays
  fused (models/attention.py applies this rule).

All fused ops are trainable: `fused_linformer_attention` carries an analytic
custom VJP; `fused_seq_projection` is linear (analytic VJP below);
`fused_blockwise_causal_attention` recomputes its backward through the
pure-jnp reference (same math, so gradients match the reference path).

Layout note: kernels use (B, H, S, Dh); the model uses (B, S, H, Dh). These
wrappers accept model layout and handle GQA head repetition for the
compressed operands (cheap: K is small). The single-token decode wrapper
`fused_decode_attention` instead folds the GQA group axis into the kernel's
query-sequence axis, so K/V are never repeated; the blockwise-causal
wrappers route grouped query heads to their kv row via the grid index maps.

Known limits (docs/kernels.md has the full list): the fused path is
single-device (under a mesh, GSPMD partitions the reference einsums; the
kernels run whole inside a shard); `fused_chunk_prefill_attention` and
`fused_decode_attention` are inference-only (no VJP); pinned compressed
operands must fit VMEM (K ≤ 512 exact form, M = (max_seq/c)·r causal
forms); blockwise-causal forms need S % block_size == 0 (serving routes
the remainder through the decode path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import blockwise_causal_attn as bca
from repro.kernels import linformer_attn as la
from repro.kernels import ref
from repro.kernels import seq_projection as sp
from repro.core.causal import (blockwise_causal_attention,
                               blockwise_causal_attention_chunked,
                               compress_blocks)

BACKENDS = ("reference", "fused")


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def resolve_backend(backend: str = "auto") -> str:
    """Resolve an `AttentionConfig.backend` knob to a concrete backend.

    "auto" per platform: TPU -> fused (Mosaic-compiled); CPU -> fused in
    interpret mode (the kernel logic is the validated default path on this
    container); any other platform (e.g. GPU, which has no Mosaic lowering
    and where interpret mode would be pathologically slow) -> reference.
    """
    if backend in BACKENDS:
        return backend
    if backend != "auto":
        raise ValueError(
            f"unknown attention backend {backend!r}; "
            f"expected 'auto' or one of {BACKENDS}")
    return "fused" if jax.default_backend() in ("tpu", "cpu") else "reference"


def _divisor_block(size: int, preferred: int) -> int:
    """Largest block ≤ preferred that divides `size` (kernels tile evenly)."""
    b = max(1, min(preferred, size))
    while size % b:
        b -= 1
    return b


def _to_kernel_layout(x):        # (B,S,H,D) -> (B,H,S,D)
    return jnp.moveaxis(x, 2, 1)


def _from_kernel_layout(x):
    return jnp.moveaxis(x, 1, 2)


def _repeat_kv(x, H):            # (B,Hkv,K,D) -> (B,H,K,D)
    Hkv = x.shape[1]
    if Hkv == H:
        return x
    return jnp.repeat(x, H // Hkv, axis=1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _linformer_attn_diff(q, kbar, vbar, scale, block_q, interpret):
    """Differentiable fused attention: Pallas forward, analytic backward.

    Backward derivation (per head; P = softmax(S), S = q·k̄ᵀ·scale,
    o = P·v̄):  dv̄ = Pᵀ·do;  dP = do·v̄ᵀ;  dS = P ∘ (dP − rowsum(dP∘P));
    dq = dS·k̄·scale;  dk̄ = dSᵀ·q·scale. The P recompute is one small
    (S × k) matmul — cheaper than storing it."""
    kb = _repeat_kv(kbar, q.shape[1])
    vb = _repeat_kv(vbar, q.shape[1])
    return la.linformer_attn(q, kb, vb, scale=scale, block_q=block_q,
                             interpret=interpret)


def _lin_fwd(q, kbar, vbar, scale, block_q, interpret):
    out = _linformer_attn_diff(q, kbar, vbar, scale, block_q, interpret)
    return out, (q, kbar, vbar)


def _lin_bwd(scale, block_q, interpret, res, do):
    q, kbar, vbar = res
    H, Hkv = q.shape[1], kbar.shape[1]
    G = H // Hkv
    kb = _repeat_kv(kbar, H)
    vb = _repeat_kv(vbar, H)
    s = jnp.einsum("bhsd,bhkd->bhsk", q, kb).astype(jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    do32 = do.astype(jnp.float32)
    dv = jnp.einsum("bhsk,bhsd->bhkd", p, do32)
    dp = jnp.einsum("bhsd,bhkd->bhsk", do32, vb.astype(jnp.float32))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhsk,bhkd->bhsd", ds, kb.astype(jnp.float32)) * scale
    dk = jnp.einsum("bhsk,bhsd->bhkd", ds, q.astype(jnp.float32)) * scale
    # fold the GQA head-repeat: sum grads over the query-group axis
    B, _, K, Dh = kbar.shape
    dk = dk.reshape(B, Hkv, G, K, Dh).sum(2)
    dv = dv.reshape(B, Hkv, G, K, Dh).sum(2)
    return (dq.astype(q.dtype), dk.astype(kbar.dtype), dv.astype(vbar.dtype))


_linformer_attn_diff.defvjp(_lin_fwd, _lin_bwd)


@functools.partial(jax.jit, static_argnames=("scale", "block_q", "interpret"))
def fused_linformer_attention(
    q: jax.Array,        # (B, S, H, Dh) model layout
    kbar: jax.Array,     # (B, K, Hkv, Dh)
    vbar: jax.Array,
    *,
    scale: float,
    block_q: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Exact (bidirectional) Linformer attention through the Pallas kernel:
    softmax(q·k̄ᵀ·scale)·v̄ over the K compressed slots.

    Shapes/dtypes: model layout — q (B, S, H, Dh); kbar/vbar (B, K, Hkv,
    Dh) with K ≤ 512 so the whole compressed operand pins in VMEM (scores
    fp32, output in q's dtype). GQA kv heads are repeated to H for the
    compressed operands (cheap: K is small). Trainable — analytic custom
    VJP (`_lin_bwd`); `block_q` shrinks to the largest divisor of S."""
    qk = _to_kernel_layout(q)
    kb = _to_kernel_layout(kbar)
    vb = _to_kernel_layout(vbar)
    out = _linformer_attn_diff(qk, kb, vb, scale,
                               _divisor_block(q.shape[1], block_q),
                               _auto_interpret(interpret))
    return _from_kernel_layout(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _seq_projection_diff(xk, E, block_s, interpret):
    """Differentiable fused projection (kernel layout). The op is linear:
    out = Eᵀ·x, so dx = E·dout and dE = Σ_{b,h} x·doutᵀ."""
    return sp.seq_projection(xk, E, block_s=block_s, interpret=interpret)


def _sp_fwd(xk, E, block_s, interpret):
    return _seq_projection_diff(xk, E, block_s, interpret), (xk, E)


def _sp_bwd(block_s, interpret, res, do):
    xk, E = res
    do32 = do.astype(jnp.float32)
    dx = jnp.einsum("bhkd,sk->bhsd", do32, E.astype(jnp.float32))
    dE = jnp.einsum("bhsd,bhkd->sk", xk.astype(jnp.float32), do32)
    return dx.astype(xk.dtype), dE.astype(E.dtype)


_seq_projection_diff.defvjp(_sp_fwd, _sp_bwd)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def fused_seq_projection(
    x: jax.Array,        # (B, S, H, Dh)
    E: jax.Array,        # (S, K)
    *,
    block_s: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused sequence-axis projection out = Eᵀ·x: (B, S, H, Dh) × (S, K)
    → (B, K, H, Dh) — the paper's shared linear compression of K/V.
    Handles ONLY the shared 2-D E (per-head / conv / pool projections go
    through the reference ops; models/attention.py applies this rule).
    Linear, so trainable with an analytic VJP."""
    out = _seq_projection_diff(_to_kernel_layout(x), E,
                               _divisor_block(x.shape[1], block_s),
                               _auto_interpret(interpret))
    return _from_kernel_layout(out)        # (B, K, H, Dh)


def _blockwise_causal_fused(q, k, v, E, F, block_size, block_slots, scale,
                            interpret):
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    nb = S // block_size
    kbar = compress_blocks(k.reshape(B, nb, block_size, Hkv, Dh), E)
    vbar = compress_blocks(v.reshape(B, nb, block_size, Hkv, Dh), F)
    kbar = kbar.reshape(B, nb * block_slots, Hkv, Dh)
    vbar = vbar.reshape(B, nb * block_slots, Hkv, Dh)
    # K/V keep their native Hkv heads: the kernel's index maps route each
    # grouped query head to its kv row (no G-fold jnp.repeat in HBM).
    out = bca.blockwise_causal_attn(
        _to_kernel_layout(q), _to_kernel_layout(k), _to_kernel_layout(v),
        _to_kernel_layout(kbar), _to_kernel_layout(vbar),
        block_size=block_size, block_slots=block_slots, scale=scale,
        interpret=interpret)
    return _from_kernel_layout(out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _blockwise_causal_diff(q, k, v, E, F, block_size, block_slots, scale,
                           interpret):
    """Differentiable fused blockwise-causal attention: Pallas forward,
    backward recomputed through the pure-jnp reference (identical math, so
    gradients match the reference path; the recompute is the standard
    no-stored-probabilities tradeoff)."""
    return _blockwise_causal_fused(q, k, v, E, F, block_size, block_slots,
                                   scale, interpret)


def _bca_fwd(q, k, v, E, F, block_size, block_slots, scale, interpret):
    out = _blockwise_causal_diff(q, k, v, E, F, block_size, block_slots,
                                 scale, interpret)
    return out, (q, k, v, E, F)


def _bca_bwd(block_size, block_slots, scale, interpret, res, do):
    q, k, v, E, F = res
    # Long sequences recompute through the memory-bounded chunked reference
    # (same math): the plain form materializes the full (…, S, nb·r) global
    # score tensor, which the fused forward exists to avoid. Threshold
    # mirrors the forward's `chunked = S >= 8192` rule (models/transformer).
    ref_fn = (blockwise_causal_attention_chunked if q.shape[1] >= 8192
              else blockwise_causal_attention)
    _, vjp = jax.vjp(
        lambda q_, k_, v_, E_, F_: ref_fn(
            q_, k_, v_, E_, F_, block_size=block_size, scale=scale),
        q, k, v, E, F)
    return vjp(do)


_blockwise_causal_diff.defvjp(_bca_fwd, _bca_bwd)


@functools.partial(jax.jit, static_argnames=(
    "block_size", "block_slots", "scale", "interpret"))
def fused_blockwise_causal_attention(
    q: jax.Array,        # (B, S, H, Dh)
    k: jax.Array,        # (B, S, Hkv, Dh)
    v: jax.Array,
    E: jax.Array,        # (c, r) or (Hkv, c, r)
    F: jax.Array,
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if q.shape[1] % block_size != 0:
        raise ValueError(
            f"S={q.shape[1]} must be a multiple of block_size={block_size}")
    return _blockwise_causal_diff(q, k, v, E, F, block_size, block_slots,
                                  scale, _auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "block_size", "block_slots", "scale", "interpret"))
def fused_chunk_prefill_attention(
    q: jax.Array,        # (B, P, H, Dh) — one prefill chunk, model layout
    k: jax.Array,        # (B, P, Hkv, Dh) — the chunk's own keys
    v: jax.Array,
    comp_k: jax.Array,   # (B, M, Hkv, Dh) — slot-resident compressed cache
    comp_v: jax.Array,   #   with the chunk's own blocks already folded in
    start_blocks: jax.Array,   # (B,) int32 — per-row absolute start block
    *,
    block_size: int,
    block_slots: int,
    scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Blockwise-causal attention for a prefill chunk starting at a nonzero
    per-row offset (the chunked-admission prefill path).

    Shapes/dtypes: model layout in and out — q (B, P, H, Dh) with
    P % block_size == 0; k/v carry native Hkv GQA heads (index-map routing,
    no HBM repeat); comp_k/comp_v are the cache's FULL slot buffers
    (M = (max_seq/block_size)·block_slots rows, cache dtype), pinned per grid
    step like the decode kernel's compressed operand. Row b's query block j
    attends [its own block, causally | compressed slots of absolute blocks
    < start_blocks[b] + j] — `start_blocks` is traced (one compile serves
    every offset), which is what makes fixed-size chunk compiles reusable
    across a prompt and across rows of a batched admission round.

    Inference-only: no custom VJP (the training path prefers
    `fused_blockwise_causal_attention`, which starts at offset zero).
    """
    if q.shape[1] % block_size != 0:
        raise ValueError(
            f"P={q.shape[1]} must be a multiple of block_size={block_size}")
    out = bca.blockwise_causal_prefix_attn(
        _to_kernel_layout(q), _to_kernel_layout(k), _to_kernel_layout(v),
        _to_kernel_layout(comp_k), _to_kernel_layout(comp_v), start_blocks,
        block_size=block_size, block_slots=block_slots, scale=scale,
        interpret=_auto_interpret(interpret))
    return _from_kernel_layout(out)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def fused_decode_attention(
    q_t: jax.Array,        # (B, 1, H, Dh) — one decode token per row
    raw_k: jax.Array,      # (B, c, Hkv, Dh) — raw ring buffer (resident)
    raw_v: jax.Array,
    comp_k: jax.Array,     # (B, M, Hkv, Dh) — compressed slots (resident)
    comp_v: jax.Array,
    bias_loc: jax.Array,   # (B, c) fp32 — 0 attendable, NEG_INF masked
    bias_glob: jax.Array,  # (B, M) fp32
    *,
    scale: float,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Single-token GQA decode attention through the fused kernel.

    Instead of repeating K/V to the query head count, the GQA group axis is
    folded into the kernel's query-sequence axis: q (B, 1, Hkv·G, Dh) is
    viewed as (B, Hkv, G, Dh) — G queries per kv head, all sharing that
    head's raw + compressed slots. The raw block and the compressed prefix
    stay TWO pinned kernel operands (cache residency: no per-step HBM
    concatenate of the caches), each with a PER-ROW additive validity bias
    (the raw ring prefix ≤ pos[b] and the blk[b]·r completed slots), so one
    kernel handles every per-row (pos, blk) combination — the contract the
    continuous-batching scheduler relies on.
    """
    B, _, H, Dh = q_t.shape
    Hkv = raw_k.shape[2]
    G = H // Hkv
    qk = q_t.reshape(B, Hkv, G, Dh)             # kernel layout: S-axis = G
    out = la.decode_attn(
        qk, _to_kernel_layout(raw_k), _to_kernel_layout(raw_v),
        _to_kernel_layout(comp_k), _to_kernel_layout(comp_v),
        bias_loc, bias_glob, scale=scale,
        interpret=_auto_interpret(interpret))
    return out.reshape(B, 1, H, Dh)
