"""Host-side page allocator for the paged, quantized pool cache.

The device side (core/cache.py paged family) never allocates: it reads and
writes pages strictly through the per-row page table, redirecting folds
whose block has no page to the reserved TRASH page. THIS class is the only
authority over which physical arena page belongs to which pool row, and it
runs on the host BETWEEN chunks — exactly where the scheduler already does
its slot bookkeeping, so allocation adds no device sync.

Invariants (property-tested in tests/test_properties.py):

* a page is owned by at most one row at a time (no double-allocation, no
  cross-row aliasing);
* every page handed out by `alloc` comes back through `free_row` — the
  free list plus all row lists always partition the usable pages (no
  leaks);
* the TRASH page (id `n_pages - 1`) is never allocated;
* freed pages are scrubbed (the `scrub` callback — the engine zeroes the
  arena pages + scales on device) BEFORE they return to the free list, so
  a page can never leak one request's KV bytes into the next request's
  snapshot.

Allocation is all-or-nothing per call: a request that cannot get all the
pages it asked for gets none (the scheduler then preempts or sheds with
the `pages_exhausted` reason rather than wedging half-allocated).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence


class PageAllocator:
    """Free-list allocator over a page arena whose last page is TRASH."""

    def __init__(self, n_pages: int, *,
                 scrub: Optional[Callable[[Sequence[int]], None]] = None):
        if n_pages < 2:
            raise ValueError("arena needs >= 2 pages (1 usable + TRASH)")
        self.n_pages = n_pages
        self.trash_page = n_pages - 1
        # LIFO free list: recently scrubbed pages are reused first (their
        # zeroed bytes are most likely still resident in cache)
        self._free: List[int] = list(range(n_pages - 2, -1, -1))
        self._rows: Dict[int, List[int]] = {}
        self._scrub = scrub

    # -- introspection ------------------------------------------------------

    @property
    def usable_pages(self) -> int:
        """Pages that can ever be allocated (arena minus TRASH)."""
        return self.n_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.usable_pages - len(self._free)

    def pages_of(self, row: int) -> List[int]:
        """The row's pages in block order (a copy)."""
        return list(self._rows.get(row, ()))

    def owned_rows(self) -> List[int]:
        return [r for r, pages in self._rows.items() if pages]

    # -- allocation ---------------------------------------------------------

    def alloc(self, row: int, n: int) -> Optional[List[int]]:
        """Append `n` pages to `row`'s table, all-or-nothing. Returns the
        new page ids (possibly empty for n == 0), or None when fewer than
        `n` pages are free — in which case nothing is allocated."""
        if n < 0:
            raise ValueError(f"alloc of negative page count {n}")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._rows.setdefault(row, []).extend(pages)
        return pages

    def free_row(self, row: int) -> int:
        """Release all of `row`'s pages: scrub first (zero the device bytes
        — the zero-before-reuse invariant), then return them to the free
        list. Returns the number of pages released."""
        pages = self._rows.pop(row, [])
        if not pages:
            return 0
        if self._scrub is not None:
            self._scrub(pages)
        self._free.extend(pages)
        return len(pages)

    # -- consistency (test / debug surface) ---------------------------------

    def check(self) -> None:
        """Assert the partition invariant: free list and row lists are
        disjoint, cover no page twice, and never touch TRASH."""
        seen = set(self._free)
        if len(seen) != len(self._free):
            raise AssertionError("free list holds duplicate pages")
        for row, pages in self._rows.items():
            for p in pages:
                if p in seen:
                    raise AssertionError(
                        f"page {p} of row {row} is double-booked")
                seen.add(p)
        if self.trash_page in seen:
            raise AssertionError("TRASH page was allocated or freed")
        if seen != set(range(self.usable_pages)):
            raise AssertionError("pages leaked: free+rows != usable arena")


def pages_needed(tokens: int, block_size: int) -> int:
    """Pages a row needs to hold `tokens` committed tokens: one page per
    completed-or-started block (ceil division). The raw ring holds the
    current incomplete block, but its page must exist BEFORE the fold that
    completes it, so capacity planning rounds up."""
    return -(-tokens // block_size)
