"""Deterministic fault injection for the serving stack.

The injector models three failure classes, all applied at chunk boundaries
through the SlotPool owner (so donation safety is never violated), and all
seeded/scheduled so a failing run replays exactly:

* ``slot_step`` — one slot's decode step "fails" (the model of a device
  fault): the row's cache leaves are garbled with finite noise before the
  chunk, and the injector reports the row as failed at the chunk's host
  sync (the stand-in for a runtime error status). The garbling is real —
  with detection disabled (``detectable=False``) the run provably streams
  wrong tokens — so recovery is negative-testable, not vacuous.
* ``nan_logits`` — the row's cache leaves are poisoned with NaN before the
  chunk, so the model's logits for that row genuinely go non-finite and the
  scheduler's NaN/Inf guard (the per-row ``bad`` flag riding the chunk's
  one host sync) must catch it. The injector does NOT report this row:
  detection is entirely the guard's job.
* ``snapshot_corrupt`` — the row's last-good snapshot has a byte flipped
  after capture AND the row's step fails (as ``slot_step``), forcing a
  restore attempt: the checksum mismatch must be detected at restore and
  recovery must fall back to re-running the request from its prompt.

Scheduler contract under injection (tests/test_serving_faults.py): every
fired fault is detected, the faulty request still completes byte-identically
(requeue from its last good snapshot, or from scratch), and co-resident
rows' outputs never change — a fault quarantines exactly one row.

Schedules are either explicit (``Fault(kind, chunk, row)`` list) or random:
``FaultInjector(seed=s, n_random=k)`` draws k (chunk, kind) pairs up front
and picks a live row at fire time — deterministic for a given seed and
serve trace. ``fired`` / ``skipped`` record what actually happened.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

SLOT_STEP = "slot_step"
NAN_LOGITS = "nan_logits"
SNAPSHOT_CORRUPT = "snapshot_corrupt"
FAULT_KINDS = (SLOT_STEP, NAN_LOGITS, SNAPSHOT_CORRUPT)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault. ``chunk`` indexes executed decode chunks
    (ScheduleStats.chunks at fire time); ``row`` is the pool row, or None
    for random schedules (a live row is drawn at fire time)."""

    kind: str
    chunk: int
    row: Optional[int] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(choose from {FAULT_KINDS})")


class FaultInjector:
    def __init__(self, schedule: Optional[Sequence[Fault]] = None, *,
                 seed: int = 0, n_random: int = 0, horizon: int = 16,
                 kinds: Sequence[str] = FAULT_KINDS,
                 detectable: bool = True):
        """`schedule`: explicit faults; or `n_random` faults drawn over
        chunks [0, horizon) from `kinds` with `seed`. `detectable=False`
        keeps the corruption but silences the injector's failure reports
        (slot_step faults become silent corruption — the negative-test mode
        proving the injection is real; nan_logits stays detectable because
        the NaN guard, not the injector, detects it)."""
        self.detectable = detectable
        self._rng = np.random.default_rng(seed)
        if schedule is None:
            chunks = sorted(self._rng.choice(horizon, size=n_random,
                                             replace=False)
                            if n_random <= horizon else
                            self._rng.integers(0, horizon, n_random))
            schedule = [Fault(kind=str(self._rng.choice(list(kinds))),
                              chunk=int(c)) for c in chunks]
        self.schedule: List[Fault] = list(schedule)
        self.fired: List[Fault] = []      # faults that actually landed
        self.skipped: List[Fault] = []    # target row dead at fire time
        self._reported: Set[int] = set()  # rows to report failed this chunk

    # -- scheduler hooks (called between decode chunks) -------------------

    def _due(self, chunk_idx: int) -> List[Fault]:
        return [f for f in self.schedule if f.chunk == chunk_idx]

    def before_chunk(self, pool, snapshots: Dict[int, object],
                     chunk_idx: int) -> None:
        """Apply the corruption of every fault due at this chunk. `pool` is
        the SlotPool (corruption routes through its donating owner methods);
        `snapshots` is the scheduler's row -> last-good-snapshot map."""
        self._reported = set()
        for fault in self._due(chunk_idx):
            row = fault.row
            if row is None:
                live = [r for r, s in enumerate(pool.slots) if s is not None]
                if not live:
                    self.skipped.append(fault)
                    continue
                row = int(self._rng.choice(live))
            elif pool.slots[row] is None:
                self.skipped.append(fault)
                continue
            fault = dataclasses.replace(fault, row=row)
            if fault.kind == NAN_LOGITS:
                pool.corrupt_row(row, mode="nan")
            else:                          # slot_step / snapshot_corrupt
                pool.corrupt_row(row, mode="garble")
                if self.detectable:
                    self._reported.add(row)
            if fault.kind == SNAPSHOT_CORRUPT:
                snap = snapshots.get(row)
                if snap is None:
                    self.skipped.append(fault)
                    continue
                # flip one byte of one LEAF, drawn uniformly — every leaf
                # is a target, so on a paged pool the flip lands in the
                # quantized pages, the ring, the counters, OR an fp32
                # scale leaf: a scale-only flip must fail verify() exactly
                # like a payload flip (the checksum covers both)
                keys = sorted(snap.cache_rows)
                key = keys[int(self._rng.integers(len(keys)))]
                leaf = snap.cache_rows[key]
                flat = leaf.reshape(-1).view(np.uint8)
                flat[int(self._rng.integers(flat.size))] ^= 0xFF
            self.fired.append(fault)

    def failed_rows(self, chunk_idx: int) -> Set[int]:
        """Rows whose step the injector reports as failed for the chunk that
        just ran — the simulated device-error status the scheduler consumes
        at the host sync."""
        return set(self._reported)
