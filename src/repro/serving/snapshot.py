"""Host-side slot snapshots: the preemption / fault-recovery unit.

A `SlotSnapshot` is everything needed to resume one request byte-identically
on any free pool row: the row's cache leaves (host copies of the
`_gather_rows` slice — O(c + M) per row thanks to the compressed prefix, not
O(n)), the next un-emitted sampled token (`cur`), the finished flag, the
emitted-token list, and the chunked-prefill progress (`state`, `filled`).

Snapshots are always captured at a chunk boundary (between device-resident
decode chunks), where a slot's state is clean: restoring the cache rows via
`_scatter_rows` and re-entering the decode loop replays exactly the steps an
uninterrupted run would have taken — greedy decode depends only on the
row's own bytes (per-row masks), so preempt -> requeue -> resume is
byte-identical (tests/test_serving_scheduler.py::TestPreemption).

Integrity: `checksum` is a CRC32 over the cache-row bytes, computed at
capture. `verify()` recomputes it at restore time — a corrupted snapshot
(bit-rot, a buggy transport, or the fault injector's `snapshot_corrupt`
fault) is detected *before* its bytes reach the pool, and the scheduler
falls back to re-running the request from its prompt (greedy decode makes
that fallback byte-identical too, just slower).

The checksum walks EVERY `cache_rows` leaf in sorted key order — for a
paged pool that is the quantized ring + pages AND their fp32 scale leaves
(`raw_*_s`, `pages_*_s`). A quantized cache is only as good as its scales
(a flipped scale byte rescales a whole block's dequantized values), so a
scale-only bit-flip fails `verify()` exactly like a payload flip
(tests/test_serving_faults.py::TestPagedSnapshotScales).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List

import numpy as np


def cache_rows_checksum(cache_rows: Dict[str, np.ndarray]) -> int:
    """CRC32 over the snapshot's cache bytes (key order fixed by sort)."""
    crc = 0
    for key in sorted(cache_rows):
        leaf = np.ascontiguousarray(cache_rows[key])
        crc = zlib.crc32(leaf.tobytes(), crc)
    return crc


@dataclasses.dataclass
class SlotSnapshot:
    """Resume state for one request, captured at a chunk boundary."""

    rid: int
    state: str                         # scheduler slot state at capture
    filled: int                        # prompt tokens committed (chunked)
    cur: int                           # next un-emitted sampled token
    finished: bool                     # EOS already sampled into `cur`
    emitted: List[int]                 # tokens emitted up to the boundary
    cache_rows: Dict[str, np.ndarray]  # host copies, batch-of-1 leaves
    checksum: int                      # CRC32 of cache_rows at capture
    tick: int                          # virtual time of capture

    def verify(self) -> bool:
        """True iff the cache bytes still match the capture-time checksum."""
        return cache_rows_checksum(self.cache_rows) == self.checksum

    @property
    def nbytes(self) -> int:
        return sum(v.nbytes for v in self.cache_rows.values())


def capture(rid: int, state: str, filled: int, cur: int, finished: bool,
            emitted: List[int], cache_rows: Dict[str, np.ndarray],
            tick: int) -> SlotSnapshot:
    """Build a snapshot, owning copies of the mutable pieces."""
    rows = {k: np.array(v) for k, v in cache_rows.items()}
    return SlotSnapshot(rid=rid, state=state, filled=filled, cur=int(cur),
                        finished=bool(finished), emitted=list(emitted),
                        cache_rows=rows,
                        checksum=cache_rows_checksum(rows), tick=tick)
