"""Serving engine: slot-based continuous batching over device-resident decode.

Slot/scheduler model (the default `serve` path): the engine owns a fixed
pool of `max_batch` cache slots — one batch row of a single pool cache —
and a `Scheduler` (serving/scheduler.py) admits/evicts requests *between*
device-resident decode chunks:

* admission: with `prefill_chunk=0` (monolithic) a queued request is
  prefilled alone (B=1), its cache rows are `dynamic_update_slice`d into a
  free pool slot, and its per-row position counter
  (`cache["lengths"][slot]`) starts at the prompt length; with
  `prefill_chunk=P` (chunked) the slot is claimed at t=0 and the prompt
  streams into the pool cache P tokens per scheduler round — interleaved
  with decode chunks so a long prompt cannot stall the pool — with every
  co-prefilling request's next chunk batched into ONE padded (g, P)
  forward (`pool_prefill_chunk`): per-row offsets and valid-token counts
  are traced, so one compile serves every prompt length and progress mix;
* decode: the whole pool scans `decode_chunk` tokens on device
  (model.decode_scan — one host sync per chunk), idle slots riding along
  finished-masked;
* retirement: EOS or an exhausted per-request token budget frees the slot
  for the next admission round, streaming the finished tokens back through
  a completion callback.

Because every cache write, rope position, attention mask and block fold is
per-row (core/cache.py), a slot decodes identically whatever its
neighbours are doing — continuous scheduling is byte-identical to the
static bucketed baseline, kept as `serve_static`.

Prefill strategy (linformer_causal): monolithically, the full-block prefix
(⌊S/c⌋·c tokens) is prefilled in ONE parallel forward that also
materializes the compressed cache; the ≤c-1 remainder tokens run through
the decode path. Chunked admission splits the full-block prefix into
fixed P-token chunks (P a multiple of c, so chunk boundaries are
block-fold boundaries) computed by a prefill-at-offset forward
(model.prefill_chunk → kernels' blockwise-causal-prefix path) against the
slot-resident compressed cache; the remainder runs through the decode
path exactly as before, batched per remainder-length group. Standard
attention prefills the full prompt in one pass (monolithic) or in P-token
chunks at any offset (chunked).

Chunked decode contract: generation runs as jitted `lax.scan` chunks of
`decode_chunk` tokens (model.decode_scan) — sampling, EOS masking, and the
cache update all stay on device, and the host syncs ONCE per chunk instead
of once per token. The per-token Python loop is kept as
`generate_batch_per_token` — the measured baseline of
benchmarks/decode_throughput.py.

Cache ownership: the chunk scan DONATES its cache buffers. The batch-level
helpers (`decode_tokens`) consume the cache they are given; the scheduler
path instead routes every donation through the pool's single owner
(scheduler.SlotPool), which swaps in the returned buffers atomically — a
live scheduler can therefore never observe a donated (invalidated) cache.

The decode-time win of the paper's technique shows up here as cache size:
c + r·S/c slots instead of S (≈14× at 32k, ≈16× at 512k) — see
benchmarks/table3_efficiency.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cache as cache_lib
from repro.data.pipeline import EOS
from repro.models import model as model_lib
from repro.parallel.sharding import ParallelCtx
from repro.telemetry import as_telemetry, plan_attribution

# Leaves of the PAGED pool cache that live in the shared page arena —
# indexed by physical page (L, Np, ...), not by pool row. Every per-row
# gather/scatter must treat them wholesale (the arena is one shared object;
# rows reach it only through their page-table indirection).
PAGED_ARENA_KEYS = ("page_k", "page_v", "page_k_s", "page_v_s")

# Hand-picked decode-scan chunk length — the fallback the tuning table's
# platform-wide "decode_chunk" scalar overrides (repro/tune/table.py).
# Chunk length changes tick granularity (scheduling interleave), never
# per-request token streams — the decode-chunk-invariance contract.
DEFAULT_DECODE_CHUNK = 32


def bucket_requests(prompts: Sequence[Sequence[int]], max_batch: int
                    ) -> List[List[int]]:
    """Group request indices into equal-length buckets of ≤ max_batch."""
    by_len: Dict[int, List[int]] = {}
    for i, p in enumerate(prompts):
        by_len.setdefault(len(p), []).append(i)
    buckets = []
    for _, idxs in sorted(by_len.items()):
        for j in range(0, len(idxs), max_batch):
            buckets.append(idxs[j:j + max_batch])
    return buckets


def _per_request_max_new(max_new_tokens: Union[int, Sequence[int]],
                         n: int) -> List[int]:
    if isinstance(max_new_tokens, int):
        return [max_new_tokens] * n
    out = list(max_new_tokens)
    if len(out) != n:
        raise ValueError(f"max_new_tokens has {len(out)} entries "
                         f"for {n} prompts")
    return out


class ServingEngine:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        max_seq: int,
        ctx: Optional[ParallelCtx] = None,
        cache_dtype=jnp.bfloat16,
        temperature: float = 0.0,
        decode_chunk: Optional[int] = None,
        attention_backend: Optional[str] = None,
        prefill_chunk: int = 0,
        cache_format: str = "dense",
        arena_pages: Optional[int] = None,
        page_dtype: str = "int8",
        telemetry=None,
    ):
        if attention_backend is not None:
            cfg = cfg.with_attention_backend(attention_backend)
        # Resolve the attention execution plan once per engine: fails fast
        # on an unshardable mesh at construction, and owns the pool cache's
        # placement (per-shard slots for the decode kernel's two pinned
        # operands under tensor parallelism).
        from repro.parallel.plan import resolve_attention_plan
        self.plan = resolve_attention_plan(cfg.attention, ctx)
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.ctx = ctx
        self.cache_dtype = cache_dtype
        self.temperature = temperature
        if decode_chunk is None:
            from repro.tune import table as tuning
            decode_chunk = tuning.scalar("decode_chunk",
                                         DEFAULT_DECODE_CHUNK)
        self.decode_chunk = max(1, decode_chunk)
        # repro-lint: allow[RL002] constructor arg normalization — host int
        self.prefill_chunk = int(prefill_chunk)
        # Paged, quantized pool storage (cache_format="paged"): the pool's
        # per-row K/V lives as int8/fp8 pages in a shared arena behind a
        # per-row page table; `arena_pages` (None = capacity-equivalent to
        # the dense pool) is the oversubscription knob. Affects ONLY the
        # slot-pool path — one-shot generate/serve_static still run dense.
        if cache_format not in ("dense", "paged"):
            raise ValueError(f"unknown cache_format {cache_format!r} "
                             "(expected 'dense' or 'paged')")
        self.cache_format = cache_format
        self.arena_pages = arena_pages
        self.page_dtype = page_dtype
        if self.paged:
            if cfg.attention.kind != "linformer_causal":
                raise ValueError(
                    "cache_format='paged' requires the linformer_causal "
                    f"attention family, got {cfg.attention.kind!r} (the "
                    "page size IS the attention block fold)")
            # resolves the dtype now: fails fast on fp8 without jnp support
            _, self._page_qmax = cache_lib.resolve_page_dtype(page_dtype)
        self.telemetry = as_telemetry(telemetry)
        # shape-level compile-cache proxies: a novel decode-scan length or
        # prefill shape forces a jit specialization (see _note_compile)
        self._prefill_shapes: set = set()
        self._attributed: set = set()   # facades holding this plan's record
        self._record_plan_attribution(self.telemetry)

        self._decode = jax.jit(
            lambda p, b, c: model_lib.decode_step(p, cfg, b, c, ctx=ctx))
        self._prefill = jax.jit(
            lambda p, b: model_lib.forward(
                p, cfg, b, ctx=ctx, return_cache=True,
                cache_max_seq=max_seq, cache_dtype=cache_dtype),
        )
        self._chunk_fns: Dict[int, Callable] = {}
        self._write_slot = jax.jit(self._write_slot_impl,
                                   donate_argnums=(0,))
        # Snapshot/restore surface (preemption + fault recovery): gather
        # does NOT donate (the pool stays live — a snapshot is a copy),
        # scatter/scrub/corrupt donate like every other pool mutation.
        self._snapshot_rows = jax.jit(self._gather_rows)
        self._restore_rows = jax.jit(self._scatter_rows,
                                     donate_argnums=(0,))
        self._scrub_row = jax.jit(self._scrub_row_impl, donate_argnums=(0,))
        self._corrupt_row = jax.jit(self._corrupt_row_impl,
                                    static_argnums=(2,), donate_argnums=(0,))
        if self.paged:
            # Paged pool mutations: the arena leaves are page-indexed, so
            # the generic per-row gather/scatter/scrub/corrupt shapes are
            # wrong for them — each gets a dedicated, page-table-aware jit.
            self._write_slot_paged = jax.jit(self._write_slot_paged_impl,
                                             donate_argnums=(0,))
            self._snapshot_rows_paged = jax.jit(self._gather_rows_paged)
            self._restore_row_paged = jax.jit(self._restore_row_paged_impl,
                                              donate_argnums=(0,))
            self._scrub_row_paged = jax.jit(self._scrub_row_paged_impl,
                                            donate_argnums=(0,))
            self._corrupt_row_paged = jax.jit(
                self._corrupt_row_paged_impl, static_argnums=(3,),
                donate_argnums=(0,))
            self._scrub_pages = jax.jit(self._scrub_pages_impl,
                                        donate_argnums=(0,))
            self._set_table_row = jax.jit(self._set_table_row_impl,
                                          donate_argnums=(0,))
        if self.prefill_chunk:
            blk = self._block()
            if self.prefill_chunk < blk or self.prefill_chunk % blk != 0:
                raise ValueError(
                    f"prefill_chunk={self.prefill_chunk} must be a positive "
                    f"multiple of the attention block size ({blk}) so chunk "
                    "boundaries land on block-fold boundaries")
            self._pool_prefill_chunk = jax.jit(
                self._pool_prefill_chunk_impl, donate_argnums=(1,))
            self._pool_prefill_remainder = jax.jit(
                self._pool_prefill_remainder_impl, donate_argnums=(1,))
            self._reset_row = jax.jit(self._reset_row_impl,
                                      donate_argnums=(0,))

    # -- internals ------------------------------------------------------

    def _block(self) -> int:
        a = self.cfg.attention
        if a.kind == "linformer_causal":
            return a.linformer.block_size
        return 1

    @property
    def paged(self) -> bool:
        return self.cache_format == "paged"

    def max_pages_per_row(self) -> int:
        """Page-table width: one page per block fold over the pool's token
        capacity (max_seq + the chunked-prefill slack)."""
        return (self.max_seq + self.prefill_chunk) // self._block()

    def resolved_arena_pages(self, max_batch: int) -> int:
        """Physical arena size for a `max_batch`-row pool: the explicit
        `arena_pages` knob, or one full table per row + TRASH (capacity-
        equivalent to the dense pool — no oversubscription)."""
        if self.arena_pages is not None:
            return self.arena_pages
        return max_batch * self.max_pages_per_row() + 1

    def _record_plan_attribution(self, tel) -> None:
        """Emit the resolved plan's cost-attribution record (backend,
        per-form FLOPs/comm-bytes estimates) into `tel` — once per facade,
        so a per-run `serve(telemetry=...)` override still gets it."""
        if not tel.enabled or tel in self._attributed:
            return
        self._attributed.add(tel)
        rec = plan_attribution(self.plan, self.cfg.attention,
                               max_seq=self.max_seq,
                               prefill_chunk=self.prefill_chunk or None)
        tel.record(rec.pop("kind"), **rec)

    def _note_compile(self, fn_name: str, hit: bool) -> None:
        """Count a shape-level jit compile-cache hit/miss (a proxy: jax's
        own cache is keyed the same way — per (function, abstract shapes) —
        so a novel shape here is a novel trace + compile there)."""
        if self.telemetry.enabled:
            self.telemetry.metrics.counter(
                "serving_compile_cache_hit_total" if hit
                else "serving_compile_cache_miss_total", fn=fn_name).inc()

    def _note_table_stats(self, tel=None) -> None:
        """Drain the tuning table's trace-time lookup counters into the
        metrics registry (rides the compile-cache proxies above): how many
        kernel-knob resolutions hit a committed TUNING.json entry vs fell
        back to the hand-picked defaults since the last drain."""
        tel = tel if tel is not None else self.telemetry
        if not tel.enabled:
            return
        from repro.tune import table as tuning
        stats = tuning.consume_stats()
        for key, name in (("hits", "tuning_table_hit_total"),
                          ("misses", "tuning_table_miss_total")):
            if stats[key]:
                tel.metrics.counter(name).inc(stats[key])

    def _sample(self, logits: jax.Array, rng) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / self.temperature, axis=-1)

    def prefill(self, tokens: np.ndarray) -> Tuple[Dict, jax.Array]:
        """tokens: (B, S) prompt. Returns (cache at t=S, last-token logits)."""
        B, S = tokens.shape
        c = self._block()
        nfull = (S // c) * c
        shape = (B, nfull)
        self._note_compile("prefill", hit=shape in self._prefill_shapes)
        self._prefill_shapes.add(shape)
        if nfull == 0:
            cache = model_lib.init_cache(self.cfg, batch=B,
                                         max_seq=self.max_seq,
                                         dtype=self.cache_dtype)
            logits = None
        else:
            batch = {"tokens": jnp.asarray(tokens[:, :nfull])}
            logits_all, _, cache = self._prefill(self.params, batch)
            logits = logits_all[:, -1]
        for t in range(nfull, S):
            logits_t, cache = self._decode(
                self.params, {"tokens": jnp.asarray(tokens[:, t:t + 1])},
                cache)
            logits = logits_t[:, 0]
        return cache, logits

    def _chunk_fn(self, n: int) -> Callable:
        """Jitted n-step device-resident decode (cached per scan length)."""
        fn = self._chunk_fns.get(n)
        self._note_compile("decode_chunk", hit=fn is not None)
        if fn is None:
            cfg, ctx, temp = self.cfg, self.ctx, self.temperature
            fn = jax.jit(
                lambda p, cur, fin, cache, rng: model_lib.decode_scan(
                    p, cfg, cur, fin, cache, rng, n_steps=n, eos_id=EOS,
                    temperature=temp, ctx=ctx),
                donate_argnums=(3,))
            self._chunk_fns[n] = fn
        return fn

    # -- chunked-prefill internals ---------------------------------------

    @staticmethod
    def _gather_rows(pool: Dict, idx: jax.Array) -> Dict:
        """Stack pool rows `idx` into a B=len(idx) sub-cache. Cache leaves
        are (L, B, ...) except the per-row `lengths` (B,). Paged arena
        leaves ride through WHOLE: the gathered rows' page-table slices
        keep indexing the one shared arena."""
        return {k: (v if k in PAGED_ARENA_KEYS
                    else jnp.take(v, idx, axis=0 if k == "lengths" else 1))
                for k, v in pool.items()}

    @staticmethod
    def _scatter_rows(pool: Dict, sub: Dict, idx: jax.Array) -> Dict:
        """Write a sub-cache back into pool rows `idx` (inverse of
        `_gather_rows`). Duplicate indices are benign ONLY when they carry
        identical rows (the batch-padding trick below relies on this:
        `.set` scatter semantics make the duplicate a no-op rewrite; a
        duplicated paged row scatters identical bytes to the same pages).
        The sub-forward's arena leaves REPLACE the pool's — the sub held
        the whole arena, and untouched pages passed through unchanged."""
        out = {}
        for k, v in pool.items():
            upd = sub[k].astype(v.dtype)
            if k in PAGED_ARENA_KEYS:
                out[k] = upd
            else:
                out[k] = (v.at[idx].set(upd) if k == "lengths"
                          else v.at[:, idx].set(upd))
        return out

    def _pool_prefill_chunk_impl(self, params, pool: Dict, tokens: jax.Array,
                                 n_valid: jax.Array, idx: jax.Array):
        """Gather rows `idx`, run one prefill-at-offset chunk forward over
        them, scatter the advanced cache state back. Donates `pool`."""
        sub = self._gather_rows(pool, idx)
        logits, sub = model_lib.prefill_chunk(
            params, self.cfg, {"tokens": tokens}, sub, n_valid, ctx=self.ctx)
        return self._scatter_rows(pool, sub, idx), logits

    def _pool_prefill_remainder_impl(self, params, pool: Dict,
                                     tokens: jax.Array, idx: jax.Array):
        """Feed the sub-block remainder of a prompt (rem = tokens.shape[1]
        < block size) through the decode path against the gathered rows —
        exactly what the monolithic prefill does for its remainder, but
        batched over every request in the same remainder group."""
        sub = self._gather_rows(pool, idx)
        logits = None
        for t in range(tokens.shape[1]):
            lg, sub = model_lib.decode_step(
                params, self.cfg, {"tokens": tokens[:, t:t + 1]}, sub,
                ctx=self.ctx)
            logits = lg[:, 0]
        return self._scatter_rows(pool, sub, idx), logits

    @staticmethod
    def _scrub_row_impl(pool: Dict, row: jax.Array) -> Dict:
        """Zero pool row `row` — cache leaves AND its position counter.
        Quarantine needs a real scrub, not the lengths-only reset: a
        faulty row may hold NaN/Inf, and unlike finite stale garbage a NaN
        would LEAK through the next occupant's additive attention masks
        (NaN + (-1e9) is still NaN)."""
        out = {}
        for k, v in pool.items():
            if k == "lengths":
                out[k] = v.at[row].set(0)
            else:
                zero = jnp.zeros_like(
                    jax.lax.dynamic_slice_in_dim(v, row, 1, axis=1))
                out[k] = jax.lax.dynamic_update_slice_in_dim(
                    v, zero, row, axis=1)
        return out

    @staticmethod
    def _corrupt_row_impl(pool: Dict, row: jax.Array, mode: str) -> Dict:
        """Fault-injection primitive: corrupt row `row`'s cache leaves in
        place. mode='nan' poisons with NaN (exercises the NaN guard);
        mode='garble' applies a finite, deterministic bit-change (models a
        silent device fault — wrong bytes, nothing for the guard to see).
        `lengths` is untouched: the row keeps decoding, just wrongly."""
        out = {}
        for k, v in pool.items():
            if k == "lengths":
                out[k] = v
                continue
            rowv = jax.lax.dynamic_slice_in_dim(v, row, 1, axis=1)
            if mode == "nan":
                upd = jnp.full_like(rowv, jnp.nan)
            elif mode == "garble":
                upd = rowv * jnp.asarray(-1.5, v.dtype) \
                    + jnp.asarray(0.25, v.dtype)
            else:
                raise ValueError(f"unknown corruption mode {mode!r}")
            out[k] = jax.lax.dynamic_update_slice_in_dim(v, upd, row, axis=1)
        return out

    @staticmethod
    def _reset_row_impl(pool: Dict, row: jax.Array) -> Dict:
        """Zero a row's position counter for incremental (chunked) prefill.
        Only `lengths` needs resetting: stale K/V from the slot's previous
        occupant is never visible — every mask is bounded by the row's
        committed length, and both the chunk fold and the decode-time ring
        write land before visibility reaches them."""
        out = dict(pool)
        out["lengths"] = pool["lengths"].at[row].set(0)
        if "page_table" in pool:
            # defensive: a reset paged row must never fold through a stale
            # table entry into a page that has since changed hands
            out["page_table"] = pool["page_table"].at[:, row].set(-1)
        return out

    # -- paged-pool internals (cache_format="paged") ----------------------

    def _write_slot_paged_impl(self, pool: Dict, slot: Dict, row: jax.Array,
                               tab: jax.Array) -> Dict:
        """Monolithic admission into a paged pool: quantize the request's
        dense B=1 slot cache — raw ring per (token, head), compressed slots
        per (block, head) — and scatter the block pages through `tab`, the
        row's new page table (block-ordered page ids, -1 past the prompt's
        committed blocks; -1 entries redirect their write to TRASH)."""
        pdt = pool["page_k"].dtype
        trash = pool["page_k"].shape[1] - 1
        out = dict(pool)
        for src, dq, ds in (("raw_k", "raw_k_q", "raw_k_s"),
                            ("raw_v", "raw_v_q", "raw_v_s")):
            q, s = cache_lib.quantize_blockwise(
                slot[src], axes=(4,), dtype=pdt, qmax=self._page_qmax)
            out[dq] = pool[dq].at[:, row].set(q[:, 0])
            out[ds] = pool[ds].at[:, row].set(s[:, 0])
        L, Np, r, Hkv, Dh = pool["page_k"].shape
        maxp = pool["page_table"].shape[2]
        dst = jnp.where(tab >= 0, tab, trash)
        for src, dq, ds in (("comp_k", "page_k", "page_k_s"),
                            ("comp_v", "page_v", "page_v_s")):
            blocks = slot[src][:, 0].reshape(L, maxp, r, Hkv, Dh)
            q, s = cache_lib.quantize_blockwise(
                blocks, axes=(2, 4), dtype=pdt, qmax=self._page_qmax)
            out[dq] = pool[dq].at[:, dst].set(q)
            out[ds] = pool[ds].at[:, dst].set(s)
        out["page_table"] = pool["page_table"].at[:, row].set(tab)
        out["lengths"] = pool["lengths"].at[row].set(slot["lengths"][0])
        return out

    @staticmethod
    def _gather_rows_paged(pool: Dict, idx: jax.Array) -> Dict:
        """Snapshot gather for a paged pool: per-row ring + lengths, plus
        the payload and scale of EVERY table entry (unallocated entries
        clip to page 0; `snapshot_pool_rows` slices to the committed page
        count before the snapshot leaves the engine, so those garbage
        reads are never part of a snapshot's bytes)."""
        g = {k: jnp.take(v, idx, axis=0 if k == "lengths" else 1)
             for k, v in pool.items() if k not in PAGED_ARENA_KEYS}
        Np = pool["page_k"].shape[1]
        safe = jnp.clip(g.pop("page_table")[0], 0, Np - 1)     # (g, maxp)
        g["pages_k"] = pool["page_k"][:, safe]      # (L, g, maxp, r, Hkv, Dh)
        g["pages_v"] = pool["page_v"][:, safe]
        g["pages_k_s"] = pool["page_k_s"][:, safe]  # (L, g, maxp, Hkv)
        g["pages_v_s"] = pool["page_v_s"][:, safe]
        return g

    @staticmethod
    def _restore_row_paged_impl(pool: Dict, sub: Dict, row: jax.Array,
                                tab: jax.Array) -> Dict:
        """Scatter a paged snapshot back into `row`: ring + lengths by row,
        page payloads+scales into the FRESH pages of `tab` (maxp-padded
        with zero pages aimed at TRASH). Physical placement is free to
        differ from capture — rows only ever reach pages through the
        table, so the resumed math (and token stream) is byte-identical."""
        trash = pool["page_k"].shape[1] - 1
        dst = jnp.where(tab >= 0, tab, trash)
        out = dict(pool)
        for k in ("raw_k_q", "raw_v_q", "raw_k_s", "raw_v_s"):
            out[k] = pool[k].at[:, row].set(sub[k][:, 0].astype(pool[k].dtype))
        for sk, pk in (("pages_k", "page_k"), ("pages_v", "page_v"),
                       ("pages_k_s", "page_k_s"), ("pages_v_s", "page_v_s")):
            out[pk] = pool[pk].at[:, dst].set(sub[sk].astype(pool[pk].dtype))
        out["page_table"] = pool["page_table"].at[:, row].set(tab)
        out["lengths"] = pool["lengths"].at[row].set(sub["lengths"][0])
        return out

    @staticmethod
    def _scrub_row_paged_impl(pool: Dict, row: jax.Array) -> Dict:
        """Paged quarantine scrub: zero the row's RING leaves (its only
        per-row payload — NaN scales would leak through a later occupant's
        additive masks exactly like NaN K/V), reset its counter, and clear
        its table. The row's arena pages are zeroed separately, by the
        allocator's scrub-before-reuse callback when they are freed."""
        out = dict(pool)
        for k in ("raw_k_q", "raw_v_q", "raw_k_s", "raw_v_s"):
            out[k] = pool[k].at[:, row].set(jnp.zeros((), pool[k].dtype))
        out["page_table"] = pool["page_table"].at[:, row].set(-1)
        out["lengths"] = pool["lengths"].at[row].set(0)
        return out

    @staticmethod
    def _corrupt_row_paged_impl(pool: Dict, row: jax.Array, dst: jax.Array,
                                mode: str) -> Dict:
        """Paged fault injection: corrupt the row's ring AND the pages its
        table owns (`dst`: block-ordered page ids, TRASH-padded). Integer
        payloads take a deterministic XOR bit-flip — NaN is a float
        concept, so in 'nan' mode the poison enters through the fp32
        SCALES, which the dequant multiplies into every attended value;
        float leaves keep the dense path's NaN fill / affine garble.
        `lengths` and the table are untouched: the row keeps decoding,
        just wrongly."""
        if mode not in ("nan", "garble"):
            raise ValueError(f"unknown corruption mode {mode!r}")

        def bad(x):
            if jnp.issubdtype(x.dtype, jnp.integer):
                return x if mode == "nan" \
                    else x ^ jnp.asarray(0x55, x.dtype)
            if mode == "nan":
                return jnp.full_like(x, jnp.nan)
            return x * jnp.asarray(-1.5, x.dtype) + jnp.asarray(0.25, x.dtype)

        out = dict(pool)
        for k in ("raw_k_q", "raw_v_q", "raw_k_s", "raw_v_s"):
            out[k] = pool[k].at[:, row].set(bad(pool[k][:, row]))
        for k in PAGED_ARENA_KEYS:
            out[k] = pool[k].at[:, dst].set(bad(pool[k][:, dst]))
        return out

    @staticmethod
    def _scrub_pages_impl(pool: Dict, ids: jax.Array) -> Dict:
        """Zero arena pages `ids` — payload AND scales: a freed page must
        never leak one request's KV bytes (or NaN) into the next tenant's
        math or snapshot."""
        out = dict(pool)
        for k in PAGED_ARENA_KEYS:
            out[k] = pool[k].at[:, ids].set(jnp.zeros((), pool[k].dtype))
        return out

    @staticmethod
    def _set_table_row_impl(pool: Dict, row: jax.Array,
                            tab: jax.Array) -> Dict:
        out = dict(pool)
        out["page_table"] = pool["page_table"].at[:, row].set(tab)
        return out

    def _pad_page_ids(self, page_ids: Sequence[int]) -> np.ndarray:
        """A row's block-ordered page ids as a fixed (maxp,) table row,
        -1-padded — one compile for every count."""
        maxp = self.max_pages_per_row()
        if len(page_ids) > maxp:
            raise ValueError(f"{len(page_ids)} pages exceed the table "
                             f"width {maxp}")
        tab = np.full((maxp,), -1, np.int32)
        tab[:len(page_ids)] = page_ids
        return tab

    # -- paged slot-pool surface (consumed by serving/scheduler.py) --------

    def write_pool_slot_paged(self, pool: Dict, slot_cache: Dict, row: int,
                              page_ids: Sequence[int]) -> Dict:
        """Paged monolithic admission (donates `pool`): quantize the B=1
        dense slot cache into `row`'s ring + the freshly allocated
        `page_ids` (one per committed prompt block, in block order)."""
        pool = self._write_slot_paged(
            pool, slot_cache, jnp.asarray(row, jnp.int32),
            jnp.asarray(self._pad_page_ids(page_ids)))
        return self.plan.place_cache(pool)

    def restore_pool_rows_paged(self, pool: Dict, sub: Dict, row: int,
                                page_ids: Sequence[int]) -> Dict:
        """Paged inverse of `snapshot_pool_rows` (donates `pool`): the
        snapshot's pages land in the freshly allocated `page_ids` (len ==
        the snapshot's committed page count)."""
        npv = len(page_ids)
        maxp = self.max_pages_per_row()
        pads = {}
        for k, v in sub.items():
            if k.startswith("pages_"):
                # repro-lint: allow[RL002] host snapshot leaves
                v = np.asarray(v)
                if v.shape[1] != npv:
                    raise ValueError(
                        f"snapshot holds {v.shape[1]} pages in {k} but "
                        f"{npv} pages were allocated")
                pad = np.zeros((v.shape[0], maxp - npv) + v.shape[2:],
                               v.dtype)
                pads[k] = jnp.asarray(np.concatenate([v, pad], axis=1))
            else:
                pads[k] = jnp.asarray(v)
        pool = self._restore_row_paged(
            pool, pads, jnp.asarray(row, jnp.int32),
            jnp.asarray(self._pad_page_ids(page_ids)))
        return self.plan.place_cache(pool)

    def scrub_arena_pages(self, pool: Dict, page_ids: Sequence[int]) -> Dict:
        """Zero arena pages (donates `pool`) — the PageAllocator's
        scrub-before-reuse callback. Ids are TRASH-padded to the table
        width so every free shares one compile (zeroing TRASH is
        harmless)."""
        if len(page_ids) == 0:
            return pool
        trash = int(pool["page_k"].shape[1]) - 1
        maxp = self.max_pages_per_row()
        ids = list(page_ids) + [trash] * (maxp - len(page_ids))
        pool = self._scrub_pages(pool, jnp.asarray(ids, jnp.int32))
        return self.plan.place_cache(pool)

    def write_table_row(self, pool: Dict, row: int,
                        page_ids: Sequence[int]) -> Dict:
        """Publish `row`'s page list to the device table (donates `pool`) —
        the on-demand growth step: the allocator appends pages on the host,
        then the whole block-ordered list is rewritten here (-1 past the
        end, so unallocated folds keep redirecting to TRASH)."""
        pool = self._set_table_row(
            pool, jnp.asarray(row, jnp.int32),
            jnp.asarray(self._pad_page_ids(page_ids)))
        return self.plan.place_cache(pool)

    def clear_table_row(self, pool: Dict, row: int) -> Dict:
        """Retirement (donates `pool`): point every future fold of the now
        idle, finished-masked row at TRASH before its pages return to the
        free list — a stale table entry over a re-allocated page would let
        a dead row write into a live tenant's KV bytes."""
        return self.write_table_row(pool, row, ())

    def corrupt_pool_row_paged(self, pool: Dict, row: int,
                               page_ids: Sequence[int], mode: str) -> Dict:
        """Paged fault-injection entry point: corrupt `row`'s ring and its
        owned pages (donates `pool`). mode: 'nan' | 'garble'."""
        tab = self._pad_page_ids(page_ids)
        trash = int(pool["page_k"].shape[1]) - 1
        dst = np.where(tab >= 0, tab, trash).astype(np.int32)
        pool = self._corrupt_row_paged(pool, jnp.asarray(row, jnp.int32),
                                       jnp.asarray(dst), mode)
        return self.plan.place_cache(pool)

    # -- slot-pool surface (consumed by serving/scheduler.py) -------------

    def init_pool_cache(self, max_batch: int) -> Dict:
        """A fresh (max_batch)-row pool cache, every slot idle at t=0.

        Chunked prefill allocates `prefill_chunk` tokens of SLACK beyond
        max_seq: a padded final chunk writes its full P-token window at the
        row's offset, and without slack a window crossing max_seq would be
        CLAMPED by dynamic_update_slice — shifting the write down over
        earlier, still-valid slots. The slack region only ever holds padding
        junk (budget checks cap real content at max_seq).

        Under a mesh the pool is laid out per the plan's cache specs —
        KV-head axis sharded over tensor parallelism, so the decode
        kernel's two pinned operands hold per-shard slots — and every
        donating consumer (decode scans, slot writes, prefill chunks)
        inherits that layout."""
        slack = self.prefill_chunk  # 0 in monolithic mode
        if self.paged:
            a = self.cfg.attention
            cache = cache_lib.init_paged_cache(
                num_layers=self.cfg.num_layers, batch=max_batch,
                max_seq=self.max_seq + slack,
                block_size=a.linformer.block_size,
                block_slots=a.linformer.block_slots,
                num_kv_heads=a.num_kv_heads, head_dim=a.head_dim,
                arena_pages=self.resolved_arena_pages(max_batch),
                page_dtype=self.page_dtype)
            return self.plan.place_cache(cache)
        cache = model_lib.init_cache(self.cfg, batch=max_batch,
                                     max_seq=self.max_seq + slack,
                                     dtype=self.cache_dtype)
        return self.plan.place_cache(cache)

    @staticmethod
    def _write_slot_impl(pool: Dict, slot: Dict, row: jax.Array) -> Dict:
        """Copy a B=1 cache into pool row `row`. Cache leaves are
        (L, B, ...) except the per-row `lengths` (B,)."""
        out = {}
        for key, v in pool.items():
            axis = 0 if key == "lengths" else 1
            out[key] = jax.lax.dynamic_update_slice_in_dim(
                v, slot[key].astype(v.dtype), row, axis=axis)
        return out

    def write_pool_slot(self, pool: Dict, slot_cache: Dict, row: int) -> Dict:
        """Admission write: donate `pool`, return it with `row` replaced by
        the request's prefilled cache (traced row index — one compile)."""
        return self._write_slot(pool, slot_cache, jnp.asarray(row, jnp.int32))

    def pool_chunk_fn(self, n: int) -> Callable:
        """The scheduler's decode-chunk entry point (donates the cache —
        call through the pool owner only)."""
        return self._chunk_fn(n)

    def prefill_request(self, tokens: Sequence[int], rng: jax.Array
                        ) -> Tuple[Dict, int]:
        """Prefill ONE request (B=1). Returns (slot cache positioned at the
        prompt length, first sampled token)."""
        arr = np.asarray([list(tokens)], np.int32)
        cache, logits = self.prefill(arr)
        # repro-lint: allow[RL002] first-token sync (B=1 path)
        first = int(np.asarray(self._sample(logits, rng))[0])
        return cache, first

    def reset_pool_row(self, pool: Dict, row: int) -> Dict:
        """Mark pool row `row` empty at t=0 for incremental prefill
        (donates `pool`; route through the SlotPool owner)."""
        return self._reset_row(pool, jnp.asarray(row, jnp.int32))

    def snapshot_pool_rows(self, pool: Dict, rows: Sequence[int],
                           pad_to: int) -> List[Dict]:
        """Host-side copies of pool rows `rows` (does NOT donate — the pool
        stays live): one padded gather (`_gather_rows`, rows duplicated to
        `pad_to` so every capture of a pool shares one compile) + one
        device_get, sliced into per-row B=1 sub-caches. Thanks to the
        compressed prefix each row is O(c + M) bytes, not O(n) — the
        low-rank-state property that makes preemption snapshots cheap."""
        g = len(rows)
        rows_p, _ = self._pad_rows(rows, pad_to=pad_to)
        idx = jnp.asarray(rows_p, jnp.int32)
        if not self.paged:
            # repro-lint: allow[RL002] snapshot pool->host copy
            sub = jax.device_get(self._snapshot_rows(pool, idx))
            return [{k: (v[j:j + 1] if k == "lengths" else v[:, j:j + 1])
                     for k, v in sub.items()} for j in range(g)]
        # Paged: the checksum covers the quantized ring AND pages AND every
        # scale leaf — any corrupt byte, payload or scale, fails verify().
        # repro-lint: allow[RL002] snapshot pool->host copy
        sub = jax.device_get(self._snapshot_rows_paged(pool, idx))
        c = self._block()
        out = []
        for j in range(g):
            # repro-lint: allow[RL002] host snapshot read
            npv = int(sub["lengths"][j]) // c   # committed (folded) pages
            d = {}
            for k, v in sub.items():
                if k == "lengths":
                    d[k] = v[j:j + 1]
                elif k.startswith("pages_"):
                    d[k] = v[:, j, :npv]
                else:
                    d[k] = v[:, j:j + 1]
            out.append(d)
        return out

    def restore_pool_rows(self, pool: Dict, sub: Dict, row: int) -> Dict:
        """Scatter a snapshot's B=1 sub-cache back into pool row `row`
        (donates `pool`) — the byte-exact inverse of `snapshot_pool_rows`.
        The result is re-placed per the attention plan so a mesh-sharded
        pool keeps its layout across a restore exactly as it does across
        donation round-trips."""
        pool = self._restore_rows(pool, sub,
                                  jnp.asarray([row], jnp.int32))
        return self.plan.place_cache(pool)

    def scrub_pool_row(self, pool: Dict, row: int) -> Dict:
        """Zero a quarantined row — cache leaves and position counter
        (donates `pool`; route through the SlotPool owner). Re-placed per
        the plan: the row-wise update gives the compiler no reason to keep
        the KV-head sharding, so the layout is pinned back explicitly."""
        fn = self._scrub_row_paged if self.paged else self._scrub_row
        pool = fn(pool, jnp.asarray(row, jnp.int32))
        return self.plan.place_cache(pool)

    def corrupt_pool_row(self, pool: Dict, row: int, mode: str) -> Dict:
        """Fault-injection entry point (serving/faults.py): corrupt row
        `row` in place (donates `pool`; re-placed like `scrub_pool_row`).
        mode: 'nan' | 'garble'."""
        pool = self._corrupt_row(pool, jnp.asarray(row, jnp.int32), mode)
        return self.plan.place_cache(pool)

    @staticmethod
    def _pad_rows(rows: Sequence[int], *arrays: np.ndarray, pad_to: int):
        """Pad a row batch to exactly `pad_to` BY DUPLICATING the last row
        (and the matching rows of every per-row array) — `.set` scatter
        writes the identical state twice, so the duplicate is harmless.
        The scheduler passes its pool size, so ONE compile serves every
        admission round of a pool, whatever the occupancy."""
        g = len(rows)
        if g == 0:
            raise ValueError("empty prefill row batch")
        if pad_to < g:
            raise ValueError(f"pad_to={pad_to} smaller than batch {g}")
        rows = list(rows) + [rows[-1]] * (pad_to - g)
        padded = [np.concatenate([a] + [a[-1:]] * (pad_to - g), axis=0)
                  for a in arrays]
        return rows, padded

    def pool_prefill_chunk(self, pool: Dict, rows: Sequence[int],
                           tokens: np.ndarray, n_valid: np.ndarray,
                           pad_to: int) -> Tuple[Dict, jax.Array]:
        """Advance rows' prefill by one padded chunk forward (donates
        `pool`). tokens: (g, prefill_chunk) int32, padded at the end;
        n_valid: (g,) real token counts. Rows are padded to `pad_to` (the
        pool size) by duplication (`_pad_rows`). Returns (pool, last-valid
        logits (g, V))."""
        g = len(rows)
        rows, (tokens, n_valid) = self._pad_rows(rows, tokens, n_valid,
                                                 pad_to=pad_to)
        pool, logits = self._pool_prefill_chunk(
            self.params, pool, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(n_valid, jnp.int32), jnp.asarray(rows, jnp.int32))
        return pool, logits[:g]

    def pool_prefill_remainder(self, pool: Dict, rows: Sequence[int],
                               tokens: np.ndarray,
                               pad_to: int) -> Tuple[Dict, jax.Array]:
        """Feed rows' final sub-block remainder tokens ((g, rem), rem <
        block size) through batched decode steps (donates `pool`). Same
        row padding as `pool_prefill_chunk`. Returns (pool, final-token
        logits (g, V))."""
        g = len(rows)
        rows, (tokens,) = self._pad_rows(rows, tokens, pad_to=pad_to)
        pool, logits = self._pool_prefill_remainder(
            self.params, pool, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(rows, jnp.int32))
        return pool, logits[:g]

    # -- public API -------------------------------------------------------

    def generate_batch(self, tokens: np.ndarray, max_new_tokens: int,
                       rng: Optional[jax.Array] = None) -> np.ndarray:
        """Greedy/temperature generation for one equal-length batch.
        tokens: (B, S) int array. Returns (B, max_new_tokens).

        Decodes in device-resident `decode_chunk`-token scans: one host sync
        per chunk (fetch tokens + all-finished early exit) instead of one per
        generated token."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache, logits = self.prefill(tokens)
        return self.decode_tokens(cache, logits, max_new_tokens, rng)

    def decode_tokens(self, cache: Dict, logits: jax.Array,
                      max_new_tokens: int,
                      rng: Optional[jax.Array] = None) -> np.ndarray:
        """Decode phase given a prefilled cache and last-token logits.
        NOTE: the chunk scan donates `cache` — it is consumed. Long-lived
        callers that must survive donation (the scheduler) own their cache
        through scheduler.SlotPool instead of calling this."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        B = logits.shape[0]
        outs = np.full((B, max_new_tokens), EOS, np.int32)
        finished = jnp.zeros((B,), bool)
        cur = self._sample(logits, rng)
        done = 0
        while done < max_new_tokens:
            n = min(self.decode_chunk, max_new_tokens - done)
            toks, cur, finished, _bad, cache, rng = self._chunk_fn(n)(
                self.params, cur, finished, cache, rng)
            # repro-lint: allow[RL002] the chunk's one sync
            outs[:, done:done + n] = np.asarray(toks)
            done += n
            # repro-lint: allow[RL002] rides the chunk's single sync boundary
            if bool(np.asarray(finished).all()):
                break
        return outs

    def generate_batch_per_token(self, tokens: np.ndarray,
                                 max_new_tokens: int,
                                 rng: Optional[jax.Array] = None
                                 ) -> np.ndarray:
        """Legacy per-token decode loop (one host round-trip per token) —
        kept as the measured baseline for benchmarks/decode_throughput.py."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache, logits = self.prefill(tokens)
        return self.decode_tokens_per_token(cache, logits, max_new_tokens,
                                            rng)

    def decode_tokens_per_token(self, cache: Dict, logits: jax.Array,
                                max_new_tokens: int,
                                rng: Optional[jax.Array] = None
                                ) -> np.ndarray:
        """Per-token decode phase (baseline counterpart of decode_tokens)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        B = logits.shape[0]
        outs = np.zeros((B, max_new_tokens), np.int32)
        finished = jnp.zeros((B,), bool)
        cur = self._sample(logits, rng)
        for i in range(max_new_tokens):
            cur = jnp.where(finished, EOS, cur)
            # repro-lint: allow[RL002] per-token baseline loop
            outs[:, i] = np.asarray(cur)
            finished = finished | (cur == EOS)
            # repro-lint: allow[RL002] per-token baseline loop
            if bool(finished.all()):
                outs[:, i + 1:] = EOS
                break
            rng, sub = jax.random.split(rng)
            logits_t, cache = self._decode(
                self.params, {"tokens": cur[:, None].astype(jnp.int32)}, cache)
            cur = self._sample(logits_t[:, 0], sub)
        return outs

    @property
    def supports_continuous_batching(self) -> bool:
        """Slot scheduling needs per-row position counters, which only the
        transformer-family caches carry; ssm/hybrid caches share a scalar
        position (and recurrent state writes are not yet per-row)."""
        return self.cfg.family in model_lib._TRANSFORMER_FAMILIES

    def _check_budgets(self, prompts, budgets) -> None:
        for i, p in enumerate(prompts):
            if len(p) == 0:
                # fail fast: there are no logits to sample a first token
                # from (and a zero-token PREFILLING slot would never
                # activate, deadlocking the chunked scheduler)
                raise ValueError(f"request {i}: empty prompt")
            if budgets[i] <= 0:
                raise ValueError(f"request {i}: max_new_tokens="
                                 f"{budgets[i]} must be positive")
            if len(p) + budgets[i] > self.max_seq:
                raise ValueError(
                    f"request {i}: prompt {len(p)} + budget {budgets[i]} "
                    f"exceeds max_seq={self.max_seq}")

    def serve(self, prompts: Sequence[Sequence[int]],
              max_new_tokens: Union[int, Sequence[int]],
              max_batch: int = 8,
              *,
              arrival_chunks: Optional[Sequence[int]] = None,
              priorities: Optional[Sequence[int]] = None,
              deadlines: Optional[Sequence[Optional[int]]] = None,
              max_queue: Optional[int] = None,
              max_retries: int = 2,
              snapshot_chunks: int = 0,
              nan_guard: bool = True,
              fault_injector=None,
              on_token: Optional[Callable[[int, int], None]] = None,
              on_complete: Optional[Callable[[int, List[int]], None]] = None,
              rng: Optional[jax.Array] = None,
              return_scheduler: bool = False,
              telemetry=None):
        """Serve arbitrary mixed-length requests with slot-based continuous
        batching: a `max_batch`-slot pool, admission/retirement between
        decode chunks (serving/scheduler.py).

        `max_new_tokens` may be one int or a per-request sequence;
        `arrival_chunks` optionally replays an arrival trace (request i
        admissible after that much virtual time, in chunk units).

        SLO knobs (all default to the plain FCFS behavior): `priorities`
        (per-request class, lower = more urgent — a strictly more urgent
        arrival preempts the least-urgent running slot), `deadlines`
        (per-request absolute deadline in ticks, None = none), `max_queue`
        (bounded admission queue — overflow sheds the least-valued entry),
        `max_retries` + `snapshot_chunks` (fault recovery: retry budget and
        last-good-snapshot refresh period), `nan_guard` (quarantine rows
        whose logits go non-finite), `fault_injector` (serving/faults.py).
        A shed request's output is a `ShedResult` instead of a token list.

        `telemetry` overrides the engine's `Telemetry` facade for this run
        (span trace, per-request timelines, per-priority SLO histograms —
        docs/observability.md); None uses the engine's own, which defaults
        to the disabled no-op singleton.

        `on_token`/`on_complete` stream per-request progress. Returns
        outputs ordered like `prompts` (or (outputs, scheduler) with
        return_scheduler=True, for stats).

        Model families whose cache has no per-row position counters
        (ssm/hybrid) fall back to the static bucketed scheduler; streaming
        callbacks then fire after each bucket completes."""
        budgets = _per_request_max_new(max_new_tokens, len(prompts))
        slo = (priorities is not None or deadlines is not None
               or max_queue is not None or fault_injector is not None
               or snapshot_chunks)
        if not self.supports_continuous_batching:
            if return_scheduler or arrival_chunks is not None or slo:
                raise ValueError(
                    f"family {self.cfg.family!r} has a shared-scalar cache: "
                    "no continuous scheduler (serve falls back to the "
                    "static bucketed path, which has no scheduler stats, "
                    "no SLO/fault handling, and cannot replay an arrival "
                    "trace)")
            outputs = self.serve_static(prompts, budgets,
                                        max_batch=max_batch)
            for i, out in enumerate(outputs):
                if on_token is not None:
                    for tok in out:
                        on_token(i, tok)
                if on_complete is not None:
                    on_complete(i, out)
            return outputs
        from repro.serving.scheduler import Request, Scheduler
        n = len(prompts)
        arrivals = list(arrival_chunks) if arrival_chunks is not None \
            else [0] * n
        prios = list(priorities) if priorities is not None else [0] * n
        dls = list(deadlines) if deadlines is not None else [None] * n
        for name, seq in (("arrival_chunks", arrivals),
                          ("priorities", prios), ("deadlines", dls)):
            if len(seq) != n:
                raise ValueError(f"{name} has {len(seq)} entries "
                                 f"for {n} prompts")
        self._check_budgets(prompts, budgets)
        tel = telemetry if telemetry is not None else self.telemetry
        self._record_plan_attribution(tel)
        sched = Scheduler(self, max_batch, rng=rng, max_queue=max_queue,
                          max_retries=max_retries,
                          snapshot_chunks=snapshot_chunks,
                          nan_guard=nan_guard,
                          fault_injector=fault_injector,
                          telemetry=tel)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, tokens=tuple(p),
                                 max_new_tokens=budgets[i],
                                 arrival_chunk=arrivals[i],
                                 priority=prios[i],
                                 deadline_ticks=dls[i]))
        with tel.span("serve", cat="engine", n_requests=n,
                      max_batch=max_batch):
            results = sched.run(on_token=on_token, on_complete=on_complete)
        self._note_table_stats(tel)
        outputs = [results[i] for i in range(n)]
        if return_scheduler:
            return outputs, sched
        return outputs

    def serve_static(self, prompts: Sequence[Sequence[int]],
                     max_new_tokens: Union[int, Sequence[int]],
                     max_batch: int = 8) -> List[List[int]]:
        """Static bucketed baseline: bucket by equal prompt length, decode
        each bucket to its LONGEST request budget (short requests pad out
        long ones — the waste continuous batching removes)."""
        budgets = _per_request_max_new(max_new_tokens, len(prompts))
        self._check_budgets(prompts, budgets)
        results: List[Optional[List[int]]] = [None] * len(prompts)
        for bucket in bucket_requests(prompts, max_batch):
            toks = np.asarray([list(prompts[i]) for i in bucket], np.int32)
            n = max(budgets[i] for i in bucket)
            gen = self.generate_batch(toks, n)
            for row, i in enumerate(bucket):
                out = gen[row, :budgets[i]].tolist()
                if EOS in out:
                    out = out[:out.index(EOS)]
                results[i] = out
        return results  # type: ignore

    def cache_bytes(self, batch: int) -> int:
        """Decode-cache footprint (the paper's memory claim, measurable).
        In paged mode this is the quantized pool: ring + scales + page
        arena (`arena_pages`, or the capacity-equivalent default) + table —
        the denominator of the capacity benchmark's equal-bytes pools."""
        if self.paged:
            a = self.cfg.attention
            spec = cache_lib.paged_cache_spec(
                num_layers=self.cfg.num_layers, batch=batch,
                max_seq=self.max_seq,
                block_size=a.linformer.block_size,
                block_slots=a.linformer.block_slots,
                num_kv_heads=a.num_kv_heads, head_dim=a.head_dim,
                arena_pages=self.arena_pages, page_dtype=self.page_dtype)
            return sum(int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
                       for v in spec.values())
        cache = model_lib.init_cache(self.cfg, batch=batch,
                                     max_seq=self.max_seq,
                                     dtype=self.cache_dtype)
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
