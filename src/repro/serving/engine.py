"""Batched serving engine: parallel prefill + device-resident chunked decode.

Prefill strategy (linformer_causal): the full-block prefix (⌊S/c⌋·c tokens)
is prefilled in ONE parallel forward that also materializes the compressed
cache; the ≤c-1 remainder tokens run through the decode path. Standard
attention prefills the full prompt in one pass.

Chunked decode contract: generation runs as jitted `lax.scan` chunks of
`decode_chunk` tokens (model.decode_scan) — sampling, EOS masking, and the
cache update all stay on device, and the host syncs ONCE per chunk (to
receive the chunk's tokens and check the all-finished early exit) instead of
once per token. The per-token Python loop that this replaces is kept as
`generate_batch_per_token` — the measured baseline of
benchmarks/decode_throughput.py. The final partial chunk compiles a second
scan length at most; chunk functions are cached per length.

Batching model: requests are grouped into equal-prompt-length buckets by the
scheduler (`bucket_requests`); each bucket decodes together with a shared
position counter. EOS'd rows keep decoding but their outputs are frozen
(finished mask) — the standard static-batching scheme.

The decode-time win of the paper's technique shows up here as cache size:
c + r·S/c slots instead of S (≈14× at 32k, ≈16× at 512k) — see
benchmarks/table3_efficiency.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import EOS
from repro.models import model as model_lib
from repro.parallel.sharding import ParallelCtx


def bucket_requests(prompts: Sequence[Sequence[int]], max_batch: int
                    ) -> List[List[int]]:
    """Group request indices into equal-length buckets of ≤ max_batch."""
    by_len: Dict[int, List[int]] = {}
    for i, p in enumerate(prompts):
        by_len.setdefault(len(p), []).append(i)
    buckets = []
    for _, idxs in sorted(by_len.items()):
        for j in range(0, len(idxs), max_batch):
            buckets.append(idxs[j:j + max_batch])
    return buckets


class ServingEngine:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        max_seq: int,
        ctx: Optional[ParallelCtx] = None,
        cache_dtype=jnp.bfloat16,
        temperature: float = 0.0,
        decode_chunk: int = 32,
        attention_backend: Optional[str] = None,
    ):
        if attention_backend is not None:
            cfg = cfg.with_attention_backend(attention_backend)
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.ctx = ctx
        self.cache_dtype = cache_dtype
        self.temperature = temperature
        self.decode_chunk = max(1, decode_chunk)

        self._decode = jax.jit(
            lambda p, b, c: model_lib.decode_step(p, cfg, b, c, ctx=ctx))
        self._prefill = jax.jit(
            lambda p, b: model_lib.forward(
                p, cfg, b, ctx=ctx, return_cache=True,
                cache_max_seq=max_seq, cache_dtype=cache_dtype),
        )
        self._chunk_fns: Dict[int, Callable] = {}

    # -- internals ------------------------------------------------------

    def _block(self) -> int:
        a = self.cfg.attention
        if a.kind == "linformer_causal":
            return a.linformer.block_size
        return 1

    def _sample(self, logits: jax.Array, rng) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / self.temperature, axis=-1)

    def prefill(self, tokens: np.ndarray) -> Tuple[Dict, jax.Array]:
        """tokens: (B, S) prompt. Returns (cache at t=S, last-token logits)."""
        B, S = tokens.shape
        c = self._block()
        nfull = (S // c) * c
        if nfull == 0:
            cache = model_lib.init_cache(self.cfg, batch=B,
                                         max_seq=self.max_seq,
                                         dtype=self.cache_dtype)
            logits = None
        else:
            batch = {"tokens": jnp.asarray(tokens[:, :nfull])}
            logits_all, _, cache = self._prefill(self.params, batch)
            logits = logits_all[:, -1]
        for t in range(nfull, S):
            logits_t, cache = self._decode(
                self.params, {"tokens": jnp.asarray(tokens[:, t:t + 1])},
                cache)
            logits = logits_t[:, 0]
        return cache, logits

    def _chunk_fn(self, n: int) -> Callable:
        """Jitted n-step device-resident decode (cached per scan length)."""
        fn = self._chunk_fns.get(n)
        if fn is None:
            cfg, ctx, temp = self.cfg, self.ctx, self.temperature
            fn = jax.jit(
                lambda p, cur, fin, cache, rng: model_lib.decode_scan(
                    p, cfg, cur, fin, cache, rng, n_steps=n, eos_id=EOS,
                    temperature=temp, ctx=ctx),
                donate_argnums=(3,))
            self._chunk_fns[n] = fn
        return fn

    # -- public API -------------------------------------------------------

    def generate_batch(self, tokens: np.ndarray, max_new_tokens: int,
                       rng: Optional[jax.Array] = None) -> np.ndarray:
        """Greedy/temperature generation for one equal-length batch.
        tokens: (B, S) int array. Returns (B, max_new_tokens).

        Decodes in device-resident `decode_chunk`-token scans: one host sync
        per chunk (fetch tokens + all-finished early exit) instead of one per
        generated token."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache, logits = self.prefill(tokens)
        return self.decode_tokens(cache, logits, max_new_tokens, rng)

    def decode_tokens(self, cache: Dict, logits: jax.Array,
                      max_new_tokens: int,
                      rng: Optional[jax.Array] = None) -> np.ndarray:
        """Decode phase given a prefilled cache and last-token logits.
        NOTE: the chunk scan donates `cache` — it is consumed."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        B = logits.shape[0]
        outs = np.full((B, max_new_tokens), EOS, np.int32)
        finished = jnp.zeros((B,), bool)
        cur = self._sample(logits, rng)
        done = 0
        while done < max_new_tokens:
            n = min(self.decode_chunk, max_new_tokens - done)
            toks, cur, finished, cache, rng = self._chunk_fn(n)(
                self.params, cur, finished, cache, rng)
            outs[:, done:done + n] = np.asarray(toks)   # the chunk's one sync
            done += n
            if bool(np.asarray(finished).all()):
                break
        return outs

    def generate_batch_per_token(self, tokens: np.ndarray,
                                 max_new_tokens: int,
                                 rng: Optional[jax.Array] = None
                                 ) -> np.ndarray:
        """Legacy per-token decode loop (one host round-trip per token) —
        kept as the measured baseline for benchmarks/decode_throughput.py."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache, logits = self.prefill(tokens)
        return self.decode_tokens_per_token(cache, logits, max_new_tokens,
                                            rng)

    def decode_tokens_per_token(self, cache: Dict, logits: jax.Array,
                                max_new_tokens: int,
                                rng: Optional[jax.Array] = None
                                ) -> np.ndarray:
        """Per-token decode phase (baseline counterpart of decode_tokens)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        B = logits.shape[0]
        outs = np.zeros((B, max_new_tokens), np.int32)
        finished = jnp.zeros((B,), bool)
        cur = self._sample(logits, rng)
        for i in range(max_new_tokens):
            cur = jnp.where(finished, EOS, cur)
            outs[:, i] = np.asarray(cur)
            finished = finished | (cur == EOS)
            if bool(finished.all()):
                outs[:, i + 1:] = EOS
                break
            rng, sub = jax.random.split(rng)
            logits_t, cache = self._decode(
                self.params, {"tokens": cur[:, None].astype(jnp.int32)}, cache)
            cur = self._sample(logits_t[:, 0], sub)
        return outs

    def serve(self, prompts: Sequence[Sequence[int]], max_new_tokens: int,
              max_batch: int = 8) -> List[List[int]]:
        """Schedule arbitrary requests: bucket by length, batch, generate."""
        results: List[Optional[List[int]]] = [None] * len(prompts)
        for bucket in bucket_requests(prompts, max_batch):
            toks = np.asarray([list(prompts[i]) for i in bucket], np.int32)
            gen = self.generate_batch(toks, max_new_tokens)
            for row, i in enumerate(bucket):
                out = gen[row].tolist()
                if EOS in out:
                    out = out[:out.index(EOS)]
                results[i] = out
        return results  # type: ignore

    def cache_bytes(self, batch: int) -> int:
        """Decode-cache footprint (the paper's memory claim, measurable)."""
        cache = model_lib.init_cache(self.cfg, batch=batch,
                                     max_seq=self.max_seq,
                                     dtype=self.cache_dtype)
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
