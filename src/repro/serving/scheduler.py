"""Slot-based continuous-batching scheduler over the device-resident decode
loop.

Admission/eviction contract
---------------------------

The unit of work is a *slot*: one row of a fixed (max_batch)-row pool cache.
The scheduler mutates the pool ONLY between decode chunks:

* **Admission** — a queued request whose arrival time has passed is prefilled
  alone (B=1, its own forward), its cache rows are `dynamic_update_slice`d
  into the pool at a free slot, its first sampled token becomes the slot's
  `cur`, and its per-row position counter (`cache["lengths"][slot]`) is set
  to the prompt length. Admission never perturbs live rows: every cache
  write, rope position, attention mask and block fold is per-row
  (core/cache.py), so a slot's math is identical whether its neighbours are
  mid-request, freshly admitted, or idle.
* **Decode** — the pool decodes `decode_chunk` tokens as one jitted
  `lax.scan` (model.decode_scan): ONE host sync per chunk. Idle slots ride
  along `finished`-masked (their outputs are frozen to EOS and their
  position counters do not advance).
* **Eviction / retirement** — after the chunk's host sync, each live slot's
  tokens are scanned: an EOS or an exhausted per-request `max_new_tokens`
  budget retires the slot (completion callback fires; the slot is free for
  the next admission round). Tokens a row produced past its retirement point
  are discarded — they never reach the request's output, and the slot's
  cache rows are fully overwritten by the next admission.

The pool cache has a single owner (`SlotPool`): the chunk scan donates the
cache buffers, so `SlotPool` swaps in the returned cache each chunk and no
other live reference can dangle (the donation-safety contract the serving
engine relies on).

Determinism: greedy decode of a request depends only on its own prompt —
per-row masks make every row's attention independent of its neighbours — so
continuous scheduling produces byte-identical outputs to the static bucketed
baseline (`ServingEngine.serve_static`), under any arrival order and any
pool size (tests/test_serving_scheduler.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import EOS


@dataclasses.dataclass
class Request:
    """One generation request.

    `arrival_chunk`: the request becomes admissible once that much virtual
    time has passed (executed decode chunks + idle ticks, `stats.ticks`) —
    the replay knob for arrival traces (benchmarks/serving_throughput.py);
    0 = available immediately.
    """

    rid: int
    tokens: Tuple[int, ...]
    max_new_tokens: int
    arrival_chunk: int = 0


@dataclasses.dataclass
class _Slot:
    request: Request
    emitted: List[int]


@dataclasses.dataclass
class ScheduleStats:
    chunks: int = 0                    # decode chunks actually executed
    idle_ticks: int = 0                # empty-pool ticks (no decode ran)
    row_steps: int = 0                 # occupied-slot decode steps
    occupancy_sum: float = 0.0         # Σ per-executed-chunk occupied frac

    @property
    def ticks(self) -> int:
        """Virtual time: executed chunks + idle ticks (arrival clock)."""
        return self.chunks + self.idle_ticks

    @property
    def mean_occupancy(self) -> float:
        """Mean occupied fraction over EXECUTED chunks (idle ticks, where
        nothing decoded, are excluded)."""
        return self.occupancy_sum / max(self.chunks, 1)


class SlotPool:
    """Sole owner of the live pool cache + per-slot decode state.

    All jitted mutations (slot writes, chunk scans) donate the cache and the
    pool swaps in the result, so external references can never observe a
    donated buffer.
    """

    def __init__(self, engine, max_batch: int):
        self.engine = engine
        self.max_batch = max_batch
        self.cache = engine.init_pool_cache(max_batch)
        if "lengths" not in self.cache:
            raise ValueError(
                "continuous batching needs per-row position counters "
                "(cache['lengths']); this model family has a shared scalar "
                "cache — use serve_static")
        self.cur = np.full((max_batch,), EOS, np.int32)
        self.finished = np.ones((max_batch,), bool)
        self.slots: List[Optional[_Slot]] = [None] * max_batch

    # -- slot table ------------------------------------------------------

    def free_rows(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def occupancy(self) -> int:
        return sum(s is not None for s in self.slots)

    # -- mutations (between chunks only) ---------------------------------

    def admit(self, row: int, request: Request, slot_cache: Dict,
              first_token: int) -> None:
        """Write a prefilled request into `row`. `slot_cache` is a B=1 cache
        positioned at the prompt length; `first_token` the token sampled
        from the prefill logits (the row's first emitted token)."""
        self.cache = self.engine.write_pool_slot(self.cache, slot_cache, row)
        self.cur[row] = first_token
        self.finished[row] = False
        self.slots[row] = _Slot(request=request, emitted=[])

    def retire(self, row: int) -> None:
        self.slots[row] = None
        self.cur[row] = EOS
        self.finished[row] = True

    def decode_chunk(self, n: int, rng: jax.Array
                     ) -> Tuple[np.ndarray, jax.Array]:
        """Run one n-step device-resident decode chunk over the pool.
        Returns (tokens (max_batch, n), next rng). The chunk scan donates
        the pool cache; the returned cache replaces it atomically."""
        toks, cur, finished, cache, rng = self.engine.pool_chunk_fn(n)(
            self.engine.params, jnp.asarray(self.cur),
            jnp.asarray(self.finished), self.cache, rng)
        self.cache = cache
        self.cur = np.array(cur)            # writable host copies
        self.finished = np.array(finished)
        return np.asarray(toks), rng


class Scheduler:
    """FCFS continuous-batching scheduler: admit into free slots between
    decode chunks, retire on EOS / per-request token budget, stream
    completions. See the module docstring for the full contract."""

    def __init__(self, engine, max_batch: int,
                 rng: Optional[jax.Array] = None):
        self.engine = engine
        self.pool = SlotPool(engine, max_batch)
        self.queue: deque[Request] = deque()
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.stats = ScheduleStats()

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    # -- internals -------------------------------------------------------

    def _admit_ready(self) -> None:
        """Fill free slots with arrived requests (FCFS; later-arriving
        requests never jump the queue)."""
        free = self.pool.free_rows()
        while free and self.queue \
                and self.queue[0].arrival_chunk <= self.stats.ticks:
            req = self.queue.popleft()
            self.rng, sub = jax.random.split(self.rng)
            slot_cache, first = self.engine.prefill_request(req.tokens, sub)
            self.pool.admit(free.pop(0), req, slot_cache, first)

    def _drain_chunk(self, toks: np.ndarray,
                     on_token: Optional[Callable[[int, int], None]],
                     on_complete: Optional[Callable[[int, List[int]], None]],
                     results: Dict[int, List[int]]) -> None:
        """Distribute a chunk's tokens to their requests; retire EOS'd /
        budget-exhausted slots."""
        for row in range(self.pool.max_batch):
            slot = self.pool.slots[row]
            if slot is None:
                continue
            done = False
            budget = slot.request.max_new_tokens
            for tok in toks[row].tolist():
                # budget check BEFORE appending: a ≤0 budget emits nothing
                # (matching serve_static's gen[row, :0] truncation)
                if tok == EOS or len(slot.emitted) >= budget:
                    done = True
                    break
                slot.emitted.append(tok)
                if on_token is not None:
                    on_token(slot.request.rid, tok)
            if len(slot.emitted) >= budget:
                done = True
            if done:
                results[slot.request.rid] = slot.emitted
                if on_complete is not None:
                    on_complete(slot.request.rid, slot.emitted)
                self.pool.retire(row)

    # -- main loop -------------------------------------------------------

    def run(self,
            on_token: Optional[Callable[[int, int], None]] = None,
            on_complete: Optional[Callable[[int, List[int]], None]] = None,
            ) -> Dict[int, List[int]]:
        """Drive the pool until every submitted request completes. Returns
        {rid: tokens} (tokens exclude EOS, capped at max_new_tokens)."""
        results: Dict[int, List[int]] = {}
        chunk = self.engine.decode_chunk
        while self.queue or self.pool.occupancy:
            self._admit_ready()
            if not self.pool.occupancy:
                # nothing live yet: let virtual time pass so future
                # arrival_chunk requests become admissible
                self.stats.idle_ticks += 1
                continue
            toks, self.rng = self.pool.decode_chunk(chunk, self.rng)
            self.stats.chunks += 1
            self.stats.row_steps += self.pool.occupancy * chunk
            self.stats.occupancy_sum += self.pool.occupancy \
                / self.pool.max_batch
            self._drain_chunk(toks, on_token, on_complete, results)
        return results
