"""Slot-based continuous-batching scheduler over the device-resident decode
loop.

Admission/eviction contract
---------------------------

The unit of work is a *slot*: one row of a fixed (max_batch)-row pool cache.
The scheduler mutates the pool ONLY between decode chunks:

* **Admission** — a queued request whose arrival time has passed claims a
  free slot. With `engine.prefill_chunk == 0` (monolithic) it is prefilled
  alone (B=1, its own forward), its cache rows are `dynamic_update_slice`d
  into the pool, its first sampled token becomes the slot's `cur`, and its
  per-row position counter (`cache["lengths"][slot]`) is set to the prompt
  length. With `engine.prefill_chunk > 0` (chunked) the slot is claimed in
  the PREFILLING state at t=0 and the prompt streams into the pool cache
  one fixed-size chunk per scheduler round, interleaved with everyone
  else's decode chunks — a 32k-token prompt can no longer stall the pool
  for a full forward — and every PREFILLING row's next chunk rides ONE
  padded, batched forward (batched admission prefill; per-row offsets and
  valid-token counts are traced, so one compile covers any mix of lengths
  and progress). Admission never perturbs live rows: every cache write,
  rope position, attention mask and block fold is per-row (core/cache.py),
  so a slot's math is identical whether its neighbours are mid-request,
  mid-prefill, freshly admitted, or idle.
* **Decode** — the pool decodes `decode_chunk` tokens as one jitted
  `lax.scan` (model.decode_scan): ONE host sync per chunk. Idle and
  PREFILLING slots ride along `finished`-masked (their outputs are frozen
  to EOS and their position counters do not advance; a PREFILLING row's
  masked ring-buffer writes land at pos 0 of a block the remainder/decode
  path rewrites before any mask can see it).
* **Eviction / retirement** — after the chunk's host sync, each live slot's
  tokens are scanned: an EOS or an exhausted per-request `max_new_tokens`
  budget retires the slot (completion callback fires; the slot is free for
  the next admission round). Tokens a row produced past its retirement point
  are discarded — they never reach the request's output, and the next
  admission makes the slot's stale cache contents unreachable (monolithic:
  a full row overwrite; chunked: a lengths reset — every mask is bounded
  by the row's committed length, and writes land before visibility).

The pool cache has a single owner (`SlotPool`): the chunk scan donates the
cache buffers, so `SlotPool` swaps in the returned cache each chunk and no
other live reference can dangle (the donation-safety contract the serving
engine relies on).

Determinism: greedy decode of a request depends only on its own prompt —
per-row masks make every row's attention independent of its neighbours — so
continuous scheduling produces byte-identical outputs to the static bucketed
baseline (`ServingEngine.serve_static`), under any arrival order and any
pool size (tests/test_serving_scheduler.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import EOS


@dataclasses.dataclass
class Request:
    """One generation request.

    `arrival_chunk`: the request becomes admissible once that much virtual
    time has passed (executed decode chunks + idle ticks, `stats.ticks`) —
    the replay knob for arrival traces (benchmarks/serving_throughput.py);
    0 = available immediately.
    """

    rid: int
    tokens: Tuple[int, ...]
    max_new_tokens: int
    arrival_chunk: int = 0


# Slot states. A monolithically-admitted slot is born DECODING; under
# chunked admission (engine.prefill_chunk > 0) a slot is born PREFILLING —
# its prompt enters the pool cache one fixed-size chunk per scheduler round,
# interleaved with everyone else's decode chunks — and flips to DECODING
# when its first token is sampled. PREFILLING survives across rounds: the
# partial-prefill state is the row's cache contents + `_Slot.filled`.
PREFILLING = "prefilling"
DECODING = "decoding"


@dataclasses.dataclass
class _Slot:
    request: Request
    emitted: List[int]
    state: str = DECODING
    filled: int = 0                    # prompt tokens committed to the cache


@dataclasses.dataclass
class ScheduleStats:
    chunks: int = 0                    # decode chunks actually executed
    idle_ticks: int = 0                # no-decode ticks (pool empty or
    #                                    every occupied slot still prefilling)
    row_steps: int = 0                 # DECODING-slot decode steps
    occupancy_sum: float = 0.0         # Σ per-executed-chunk occupied frac
    #                                    (DECODING + PREFILLING slots — a
    #                                    prefilling row holds its slot)
    prefill_forwards: int = 0          # prefill launches (chunked: batched
    #                                    chunk/remainder; monolithic: one
    #                                    B=1 forward per admission)
    prefill_tokens: int = 0            # real (unpadded) prompt tokens filled

    @property
    def ticks(self) -> int:
        """Virtual time: executed chunks + idle ticks (arrival clock)."""
        return self.chunks + self.idle_ticks

    @property
    def mean_occupancy(self) -> float:
        """Mean occupied fraction over EXECUTED chunks (idle ticks, where
        nothing decoded, are excluded)."""
        return self.occupancy_sum / max(self.chunks, 1)


class SlotPool:
    """Sole owner of the live pool cache + per-slot decode state.

    All jitted mutations (slot writes, chunk scans) donate the cache and the
    pool swaps in the result, so external references can never observe a
    donated buffer. Under a mesh the cache arrives from
    `engine.init_pool_cache` already laid out per the engine's
    AttentionPlan (KV-head axis sharded over tensor parallelism — per-shard
    slots for the decode kernel's pinned operands); donation round-trips
    preserve that layout, so the pool stays sharded for its whole life
    without the scheduler knowing a mesh exists.
    """

    def __init__(self, engine, max_batch: int):
        self.engine = engine
        self.max_batch = max_batch
        self.cache = engine.init_pool_cache(max_batch)
        if "lengths" not in self.cache:
            raise ValueError(
                "continuous batching needs per-row position counters "
                "(cache['lengths']); this model family has a shared scalar "
                "cache — use serve_static")
        self.cur = np.full((max_batch,), EOS, np.int32)
        self.finished = np.ones((max_batch,), bool)
        self.slots: List[Optional[_Slot]] = [None] * max_batch

    # -- slot table ------------------------------------------------------

    def free_rows(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def occupancy(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def decoding_count(self) -> int:
        return sum(s is not None and s.state == DECODING for s in self.slots)

    # -- mutations (between chunks only) ---------------------------------

    def admit(self, row: int, request: Request, slot_cache: Dict,
              first_token: int) -> None:
        """Monolithic admission: write a fully-prefilled request into `row`.
        `slot_cache` is a B=1 cache positioned at the prompt length;
        `first_token` the token sampled from the prefill logits (the row's
        first emitted token)."""
        self.cache = self.engine.write_pool_slot(self.cache, slot_cache, row)
        self.cur[row] = first_token
        self.finished[row] = False
        self.slots[row] = _Slot(request=request, emitted=[], state=DECODING,
                                filled=len(request.tokens))

    def begin_prefill(self, row: int, request: Request) -> None:
        """Chunked admission: claim `row` in the PREFILLING state at t=0.
        The row rides subsequent decode chunks finished-masked (its position
        counter frozen, its outputs discarded) while `prefill_chunk_rows` /
        `prefill_remainder_rows` stream the prompt into its cache."""
        self.cache = self.engine.reset_pool_row(self.cache, row)
        self.cur[row] = EOS
        self.finished[row] = True
        self.slots[row] = _Slot(request=request, emitted=[],
                                state=PREFILLING, filled=0)

    def prefill_chunk_rows(self, rows: List[int], tokens: np.ndarray,
                           n_valid: np.ndarray) -> np.ndarray:
        """One padded, batched chunk forward over PREFILLING rows (the
        engine donates the pool cache; the owner swaps in the result).
        The batch is padded to the pool size, so EVERY admission round of
        this pool shares one chunk-forward compile."""
        self.cache, logits = self.engine.pool_prefill_chunk(
            self.cache, rows, tokens, n_valid, pad_to=self.max_batch)
        return np.asarray(logits)

    def prefill_remainder_rows(self, rows: List[int],
                               tokens: np.ndarray) -> np.ndarray:
        """Batched decode-path prefill of the final sub-block remainder
        (pool-size padded like `prefill_chunk_rows`)."""
        self.cache, logits = self.engine.pool_prefill_remainder(
            self.cache, rows, tokens, pad_to=self.max_batch)
        return np.asarray(logits)

    def activate(self, row: int, first_token: int) -> None:
        """Prefill complete: the row joins the decoding pool next chunk."""
        self.cur[row] = first_token
        self.finished[row] = False
        self.slots[row].state = DECODING

    def retire(self, row: int) -> None:
        self.slots[row] = None
        self.cur[row] = EOS
        self.finished[row] = True

    def decode_chunk(self, n: int, rng: jax.Array
                     ) -> Tuple[np.ndarray, jax.Array]:
        """Run one n-step device-resident decode chunk over the pool.
        Returns (tokens (max_batch, n), next rng). The chunk scan donates
        the pool cache; the returned cache replaces it atomically."""
        toks, cur, finished, cache, rng = self.engine.pool_chunk_fn(n)(
            self.engine.params, jnp.asarray(self.cur),
            jnp.asarray(self.finished), self.cache, rng)
        self.cache = cache
        self.cur = np.array(cur)            # writable host copies
        self.finished = np.array(finished)
        return np.asarray(toks), rng


class Scheduler:
    """FCFS continuous-batching scheduler: admit into free slots between
    decode chunks, retire on EOS / per-request token budget, stream
    completions. See the module docstring for the full contract."""

    def __init__(self, engine, max_batch: int,
                 rng: Optional[jax.Array] = None):
        self.engine = engine
        self.pool = SlotPool(engine, max_batch)
        self.queue: deque[Request] = deque()
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.stats = ScheduleStats()

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    # -- internals -------------------------------------------------------

    def _admit_ready(self) -> None:
        """Fill free slots with arrived requests (FCFS; later-arriving
        requests never jump the queue). Monolithic mode prefills the whole
        prompt here (one B=1 forward per request); chunked mode only claims
        the slot — `_advance_prefill` streams the prompt in afterwards."""
        free = self.pool.free_rows()
        chunked = self.engine.prefill_chunk > 0
        while free and self.queue \
                and self.queue[0].arrival_chunk <= self.stats.ticks:
            req = self.queue.popleft()
            if chunked:
                self.pool.begin_prefill(free.pop(0), req)
                continue
            self.rng, sub = jax.random.split(self.rng)
            slot_cache, first = self.engine.prefill_request(req.tokens, sub)
            self.stats.prefill_forwards += 1      # one B=1 forward each
            self.stats.prefill_tokens += len(req.tokens)
            self.pool.admit(free.pop(0), req, slot_cache, first)

    def _advance_prefill(self) -> None:
        """Advance every PREFILLING slot by ONE chunk (the interleave
        quantum), batching rows into shared forwards.

        Phase 1 — full-block chunks: every row with ≥ block_size full-block
        prompt tokens left joins ONE padded (g, prefill_chunk) forward —
        per-row `n_valid` + traced per-row offsets mean arbitrary mixes of
        prompt lengths and progress share the compile, which is the whole
        batched-admission win over B=1-per-request monolithic prefill.

        Phase 2 — remainder: rows whose full-block prefix is done feed their
        < block_size leftover tokens through batched decode steps, grouped
        by remainder length (same math as the monolithic path's remainder
        loop, batched).

        Phase 3 — activation: completed rows sample their first token from
        the final logits and flip to DECODING for the next decode chunk."""
        P = self.engine.prefill_chunk
        c = self.engine._block()
        pf = [(row, s) for row, s in enumerate(self.pool.slots)
              if s is not None and s.state == PREFILLING]
        if not pf:
            return
        final_logits: Dict[int, np.ndarray] = {}

        chunk_rows = []
        for row, s in pf:
            nfull = (len(s.request.tokens) // c) * c
            if s.filled < nfull:
                chunk_rows.append((row, s, nfull))
        if chunk_rows:
            g = len(chunk_rows)
            toks = np.zeros((g, P), np.int32)
            n_valid = np.zeros((g,), np.int32)
            for j, (row, s, nfull) in enumerate(chunk_rows):
                n = min(P, nfull - s.filled)
                toks[j, :n] = s.request.tokens[s.filled:s.filled + n]
                n_valid[j] = n
            logits = self.pool.prefill_chunk_rows(
                [row for row, _, _ in chunk_rows], toks, n_valid)
            self.stats.prefill_forwards += 1
            self.stats.prefill_tokens += int(n_valid.sum())
            for j, (row, s, nfull) in enumerate(chunk_rows):
                s.filled += int(n_valid[j])
                if s.filled == len(s.request.tokens):
                    final_logits[row] = logits[j]

        rem_groups: Dict[int, List[Tuple[int, _Slot]]] = {}
        for row, s in pf:
            rem = len(s.request.tokens) - s.filled
            if 0 < rem < c:
                rem_groups.setdefault(rem, []).append((row, s))
        for rem, group in sorted(rem_groups.items()):
            toks = np.asarray(
                [s.request.tokens[s.filled:s.filled + rem]
                 for _, s in group], np.int32)
            logits = self.pool.prefill_remainder_rows(
                [row for row, _ in group], toks)
            self.stats.prefill_forwards += 1
            self.stats.prefill_tokens += rem * len(group)
            for j, (row, s) in enumerate(group):
                s.filled += rem
                final_logits[row] = logits[j]

        for row in sorted(final_logits):
            self.rng, sub = jax.random.split(self.rng)
            first = int(np.asarray(
                self.engine._sample(jnp.asarray(final_logits[row])[None],
                                    sub))[0])
            self.pool.activate(row, first)

    def _drain_chunk(self, toks: np.ndarray,
                     on_token: Optional[Callable[[int, int], None]],
                     on_complete: Optional[Callable[[int, List[int]], None]],
                     results: Dict[int, List[int]]) -> None:
        """Distribute a chunk's tokens to their requests; retire EOS'd /
        budget-exhausted slots."""
        for row in range(self.pool.max_batch):
            slot = self.pool.slots[row]
            if slot is None or slot.state != DECODING:
                continue                 # PREFILLING rows rode along masked
            done = False
            budget = slot.request.max_new_tokens
            for tok in toks[row].tolist():
                # budget check BEFORE appending: a ≤0 budget emits nothing
                # (matching serve_static's gen[row, :0] truncation)
                if tok == EOS or len(slot.emitted) >= budget:
                    done = True
                    break
                slot.emitted.append(tok)
                if on_token is not None:
                    on_token(slot.request.rid, tok)
            if len(slot.emitted) >= budget:
                done = True
            if done:
                results[slot.request.rid] = slot.emitted
                if on_complete is not None:
                    on_complete(slot.request.rid, slot.emitted)
                self.pool.retire(row)

    # -- main loop -------------------------------------------------------

    def run(self,
            on_token: Optional[Callable[[int, int], None]] = None,
            on_complete: Optional[Callable[[int, List[int]], None]] = None,
            ) -> Dict[int, List[int]]:
        """Drive the pool until every submitted request completes. Returns
        {rid: tokens} (tokens exclude EOS, capped at max_new_tokens)."""
        results: Dict[int, List[int]] = {}
        chunk = self.engine.decode_chunk
        while self.queue or self.pool.occupancy:
            self._admit_ready()
            if self.engine.prefill_chunk:
                self._advance_prefill()
            decoding = self.pool.decoding_count
            if not decoding:
                # nothing decodable yet (pool empty, or every occupied slot
                # still prefilling): let virtual time pass so future
                # arrival_chunk requests become admissible
                self.stats.idle_ticks += 1
                continue
            toks, self.rng = self.pool.decode_chunk(chunk, self.rng)
            self.stats.chunks += 1
            self.stats.row_steps += decoding * chunk
            self.stats.occupancy_sum += self.pool.occupancy \
                / self.pool.max_batch
            self._drain_chunk(toks, on_token, on_complete, results)
        return results
