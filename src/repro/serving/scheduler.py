"""SLO-aware slot-based continuous-batching scheduler over the
device-resident decode loop.

Admission/eviction contract
---------------------------

The unit of work is a *slot*: one row of a fixed (max_batch)-row pool cache.
The scheduler mutates the pool ONLY between decode chunks:

* **Admission** — earliest-deadline-first within priority classes: arrived
  requests are ordered by (priority, deadline, submission order) — lower
  `priority` numbers are more urgent, `deadline_ticks=None` sorts last
  within its class, and with the default priority/deadline on every request
  the order degenerates to exactly the old FCFS. A queued request whose
  arrival time has passed claims a free slot. With `engine.prefill_chunk ==
  0` (monolithic) it is prefilled alone (B=1, its own forward) and its
  cache rows are `dynamic_update_slice`d into the pool; with
  `engine.prefill_chunk > 0` (chunked) the slot is claimed PREFILLING at
  t=0 and the prompt streams into the pool cache one fixed-size chunk per
  round, every co-prefilling row sharing ONE padded, batched forward.
  Admission never perturbs live rows: every cache write, rope position,
  attention mask and block fold is per-row (core/cache.py).
* **Preemption** — when no slot is free, an arrived request whose priority
  is STRICTLY more urgent than the least-urgent occupied slot evicts that
  slot: the victim's state is captured as a host-side `SlotSnapshot`
  (cache rows via the engine's `_gather_rows` — O(c + M) bytes per row,
  the compressed prefix making preemption cheap — plus `cur`, `finished`,
  emitted tokens and prefill progress) and the victim is requeued; when it
  is re-admitted the snapshot is `_scatter_rows`'d back and decode resumes
  byte-identically to an uninterrupted run. Strict inequality means a
  victim can never preempt its preemptor — no thrash.
* **Overload shedding** — `max_queue` bounds the admission queue: a submit
  beyond the bound sheds the entry that EDF would schedule LAST (lowest
  priority class, latest deadline, latest submission) with an explicit
  `ShedResult` instead of queueing unboundedly. Per round, a waiting
  request whose deadline can no longer be met even by the optimistic
  lower-bound estimate (`_needed_ticks`) is shed as infeasible rather than
  admitted to miss.
* **Decode** — the pool decodes `decode_chunk` tokens as one jitted
  `lax.scan` (model.decode_scan): ONE host sync per chunk, which now also
  carries a per-row non-finite-logits flag (the NaN/Inf guard — detection
  costs nothing extra).
* **Faults & quarantine** — a row flagged bad (NaN/Inf logits) or reported
  failed by an attached `FaultInjector` is quarantined at the chunk
  boundary: its tokens from the poisoned chunk are discarded, its row is
  scrubbed (zeroed — a NaN cache must never be left where additive masks
  could leak it to a later occupant), and the request is requeued from its
  last good snapshot (or from scratch when none exists — greedy decode
  makes that byte-identical too). Retries are bounded by `max_retries`;
  exhaustion sheds the request with an explicit ShedResult. A corrupt
  snapshot (checksum mismatch) is detected at restore and falls back to
  from-scratch. Neighbour rows' bytes are never touched — per-row masks
  make every row's math independent, so a fault-free co-resident request
  is byte-identical to a fault-free run (tests/test_serving_faults.py).
* **Eviction / retirement** — after the chunk's host sync, an EOS or an
  exhausted per-request `max_new_tokens` budget retires the slot; a
  completion past the request's deadline counts a `deadline_miss`.

The pool cache has a single owner (`SlotPool`): every donating mutation
(chunk scans, slot writes, restores, scrubs, fault corruption) routes
through it and swaps in the returned cache, so no other live reference can
dangle. Snapshot capture gathers WITHOUT donating.

Determinism: greedy decode of a request depends only on its own prompt —
per-row masks make every row's attention independent of its neighbours — so
continuous scheduling (with any mix of preemptions, requeues and restores)
produces byte-identical outputs to the static bucketed baseline
(`ServingEngine.serve_static`), under any arrival order and any pool size
(tests/test_serving_scheduler.py, tests/test_serving_faults.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import EOS
from repro.serving.paged import PageAllocator, pages_needed
from repro.serving.snapshot import SlotSnapshot, capture
from repro.telemetry import MetricsRegistry, as_telemetry

_INF = float("inf")

# ShedResult reasons
SHED_QUEUE_FULL = "queue_full"
SHED_DEADLINE_INFEASIBLE = "deadline_infeasible"
SHED_RETRIES_EXHAUSTED = "retries_exhausted"
SHED_PAGES_EXHAUSTED = "pages_exhausted"   # paged pool: the request's
#                        lifetime page need exceeds the whole arena — it
#                        could never run to completion, so it is refused
#                        up front rather than wedged mid-decode


@dataclasses.dataclass
class Request:
    """One generation request.

    `arrival_chunk`: the request becomes admissible once that much virtual
    time has passed (executed decode chunks + idle ticks, `stats.ticks`) —
    the replay knob for arrival traces (benchmarks/serving_throughput.py);
    0 = available immediately.

    `priority`: admission class — LOWER is more urgent (0 = interactive).
    Within a class, earliest `deadline_ticks` first, then submission order.
    A strictly more urgent arrival may preempt a less urgent running slot.

    `deadline_ticks`: absolute virtual-time deadline (None = no deadline).
    Used for EDF ordering, feasibility shedding, and the deadline_misses
    counter; it is an SLO signal, not a hard kill — a running request past
    its deadline finishes and counts a miss.

    Construction fails fast on malformed fields with the rid in the message
    (a bad request must never surface as an opaque shape error mid-decode).
    """

    rid: int
    tokens: Tuple[int, ...]
    max_new_tokens: int
    arrival_chunk: int = 0
    priority: int = 0
    deadline_ticks: Optional[int] = None

    def __post_init__(self):
        if len(self.tokens) == 0:
            raise ValueError(f"request {self.rid}: empty prompt (there are "
                             "no logits to sample a first token from)")
        if self.max_new_tokens <= 0:
            raise ValueError(f"request {self.rid}: max_new_tokens="
                             f"{self.max_new_tokens} must be positive")
        if self.arrival_chunk < 0:
            raise ValueError(f"request {self.rid}: arrival_chunk="
                             f"{self.arrival_chunk} must be >= 0")
        if self.deadline_ticks is not None and self.deadline_ticks < 0:
            raise ValueError(f"request {self.rid}: deadline_ticks="
                             f"{self.deadline_ticks} must be >= 0")


@dataclasses.dataclass(frozen=True)
class ShedResult:
    """Explicit rejection: the scheduler refused (or gave up on) a request
    instead of queueing it forever or streaming garbage. Returned in place
    of the token list."""

    rid: int
    reason: str        # SHED_QUEUE_FULL | SHED_DEADLINE_INFEASIBLE |
    #                    SHED_RETRIES_EXHAUSTED
    tick: int          # virtual time of the decision
    priority: int


# Slot states. A monolithically-admitted slot is born DECODING; under
# chunked admission (engine.prefill_chunk > 0) a slot is born PREFILLING —
# its prompt enters the pool cache one fixed-size chunk per scheduler round,
# interleaved with everyone else's decode chunks — and flips to DECODING
# when its first token is sampled. PREFILLING survives across rounds: the
# partial-prefill state is the row's cache contents + `_Slot.filled`.
PREFILLING = "prefilling"
DECODING = "decoding"


@dataclasses.dataclass
class _Slot:
    request: Request
    emitted: List[int]
    state: str = DECODING
    filled: int = 0                    # prompt tokens committed to the cache
    seq: int = 0                       # submission order (EDF tie-break)
    retries: int = 0                   # fault requeues consumed so far


@dataclasses.dataclass
class _QueueEntry:
    """A waiting request, possibly carrying resume state from a preemption
    or a fault requeue."""

    request: Request
    seq: int
    snapshot: Optional[SlotSnapshot] = None
    retries: int = 0

    def sort_key(self) -> Tuple[int, float, int]:
        """EDF within priority classes; submission order breaks ties. The
        max of this key over a set is also the shedding/preemption victim
        (the entry the schedule values least)."""
        dl = self.request.deadline_ticks
        return (self.request.priority, _INF if dl is None else dl, self.seq)


def _slot_sort_key(slot: _Slot) -> Tuple[int, float, int]:
    dl = slot.request.deadline_ticks
    return (slot.request.priority, _INF if dl is None else dl, slot.seq)


# ScheduleStats attribute -> metric name in the backing registry. The
# attribute surface (stats.chunks, stats.sheds += 1, ...) is unchanged from
# the pre-telemetry dataclass; the storage moved into a MetricsRegistry so
# one increment is visible to both the scheduler and the metrics export.
_STAT_COUNTERS = {
    "chunks": "serving_chunks_total",              # decode chunks executed
    "idle_ticks": "serving_idle_ticks_total",      # no-decode ticks (pool
    #                                                empty or all prefilling)
    "row_steps": "serving_row_steps_total",        # DECODING-slot steps
    "occupancy_sum": "serving_occupancy_sum",      # Σ per-chunk occupied frac
    #                                                (DECODING + PREFILLING)
    "prefill_forwards": "serving_prefill_forwards_total",  # prefill launches
    "prefill_tokens": "serving_prefill_tokens_total",  # real prompt tokens
    "preemptions": "serving_preemptions_total",    # snapshot + requeue evicts
    "sheds": "serving_sheds_total",                # explicit ShedResults
    "deadline_misses": "serving_deadline_misses_total",  # late completions
    "retries": "serving_retries_total",            # fault requeues
    "quarantines": "serving_quarantines_total",    # faulty rows isolated
    "snapshots": "serving_snapshots_total",        # snapshots captured
    "snapshot_corruptions": "serving_snapshot_corruptions_total",
    "page_preemptions": "serving_page_preemptions_total",  # evictions forced
    #                                                by arena-page pressure
}


class ScheduleStats:
    """Scheduler counters, stored in a `telemetry.MetricsRegistry`.

    A *view*: `stats.chunks` reads — and `stats.chunks += 1` writes — the
    `serving_chunks_total` counter of `stats.registry` (see
    `_STAT_COUNTERS` for the full name map), so the same numbers flow into
    the Prometheus/JSONL exports without a second set of hand-rolled ints.
    Each Scheduler owns a FRESH registry (plus the per-priority SLO
    histograms folded in at the end of `run`); a shared `Telemetry` facade
    adopts it per run, so warm reruns never accumulate across schedulers.
    All attributes except `occupancy_sum` read back as ints, exactly like
    the old dataclass fields."""

    __slots__ = ("registry", "_c")

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        object.__setattr__(self, "registry",
                           registry if registry is not None
                           else MetricsRegistry())
        object.__setattr__(self, "_c", {
            attr: self.registry.counter(name)
            for attr, name in _STAT_COUNTERS.items()})

    def __getattr__(self, name):
        try:
            c = object.__getattribute__(self, "_c")[name]
        except KeyError:
            raise AttributeError(name) from None
        return c.value if name == "occupancy_sum" else int(c.value)

    def __setattr__(self, name, value):
        c = self._c.get(name)
        if c is None:
            raise AttributeError(f"ScheduleStats has no counter {name!r}")
        # repro-lint: allow[RL002] metrics mirror ingests host floats
        c.value = float(value)

    @property
    def ticks(self) -> int:
        """Virtual time: executed chunks + idle ticks (arrival clock)."""
        return self.chunks + self.idle_ticks

    @property
    def mean_occupancy(self) -> float:
        """Mean occupied fraction over EXECUTED chunks (idle ticks, where
        nothing decoded, are excluded)."""
        return self.occupancy_sum / max(self.chunks, 1)

    def counters_line(self) -> str:
        """One-line SLO counter summary (surfaced by launch/serve.py)."""
        return (f"preemptions={self.preemptions} sheds={self.sheds} "
                f"deadline_misses={self.deadline_misses} "
                f"retries={self.retries} quarantines={self.quarantines} "
                f"snapshot_corruptions={self.snapshot_corruptions} "
                f"page_preemptions={self.page_preemptions}")


class SlotPool:
    """Sole owner of the live pool cache + per-slot decode state.

    All jitted mutations (slot writes, chunk scans, restores, scrubs,
    injected corruption) donate the cache and the pool swaps in the result,
    so external references can never observe a donated buffer. Snapshot
    capture (`snapshot_rows`) gathers without donating. Under a mesh the
    cache arrives from `engine.init_pool_cache` already laid out per the
    engine's AttentionPlan (KV-head axis sharded over tensor parallelism —
    per-shard slots for the decode kernel's pinned operands); donation and
    snapshot/restore round-trips preserve that layout, so the pool stays
    sharded for its whole life without the scheduler knowing a mesh exists.
    """

    def __init__(self, engine, max_batch: int):
        self.engine = engine
        self.max_batch = max_batch
        self.cache = engine.init_pool_cache(max_batch)
        if "lengths" not in self.cache:
            raise ValueError(
                "continuous batching needs per-row position counters "
                "(cache['lengths']); this model family has a shared scalar "
                "cache — use serve_static")
        self.cur = np.full((max_batch,), EOS, np.int32)
        self.finished = np.ones((max_batch,), bool)
        self.slots: List[Optional[_Slot]] = [None] * max_batch
        # Scheduler-round fast path (the warm-wall gap is round-dominated,
        # not scatter-dominated — docs/serving.md): the chunk scan's cur/
        # finished OUTPUTS are kept device-resident and fed straight back
        # into the next chunk, skipping two host->device uploads per round.
        # Any host-side row mutation (admit/activate/retire/restore/
        # begin_prefill) marks them dirty, and the next chunk re-uploads
        # the authoritative host mirrors — so the math is byte-identical
        # to re-uploading every round.
        self._cur_dev: Optional[jax.Array] = None
        self._fin_dev: Optional[jax.Array] = None
        self._rows_dirty = True
        # resolved jitted chunk callables, cached per scan length: avoids
        # re-resolving (and re-counting) through the engine every round
        self._chunk_fns: Dict[int, Callable] = {}
        # Paged pool (engine.cache_format == "paged"): the pool owns the
        # page allocator alongside the cache — every page the device table
        # references was handed out here, and every freed page is zeroed
        # (the scrub callback) before it can be reused.
        self.paged: bool = bool(getattr(engine, "paged", False))
        self.alloc: Optional[PageAllocator] = None
        self.pages_allocated = 0           # cumulative, for telemetry
        self.pages_freed = 0
        self.quant_error_bound = 0.0       # Σ 0.5·scale over snapshotted
        #                                    pages (worst-case abs error of
        #                                    symmetric int8 rounding)
        if self.paged:
            self.alloc = PageAllocator(
                engine.resolved_arena_pages(max_batch),
                scrub=self._scrub_freed_pages)

    def _scrub_freed_pages(self, pages) -> None:
        """PageAllocator scrub callback: zero the freed pages' device bytes
        BEFORE they return to the free list."""
        self.cache = self.engine.scrub_arena_pages(self.cache, pages)
        self.pages_freed += len(pages)

    def _alloc_pages(self, row: int, n: int) -> Optional[List[int]]:
        pages = self.alloc.alloc(row, n)
        if pages is not None:
            self.pages_allocated += len(pages)
        return pages

    # -- slot table ------------------------------------------------------

    def free_rows(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def occupancy(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def decoding_count(self) -> int:
        return sum(s is not None and s.state == DECODING for s in self.slots)

    def occupied_rows(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    # -- mutations (between chunks only) ---------------------------------

    def admit(self, row: int, request: Request, slot_cache: Dict,
              first_token: int) -> None:
        """Monolithic admission: write a fully-prefilled request into `row`.
        `slot_cache` is a B=1 cache positioned at the prompt length;
        `first_token` the token sampled from the prefill logits (the row's
        first emitted token). On a paged pool the dense slot cache is
        quantized into freshly allocated pages (the caller checked the
        headroom via `pages_for_admission`)."""
        if self.paged:
            pages = self._alloc_pages(
                row, len(request.tokens) // self.engine._block())
            if pages is None:
                raise RuntimeError(
                    f"admit({row}): page headroom vanished between check "
                    "and allocation")
            self.cache = self.engine.write_pool_slot_paged(
                self.cache, slot_cache, row, pages)
        else:
            self.cache = self.engine.write_pool_slot(self.cache, slot_cache,
                                                     row)
        self.cur[row] = first_token
        self.finished[row] = False
        self._rows_dirty = True
        self.slots[row] = _Slot(request=request, emitted=[], state=DECODING,
                                filled=len(request.tokens))

    def begin_prefill(self, row: int, request: Request) -> None:
        """Chunked admission: claim `row` in the PREFILLING state at t=0.
        The row rides subsequent decode chunks finished-masked (its position
        counter frozen, its outputs discarded) while `prefill_chunk_rows` /
        `prefill_remainder_rows` stream the prompt into its cache."""
        self.cache = self.engine.reset_pool_row(self.cache, row)
        self.cur[row] = EOS
        self.finished[row] = True
        self._rows_dirty = True
        self.slots[row] = _Slot(request=request, emitted=[],
                                state=PREFILLING, filled=0)

    def snapshot_rows(self, rows: Sequence[int],
                      tick: int) -> List[SlotSnapshot]:
        """Capture host-side snapshots of occupied `rows` at the current
        chunk boundary (one non-donating padded gather + device_get — the
        cache slice is O(c + M) per row)."""
        subs = self.engine.snapshot_pool_rows(self.cache, rows,
                                              pad_to=self.max_batch)
        if self.paged:
            # worst-case |error| of symmetric round-to-nearest int8 is
            # 0.5·scale per element — accumulate it over the snapshotted
            # page scales as the run's quantization-error telemetry
            for sub in subs:
                for k in ("pages_k_s", "pages_v_s"):
                    # repro-lint: allow[RL002] host snapshot scale leaves
                    s_sum = float(np.asarray(sub[k]).sum())
                    self.quant_error_bound += 0.5 * s_sum
        out = []
        for row, sub in zip(rows, subs):
            slot = self.slots[row]
            out.append(capture(
                rid=slot.request.rid, state=slot.state, filled=slot.filled,
                # repro-lint: allow[RL002] host np mirrors of pool state
                cur=int(self.cur[row]), finished=bool(self.finished[row]),
                emitted=slot.emitted, cache_rows=sub, tick=tick))
        return out

    def restore(self, row: int, request: Request,
                snap: SlotSnapshot) -> None:
        """Re-admit a preempted/faulted request from its snapshot: scatter
        the cache rows back (byte-identical resume) and rebuild the slot.
        A paged restore scatters the snapshot's quantized pages into FRESH
        arena pages — physical placement may differ from capture; the
        table indirection makes the resumed math identical anyway."""
        if self.paged:
            # repro-lint: allow[RL002] snapshot lengths are a host copy
            npv = int(np.asarray(snap.cache_rows["lengths"])[0]) \
                // self.engine._block()
            pages = self._alloc_pages(row, npv)
            if pages is None:
                raise RuntimeError(
                    f"restore({row}): page headroom vanished between check "
                    "and allocation")
            self.cache = self.engine.restore_pool_rows_paged(
                self.cache, snap.cache_rows, row, pages)
        else:
            sub = {k: jnp.asarray(v) for k, v in snap.cache_rows.items()}
            self.cache = self.engine.restore_pool_rows(self.cache, sub, row)
        self.cur[row] = snap.cur
        self.finished[row] = snap.finished
        self._rows_dirty = True
        self.slots[row] = _Slot(request=request, emitted=list(snap.emitted),
                                state=snap.state, filled=snap.filled)

    def scrub_row(self, row: int) -> None:
        """Zero a quarantined row's cache leaves and its position counter.
        A faulty row may hold NaN/Inf — which, unlike finite stale garbage,
        would LEAK through the additive masking of a later occupant's
        attention (NaN + bias = NaN) — so quarantine always scrubs."""
        self.cache = self.engine.scrub_pool_row(self.cache, row)

    def corrupt_row(self, row: int, mode: str) -> None:
        """Fault-injection surface: corrupt row's cache leaves in place
        (mode 'nan' or 'garble') through the donating owner path. On a
        paged pool the corruption hits the row's ring and its OWN pages
        only — neighbour rows' pages stay clean."""
        if self.paged:
            self.cache = self.engine.corrupt_pool_row_paged(
                self.cache, row, self.alloc.pages_of(row), mode)
        else:
            self.cache = self.engine.corrupt_pool_row(self.cache, row, mode)

    def prefill_chunk_rows(self, rows: List[int], tokens: np.ndarray,
                           n_valid: np.ndarray) -> np.ndarray:
        """One padded, batched chunk forward over PREFILLING rows (the
        engine donates the pool cache; the owner swaps in the result).
        The batch is padded to the pool size, so EVERY admission round of
        this pool shares one chunk-forward compile."""
        self.cache, logits = self.engine.pool_prefill_chunk(
            self.cache, rows, tokens, n_valid, pad_to=self.max_batch)
        # repro-lint: allow[RL002] the prefill chunk's one sync
        return np.asarray(logits)

    def prefill_remainder_rows(self, rows: List[int],
                               tokens: np.ndarray) -> np.ndarray:
        """Batched decode-path prefill of the final sub-block remainder
        (pool-size padded like `prefill_chunk_rows`)."""
        self.cache, logits = self.engine.pool_prefill_remainder(
            self.cache, rows, tokens, pad_to=self.max_batch)
        # repro-lint: allow[RL002] the prefill remainder's one sync
        return np.asarray(logits)

    # -- page bookkeeping (paged pools only) ------------------------------

    def pages_for_admission(self, entry: "_QueueEntry") -> int:
        """Pages an entry must be able to allocate AT admission: its
        snapshot's committed pages (restore), the prompt's full blocks
        (monolithic — the whole prefilled prefix lands at once), or none
        (chunked — `ensure_row_pages` grows the table chunk by chunk)."""
        if not self.paged:
            return 0
        c = self.engine._block()
        if entry.snapshot is not None:
            # repro-lint: allow[RL002] snapshot lengths are a host copy
            return int(np.asarray(
                entry.snapshot.cache_rows["lengths"])[0]) // c
        if self.engine.prefill_chunk:
            return 0
        return len(entry.request.tokens) // c

    def ensure_row_pages(self, row: int, target_tokens: int) -> bool:
        """On-demand growth: extend `row`'s page table to cover
        `target_tokens` (ceil to pages) and publish the new entries to the
        device table. Returns False — allocating NOTHING — when the arena
        lacks the pages; the scheduler then preempts or stalls the row."""
        if not self.paged:
            return True
        need = pages_needed(target_tokens, self.engine._block()) \
            - len(self.alloc.pages_of(row))
        if need <= 0:
            return True
        if self._alloc_pages(row, need) is None:
            return False
        self.cache = self.engine.write_table_row(
            self.cache, row, self.alloc.pages_of(row))
        return True

    def activate(self, row: int, first_token: int) -> None:
        """Prefill complete: the row joins the decoding pool next chunk."""
        self.cur[row] = first_token
        self.finished[row] = False
        self._rows_dirty = True
        self.slots[row].state = DECODING

    def retire(self, row: int) -> None:
        if self.paged:
            # clear the device table BEFORE freeing: a stale entry over a
            # re-allocated page would let this dead (finished-masked but
            # still folding) row write into a live tenant's KV bytes
            self.cache = self.engine.clear_table_row(self.cache, row)
            self.alloc.free_row(row)       # scrubs (zeroes) before reuse
        self.slots[row] = None
        self.cur[row] = EOS
        self.finished[row] = True
        self._rows_dirty = True

    def decode_chunk(self, n: int, rng: jax.Array
                     ) -> Tuple[np.ndarray, np.ndarray, jax.Array]:
        """Run one n-step device-resident decode chunk over the pool.
        Returns (tokens (max_batch, n), bad (max_batch,) non-finite-logits
        flags, next rng). The chunk scan donates the pool cache; the
        returned cache replaces it atomically.

        Fast path: between rounds with no row mutation the previous
        chunk's device-resident cur/finished feed the next chunk directly
        (no host->device upload); the host mirrors are still refreshed at
        the chunk's one sync, so scheduler bookkeeping sees exactly the
        values it always did."""
        fn = self._chunk_fns.get(n)
        if fn is None:
            fn = self.engine.pool_chunk_fn(n)
            self._chunk_fns[n] = fn
        if self._rows_dirty or self._cur_dev is None:
            self._cur_dev = jnp.asarray(self.cur)
            self._fin_dev = jnp.asarray(self.finished)
        toks, cur, finished, bad, cache, rng = fn(
            self.engine.params, self._cur_dev, self._fin_dev,
            self.cache, rng)
        self.cache = cache
        self._cur_dev, self._fin_dev = cur, finished
        self._rows_dirty = False
        # repro-lint: allow[RL002] host mirror; rides the chunk sync
        self.cur = np.array(cur)
        # repro-lint: allow[RL002] host mirror; rides the chunk sync
        self.finished = np.array(finished)
        # repro-lint: allow[RL002] the chunk's one sync (decode contract)
        return np.asarray(toks), np.asarray(bad), rng


class Scheduler:
    """SLO-aware continuous-batching scheduler: EDF-within-priority
    admission, preemptive eviction with snapshot resume, bounded-queue
    overload shedding, and fault quarantine/retry. With every knob at its
    default (priority 0, no deadlines, unbounded queue, no injector) the
    behavior is exactly the old FCFS scheduler. See the module docstring
    for the full contract."""

    def __init__(self, engine, max_batch: int,
                 rng: Optional[jax.Array] = None, *,
                 max_queue: Optional[int] = None,
                 max_retries: int = 2,
                 snapshot_chunks: int = 0,
                 nan_guard: bool = True,
                 fault_injector=None,
                 telemetry=None):
        self.engine = engine
        self.pool = SlotPool(engine, max_batch)
        self.waiting: List[_QueueEntry] = []
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.stats = ScheduleStats()
        # fresh per-scheduler timeline namespace + stats registry: warm
        # reruns reuse request ids, so runs must not share either
        self.telemetry = as_telemetry(telemetry)
        self.timelines = self.telemetry.new_timelines("serving")
        self.telemetry.adopt_registry(self.stats.registry, "serving")
        self.max_queue = max_queue
        self.max_retries = max_retries
        # snapshot_chunks=k refreshes every occupied row's last-good
        # snapshot each k-th executed chunk (0 = only capture on
        # preemption; fault recovery then requeues from scratch)
        self.snapshot_chunks = snapshot_chunks
        self.nan_guard = nan_guard
        self.fault_injector = fault_injector
        self.shed: Dict[int, ShedResult] = {}
        self.completed_at: Dict[int, int] = {}      # rid -> completion tick
        self.snapshots: Dict[int, SlotSnapshot] = {}  # row -> last good
        self._streamed: Dict[int, int] = {}  # rid -> on_token high-water
        #                                      mark (a requeued request must
        #                                      not re-stream tokens)
        self._seq = 0
        self._page_stats_last = None  # last published page-gauge tuple:
        #                               the per-round refresh is skipped
        #                               when nothing allocated or freed

    def submit(self, request: Request) -> None:
        """Queue a request. With `max_queue` set, submitting past the bound
        sheds the entry EDF values least (possibly the incoming one) with
        an explicit ShedResult — never silent unbounded queueing."""
        entry = _QueueEntry(request=request, seq=self._seq)
        self._seq += 1
        self.timelines.stamp(request.rid, "queued", self.stats.ticks,
                             priority=request.priority,
                             deadline=request.deadline_ticks,
                             prompt_len=len(request.tokens),
                             budget=request.max_new_tokens)
        if self.max_queue is not None and len(self.waiting) >= self.max_queue:
            victim = max(self.waiting + [entry],
                         key=lambda e: e.sort_key())
            self._shed(victim, SHED_QUEUE_FULL)
            if victim is entry:
                return
            self.waiting.remove(victim)
        self.waiting.append(entry)

    # -- internals -------------------------------------------------------

    def _shed(self, entry: _QueueEntry, reason: str) -> None:
        sr = ShedResult(rid=entry.request.rid, reason=reason,
                        tick=self.stats.ticks,
                        priority=entry.request.priority)
        self.shed[entry.request.rid] = sr
        self.stats.sheds += 1
        self.timelines.stamp(entry.request.rid, "shed", sr.tick,
                             reason=reason)

    def _needed_ticks(self, entry: _QueueEntry) -> int:
        """Optimistic lower bound on ticks to completion if admitted NOW:
        remaining chunked-prefill rounds + remaining decode chunks. Used
        only to shed provably-infeasible deadlines — an optimistic bound
        never sheds a request that could still make it."""
        req = entry.request
        emitted = len(entry.snapshot.emitted) if entry.snapshot else 0
        filled = entry.snapshot.filled if entry.snapshot \
            else (len(req.tokens) if not self.engine.prefill_chunk else 0)
        P = self.engine.prefill_chunk
        prefill_rounds = 0
        if P and filled < len(req.tokens):
            c = self.engine._block()
            nfull = (len(req.tokens) // c) * c
            prefill_rounds = max(0, math.ceil((nfull - filled) / P))
        decode_chunks = math.ceil(
            max(0, req.max_new_tokens - emitted) / self.engine.decode_chunk)
        return prefill_rounds + decode_chunks

    def _arrived(self) -> List[_QueueEntry]:
        """Waiting entries whose arrival time has passed, in EDF order,
        with infeasible-deadline entries shed (the per-round feasibility
        check)."""
        tick = self.stats.ticks
        arrived = [e for e in self.waiting
                   if e.request.arrival_chunk <= tick]
        arrived.sort(key=lambda e: e.sort_key())
        feasible = []
        for e in arrived:
            dl = e.request.deadline_ticks
            if dl is not None and tick + self._needed_ticks(e) > dl:
                self.waiting.remove(e)
                self._shed(e, SHED_DEADLINE_INFEASIBLE)
            elif self.pool.paged and self._lifetime_pages(e.request) \
                    > self.pool.alloc.usable_pages:
                # could never finish even owning the WHOLE arena
                self.waiting.remove(e)
                self._shed(e, SHED_PAGES_EXHAUSTED)
            else:
                feasible.append(e)
        return feasible

    def _lifetime_pages(self, req: Request) -> int:
        """Worst-case pages `req` ever holds at once: full coverage of
        prompt + decode budget."""
        return pages_needed(len(req.tokens) + req.max_new_tokens,
                            self.engine._block())

    def _page_headroom(self, entry: _QueueEntry,
                       extra_free: int = 0) -> bool:
        """Can `entry` allocate its admission pages right now (optionally
        counting a prospective victim's pages as free)?"""
        if not self.pool.paged:
            return True
        return self.pool.pages_for_admission(entry) \
            <= self.pool.alloc.free_pages + extra_free

    def _admit_entry(self, row: int, entry: _QueueEntry) -> None:
        """Place one entry into a free row: snapshot restore (verified by
        checksum) for preempted/faulted entries, else a fresh prefill."""
        self.waiting.remove(entry)
        self.snapshots.pop(row, None)      # stale snapshot of a past tenant
        if entry.snapshot is not None:
            if entry.snapshot.verify():
                self.pool.restore(row, entry.request, entry.snapshot)
                slot = self.pool.slots[row]
                slot.seq, slot.retries = entry.seq, entry.retries
                self.timelines.stamp(entry.request.rid, "restored",
                                     self.stats.ticks, row=row)
                return
            # corrupt snapshot: detected BEFORE its bytes touch the pool;
            # fall back to re-running from the prompt (byte-identical under
            # greedy decode, just slower)
            self.stats.snapshot_corruptions += 1
            entry.snapshot = None
        req = entry.request
        self.timelines.stamp(req.rid, "admitted", self.stats.ticks, row=row)
        if self.engine.prefill_chunk > 0:
            self.pool.begin_prefill(row, req)
        else:
            self.rng, sub = jax.random.split(self.rng)
            with self.telemetry.span("admission_prefill", cat="scheduler",
                                     rid=req.rid, tokens=len(req.tokens)):
                slot_cache, first = self.engine.prefill_request(req.tokens,
                                                                sub)
            self.stats.prefill_forwards += 1      # one B=1 forward each
            self.stats.prefill_tokens += len(req.tokens)
            self.pool.admit(row, req, slot_cache, first)
            self.timelines.stamp(req.rid, "first_token", self.stats.ticks)
        slot = self.pool.slots[row]
        slot.seq, slot.retries = entry.seq, entry.retries

    def _preempt_row(self, row: int) -> None:
        """Evict `row` mid-stream: snapshot its state (chunk boundary, so
        the state is clean) and requeue it with the snapshot attached."""
        slot = self.pool.slots[row]
        snap = self.pool.snapshot_rows([row], self.stats.ticks)[0]
        self.stats.snapshots += 1
        self.timelines.stamp(slot.request.rid, "snapshot", self.stats.ticks,
                             row=row)
        self.waiting.append(_QueueEntry(
            request=slot.request, seq=slot.seq, snapshot=snap,
            retries=slot.retries))
        self.snapshots.pop(row, None)
        self.pool.retire(row)
        self.stats.preemptions += 1
        self.timelines.stamp(slot.request.rid, "preempted", self.stats.ticks,
                             row=row)

    def _admit_ready(self) -> None:
        """Fill free slots with arrived requests in EDF-within-priority
        order, then preempt: while the most urgent still-waiting arrival is
        STRICTLY more urgent than the least-urgent occupied slot, evict
        that slot (snapshot + requeue) and admit the arrival in its place.
        Monolithic mode prefills the whole prompt here (one B=1 forward per
        request); chunked mode only claims the slot — `_advance_prefill`
        streams the prompt in afterwards."""
        arrived = self._arrived()
        for row in self.pool.free_rows():
            if not arrived:
                return
            if not self._page_headroom(arrived[0]):
                # head-of-line blocking on purpose: admitting a later,
                # smaller entry past the most urgent one would invert EDF
                break
            self._admit_entry(row, arrived.pop(0))
        while arrived:
            entry = arrived.pop(0)
            occupied = self.pool.occupied_rows()
            if not occupied:
                break
            victim = max(occupied,
                         key=lambda r: _slot_sort_key(self.pool.slots[r]))
            if _slot_sort_key(self.pool.slots[victim])[0] \
                    <= entry.request.priority:
                break                      # nothing strictly less urgent
            if self.pool.paged and not self._page_headroom(
                    entry, extra_free=len(self.pool.alloc.pages_of(victim))):
                break            # eviction would not free enough pages
            self._preempt_row(victim)
            self._admit_entry(victim, entry)

    def _advance_prefill(self) -> None:
        """Advance every PREFILLING slot by ONE chunk (the interleave
        quantum), batching rows into shared forwards.

        Phase 1 — full-block chunks: every row with ≥ block_size full-block
        prompt tokens left joins ONE padded (g, prefill_chunk) forward —
        per-row `n_valid` + traced per-row offsets mean arbitrary mixes of
        prompt lengths and progress share the compile, which is the whole
        batched-admission win over B=1-per-request monolithic prefill.

        Phase 2 — remainder: rows whose full-block prefix is done feed their
        < block_size leftover tokens through batched decode steps, grouped
        by remainder length (same math as the monolithic path's remainder
        loop, batched).

        Phase 3 — activation: completed rows sample their first token from
        the final logits and flip to DECODING for the next decode chunk."""
        P = self.engine.prefill_chunk
        c = self.engine._block()
        pf = [(row, s) for row, s in enumerate(self.pool.slots)
              if s is not None and s.state == PREFILLING]
        if not pf:
            return
        final_logits: Dict[int, np.ndarray] = {}

        chunk_rows = []
        starved: List[int] = []
        for row, s in pf:
            nfull = (len(s.request.tokens) // c) * c
            if s.filled < nfull:
                n = min(P, nfull - s.filled)
                # on-demand page growth: this chunk folds blocks up to
                # (filled + n)/c — their pages must exist before the fold
                if not self.pool.ensure_row_pages(row, s.filled + n):
                    starved.append(row)    # stalls this round, keeps state
                    continue
                chunk_rows.append((row, s, nfull))
        if chunk_rows:
            g = len(chunk_rows)
            toks = np.zeros((g, P), np.int32)
            n_valid = np.zeros((g,), np.int32)
            for j, (row, s, nfull) in enumerate(chunk_rows):
                n = min(P, nfull - s.filled)
                toks[j, :n] = s.request.tokens[s.filled:s.filled + n]
                n_valid[j] = n
            # repro-lint: allow[RL002] n_valid is a host staging buffer
            chunk_tokens = int(n_valid.sum())
            with self.telemetry.span("prefill_chunk_forward",
                                     cat="scheduler", rows=g,
                                     tokens=chunk_tokens):
                logits = self.pool.prefill_chunk_rows(
                    [row for row, _, _ in chunk_rows], toks, n_valid)
            self.stats.prefill_forwards += 1
            # repro-lint: allow[RL002] n_valid is a host np staging buffer
            self.stats.prefill_tokens += int(n_valid.sum())
            for j, (row, s, nfull) in enumerate(chunk_rows):
                # repro-lint: allow[RL002] n_valid is a host np staging buffer
                s.filled += int(n_valid[j])
                self.timelines.stamp(s.request.rid, "prefill_chunk",
                                     self.stats.ticks, filled=s.filled,
                                     total=len(s.request.tokens))
                if s.filled == len(s.request.tokens):
                    final_logits[row] = logits[j]

        rem_groups: Dict[int, List[Tuple[int, _Slot]]] = {}
        for row, s in pf:
            rem = len(s.request.tokens) - s.filled
            if 0 < rem < c:
                rem_groups.setdefault(rem, []).append((row, s))
        for rem, group in sorted(rem_groups.items()):
            toks = np.asarray(
                [s.request.tokens[s.filled:s.filled + rem]
                 for _, s in group], np.int32)
            with self.telemetry.span("prefill_remainder_forward",
                                     cat="scheduler", rows=len(group),
                                     tokens=rem * len(group)):
                logits = self.pool.prefill_remainder_rows(
                    [row for row, _ in group], toks)
            self.stats.prefill_forwards += 1
            self.stats.prefill_tokens += rem * len(group)
            for j, (row, s) in enumerate(group):
                s.filled += rem
                self.timelines.stamp(s.request.rid, "prefill_chunk",
                                     self.stats.ticks, filled=s.filled,
                                     total=len(s.request.tokens))
                final_logits[row] = logits[j]

        for row in sorted(final_logits):
            self.rng, sub = jax.random.split(self.rng)
            # repro-lint: allow[RL002] admission first-token sync
            first = int(np.asarray(
                self.engine._sample(jnp.asarray(final_logits[row])[None],
                                    sub))[0])
            self.pool.activate(row, first)
            self.timelines.stamp(self.pool.slots[row].request.rid,
                                 "first_token", self.stats.ticks)

        if starved and not chunk_rows and not rem_groups \
                and self.pool.decoding_count == 0:
            # Nothing in the pool can make progress — every page is tied up
            # by stalled prefills. Preempt the least-urgent page-holding
            # row (its pages are zeroed and freed) so the survivors
            # advance; the victim resumes from its snapshot later.
            holders = [r for r in self.pool.occupied_rows()
                       if self.pool.alloc.pages_of(r)]
            if not holders:
                raise RuntimeError(
                    "page-starved prefill with an empty arena: a single "
                    "chunk outgrows the usable pages (the admission "
                    "feasibility check should have shed this request)")
            victim = max(holders,
                         key=lambda r: _slot_sort_key(self.pool.slots[r]))
            self.stats.page_preemptions += 1
            self._preempt_row(victim)

    def _ensure_decode_pages(self, chunk: int) -> None:
        """Before a decode chunk: grow every DECODING row's page table to
        cover the chunk's folds (on-demand allocation). On exhaustion,
        preempt the least-urgent page-holding row — the needy row itself
        if it IS the least urgent — until the chunk is covered; preempted
        rows resume from their snapshots when pages free up."""
        if not self.pool.paged:
            return
        rows = [(r, s) for r, s in enumerate(self.pool.slots)
                if s is not None and s.state == DECODING]
        for row, s in rows:
            if self.pool.slots[row] is not s:
                continue                   # preempted below, mid-loop
            life = len(s.request.tokens) + s.request.max_new_tokens
            # host upper bound on the row's position: committed prompt +
            # emitted + the pending sampled token (device lengths may lag
            # for finished-masked rows — over-covering by a page is safe)
            target = min(life, s.filled + len(s.emitted) + 1 + chunk)
            while not self.pool.ensure_row_pages(row, target):
                holders = [r for r in self.pool.occupied_rows()
                           if r != row and self.pool.alloc.pages_of(r)]
                victim = row
                if holders:
                    cand = max(holders, key=lambda r: _slot_sort_key(
                        self.pool.slots[r]))
                    if _slot_sort_key(self.pool.slots[cand]) \
                            >= _slot_sort_key(s):
                        victim = cand      # never evict a MORE urgent row
                self.stats.page_preemptions += 1
                self._preempt_row(victim)
                if victim == row:
                    break                  # the row yielded its own slot

    # -- faults ----------------------------------------------------------

    def _capture_snapshots(self) -> None:
        """Refresh every occupied row's last-good snapshot at this chunk
        boundary (one padded gather for the whole pool)."""
        rows = self.pool.occupied_rows()
        if not rows:
            return
        with self.telemetry.span("snapshot_capture", cat="scheduler",
                                 rows=len(rows)):
            snaps = self.pool.snapshot_rows(rows, self.stats.ticks)
        for row, snap in zip(rows, snaps):
            self.snapshots[row] = snap
            self.stats.snapshots += 1
            self.timelines.stamp(snap.rid, "snapshot", self.stats.ticks,
                                 row=row)

    def _quarantine(self, row: int) -> None:
        """Isolate a faulty row: discard its poisoned chunk, scrub the
        row's cache (NaN must never linger where additive masks could leak
        it), and requeue the request from its last good snapshot — or from
        scratch when none exists. Bounded by `max_retries`; exhaustion
        sheds the request explicitly. Neighbour rows are untouched."""
        slot = self.pool.slots[row]
        self.stats.quarantines += 1
        self.timelines.stamp(slot.request.rid, "quarantined",
                             self.stats.ticks, row=row,
                             retries=slot.retries + 1)
        snap = self.snapshots.pop(row, None)
        if snap is not None and snap.rid != slot.request.rid:
            snap = None                    # snapshot of a previous tenant
        entry = _QueueEntry(request=slot.request, seq=slot.seq,
                            snapshot=snap, retries=slot.retries + 1)
        self.pool.retire(row)
        self.pool.scrub_row(row)
        if entry.retries > self.max_retries:
            self._shed(entry, SHED_RETRIES_EXHAUSTED)
            return
        self.stats.retries += 1
        self.waiting.append(entry)

    def _collect_faults(self, bad: np.ndarray) -> Set[int]:
        """Rows to quarantine after a chunk: non-finite-logits flags from
        the device (the NaN guard) plus the injector's failure reports.
        Only live DECODING rows can fault — masked ride-along rows' logits
        are discarded anyway."""
        faulted: Set[int] = set()
        if self.nan_guard:
            for row in np.flatnonzero(bad):
                slot = self.pool.slots[row]
                if slot is not None and slot.state == DECODING:
                    # repro-lint: allow[RL002] host row index
                    faulted.add(int(row))
        if self.fault_injector is not None:
            for row in self.fault_injector.failed_rows(self.stats.chunks):
                if self.pool.slots[row] is not None:
                    # repro-lint: allow[RL002] host row index
                    faulted.add(int(row))
        return faulted

    def _drain_chunk(self, toks: np.ndarray,
                     on_token: Optional[Callable[[int, int], None]],
                     on_complete: Optional[Callable[[int, List[int]], None]],
                     results: Dict[int, List[int]]) -> None:
        """Distribute a chunk's tokens to their requests; retire EOS'd /
        budget-exhausted slots. A requeued request's already-streamed
        tokens are not re-streamed (`_streamed` high-water mark)."""
        for row in range(self.pool.max_batch):
            slot = self.pool.slots[row]
            if slot is None or slot.state != DECODING:
                continue                 # PREFILLING rows rode along masked
            done = False
            rid = slot.request.rid
            budget = slot.request.max_new_tokens
            for tok in toks[row].tolist():
                # budget check BEFORE appending: emit at most `budget`
                if tok == EOS or len(slot.emitted) >= budget:
                    done = True
                    break
                slot.emitted.append(tok)
                if on_token is not None \
                        and len(slot.emitted) > self._streamed.get(rid, 0):
                    self._streamed[rid] = len(slot.emitted)
                    on_token(rid, tok)
            if len(slot.emitted) >= budget:
                done = True
            if done:
                results[rid] = slot.emitted
                self.completed_at[rid] = self.stats.ticks
                dl = slot.request.deadline_ticks
                if dl is not None and self.stats.ticks > dl:
                    self.stats.deadline_misses += 1
                    self.timelines.stamp(rid, "deadline_miss",
                                         self.stats.ticks, deadline=dl)
                self.timelines.stamp(rid, "retired", self.stats.ticks,
                                     n_tokens=len(slot.emitted))
                if on_complete is not None:
                    on_complete(rid, slot.emitted)
                self.snapshots.pop(row, None)
                self.pool.retire(row)

    # -- main loop -------------------------------------------------------

    def run(self,
            on_token: Optional[Callable[[int, int], None]] = None,
            on_complete: Optional[Callable[[int, List[int]], None]] = None,
            ) -> Dict[int, object]:
        """Drive the pool until every submitted request completes or is
        shed. Returns {rid: tokens} (tokens exclude EOS, capped at
        max_new_tokens) with an explicit `ShedResult` in place of the token
        list for rejected requests."""
        results: Dict[int, object] = {}
        chunk = self.engine.decode_chunk
        while self.waiting or self.pool.occupancy:
            self._admit_ready()
            if self.engine.prefill_chunk:
                self._advance_prefill()
            self._ensure_decode_pages(chunk)
            if self.pool.paged:
                # page-occupancy gauge + allocation/quant-error telemetry,
                # refreshed when the allocator state changed since the
                # last publish (steady-state decode rounds skip it — part
                # of the scheduler-round fast path)
                page_stats = (self.pool.alloc.used_pages,
                              self.pool.pages_allocated,
                              self.pool.pages_freed,
                              self.pool.quant_error_bound)
                if page_stats != self._page_stats_last:
                    self._page_stats_last = page_stats
                    reg = self.stats.registry
                    reg.gauge("serving_pages_in_use").set(
                        self.pool.alloc.used_pages)
                    reg.gauge("serving_pages_free").set(
                        self.pool.alloc.free_pages)
                    reg.counter("serving_pages_allocated_total").value = \
                        float(self.pool.pages_allocated)
                    reg.counter("serving_pages_freed_total").value = \
                        float(self.pool.pages_freed)
                    reg.counter("serving_quant_error_bound_sum").value = \
                        float(self.pool.quant_error_bound)
            decoding = self.pool.decoding_count
            if not decoding:
                # nothing decodable yet (pool empty, or every occupied slot
                # still prefilling): let virtual time pass so future
                # arrival_chunk requests become admissible
                self.stats.idle_ticks += 1
                continue
            if self.snapshot_chunks and \
                    self.stats.chunks % self.snapshot_chunks == 0:
                self._capture_snapshots()
            if self.fault_injector is not None:
                self.fault_injector.before_chunk(self.pool, self.snapshots,
                                                 self.stats.chunks)
            # one span per chunk, closed at the chunk's single host sync —
            # stamping here adds ZERO device syncs (the sync already exists)
            with self.telemetry.span("decode_chunk", cat="scheduler",
                                     rows=decoding, chunk=chunk,
                                     tick=self.stats.ticks):
                toks, bad, self.rng = self.pool.decode_chunk(chunk, self.rng)
            faulted = self._collect_faults(bad)
            self.stats.chunks += 1
            self.stats.row_steps += decoding * chunk
            self.stats.occupancy_sum += self.pool.occupancy \
                / self.pool.max_batch
            for row in sorted(faulted):
                self._quarantine(row)      # retires the row: drain skips it
            self._drain_chunk(toks, on_token, on_complete, results)
        results.update(self.shed)
        # fold raw lifecycle stamps into the per-priority SLO histograms
        # (queue wait, TTFT, TPOT, deadline slack) of this run's registry
        self.timelines.finalize(self.stats.registry)
        return results
