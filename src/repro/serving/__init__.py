from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.faults import Fault, FaultInjector  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    Request, ScheduleStats, Scheduler, ShedResult, SlotPool)
from repro.serving.snapshot import SlotSnapshot  # noqa: F401
