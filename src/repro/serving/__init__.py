from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    Request, ScheduleStats, Scheduler, SlotPool)
