"""Mesh-aware attention execution plan.

ONE place decides how attention executes: which backend (fused Pallas
kernels vs pure-jnp reference), which backward implementation, and — under a
mesh — which mesh axes the fused kernels shard over and with what shard_map
in/out specs. Call sites (models/attention.py, core/cache.py, the trainer
and the serving engine) thread an :class:`AttentionPlan` and never branch on
backend strings or mesh presence themselves; adding a new parallelism
feature means extending the plan, not forking another call site.

Resolution (`resolve_attention_plan`, cached per (config, ctx)):

* backend/backward_impl: the `AttentionConfig` knobs through
  `kernels/common.resolve_backend` (the "auto" platform rule).
* head parallelism (tp): `ctx.model_axis`, when present in the mesh with
  size > 1. The KV-head axis shards — `launch/mesh.validate_attention_mesh`
  fails fast unless tp divides Hkv — and per-head E/F shard with their
  heads; the shared (c, r) / (S, K) projections replicate.
* sequence parallelism (sp): `ctx.seq_axis`, when present with size > 1.
  Each shard keeps its causal blocks RESIDENT and all-gathers only the
  compressed k̄/v̄ prefix ((B, M, D) bytes — the Linformer win;
  core/seq_parallel.py holds the shard-local bodies). The fused backward's
  full-buffer fp32 dk̄/dv̄ accumulators reduce across shards via the
  all-gather transpose (psum-scatter inside the manual region).
* batch: the data-like axes shard the batch dim inside the same manual
  region whenever they divide B (otherwise the batch rides replicated).

Per attention form:

* train fwd/bwd (`causal_attention`, `exact_attention`): tp × sp.
* chunk prefill (`chunk_prefill_attention`): tp; sp additionally shards the
  chunk's query blocks when the chunk length divides (falls back to
  head-parallel-only otherwise — chunks are admission-sized).
* decode (`decode_attention`): tp only — the kernel's two pinned cache
  operands get per-shard slots (Hkv/tp heads); a single query token has no
  sequence to shard, so the sp axis idles at decode (a flash-decode style
  split over the slot axis is a future plan extension, see ROADMAP).

The fused kernels run PER SHARD with purely local shapes — `kernels/ops.py`
wrappers keep their fail-fast shape contracts and never know about meshes.
The manual region is FULL-manual (every mesh axis manual; unused axes ride
replicated), sidestepping the partial-manual + scanned-layers XLA CHECK
documented in train/compressed_dp.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import AttentionConfig
from repro.core import causal as causal_lib
from repro.core import linformer as lin_lib
from repro.core import seq_parallel as sp_lib
from repro.kernels import ops as kernel_ops
from repro.kernels.common import resolve_backend, resolve_backward_impl
from repro.launch.mesh import (axis_size, validate_attention_mesh,
                               validate_seq_shards)
from repro.parallel.sharding import ParallelCtx, shard_map as _shard_map

# The axis-name registry: every mesh this stack builds (launch/mesh.py) and
# every PartitionSpec it writes draws from these four names. repro-lint's
# RL005 rule (src/repro/analysis/astlint.py, docs/static-analysis.md)
# enforces that no other axis-name literal appears in a spec — add the axis
# HERE first, then use it.
DECLARED_AXES = frozenset({"data", "model", "seq", "pod"})


def _tuned_exact_blocks(q: jax.Array, slots: int) -> Tuple[int, int]:
    """Trace-time tuning-table lookup for the exact form's grid knobs
    (block_q, block_s), keyed on the LOCAL (per-shard) shapes the kernels
    actually launch with. Falls back to kernels/common.py defaults on any
    table miss; shapes are static Python ints so this never traces."""
    from repro.tune import table as tuning
    kw = dict(seq=q.shape[1], slots=slots, heads=q.shape[2],
              dtype=str(q.dtype))
    return tuning.block_q_for(**kw), tuning.block_s_for(**kw)


@dataclasses.dataclass(frozen=True)
class AttentionPlan:
    """Resolved execution plan for every attention form of one config on one
    mesh. Frozen + hashable: resolved once per (config, ctx) and threaded
    through trace-time code."""

    backend: str                      # "fused" | "reference" (resolved)
    backward_impl: str = "fused"      # "fused" | "reference"
    mesh: Optional[Mesh] = None
    tp_axis: Optional[str] = None     # mesh axis sharding the (KV-)head dim
    sp_axis: Optional[str] = None     # mesh axis sharding the sequence dim
    data_axes: Tuple[str, ...] = ()   # batch axes inside the manual region

    # -- resolution helpers -------------------------------------------------

    @property
    def fused(self) -> bool:
        return self.backend == "fused"

    @property
    def tp(self) -> int:
        return axis_size(self.mesh, self.tp_axis) if self.tp_axis else 1

    @property
    def sp(self) -> int:
        return axis_size(self.mesh, self.sp_axis) if self.sp_axis else 1

    @property
    def manual(self) -> bool:
        """Whether the fused kernels run per-shard inside shard_map."""
        return self.fused and self.mesh is not None and (
            self.tp > 1 or self.sp > 1)

    def _batch_axes(self, B: int):
        """Data axes shard the batch inside the manual region only when they
        divide it; otherwise the batch rides replicated (correct either way —
        attention is per-row independent)."""
        if not self.data_axes:
            return None
        size = 1
        for a in self.data_axes:
            size *= axis_size(self.mesh, a)
        if size > 1 and B % size == 0:
            return tuple(self.data_axes)
        return None

    def _sp_for(self, S: int, block_size: int, *, required: bool):
        """The sequence axis for an S-token form, or None when sp is off.
        `required=True` (training) fails fast on indivisible shapes;
        `required=False` (chunk prefill) falls back to head-parallel-only."""
        if self.sp <= 1:
            return None
        if S % (self.sp * block_size) != 0:
            if required:
                validate_seq_shards(S, block_size, self.sp, self.sp_axis)
            return None
        return self.sp_axis

    def _ef_spec(self, E: jax.Array) -> P:
        """Per-head E/F (Hkv, c, r) shard with their heads; the shared
        (c, r) projection replicates."""
        if E.ndim == 3:
            return P(self.tp_axis if self.tp > 1 else None, None, None)
        return P(None, None)

    def _head_axis(self):
        return self.tp_axis if self.tp > 1 else None

    def _smap(self, body, in_specs, out_specs):
        return _shard_map(body, mesh=self.mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)

    # -- train fwd/bwd: blockwise-causal (linformer_causal) -----------------

    def causal_attention(self, q, k, v, E, F, *, block_size: int,
                         block_slots: int, scale: float,
                         chunked: bool = False) -> jax.Array:
        """Full-sequence blockwise-causal attention — the training form,
        differentiable end to end under every sharding the plan resolves.
        q (B, S, H, Dh); k/v (B, S, Hkv, Dh); E/F (c, r) or (Hkv, c, r)."""
        if not self.fused:
            # reference backend: GSPMD partitions the einsums under any mesh
            # (the pre-plan behaviour); `chunked` selects the memory-bounded
            # long-S form exactly as before.
            fn = (causal_lib.blockwise_causal_attention_chunked if chunked
                  else causal_lib.blockwise_causal_attention)
            return fn(q, k, v, E, F, block_size=block_size, scale=scale)
        if not self.manual:
            # the fused kernel streams query blocks itself in BOTH
            # directions (fwd + fused bwd), so `chunked` needs no handling
            # on this path
            return kernel_ops.fused_blockwise_causal_attention(
                q, k, v, E, F, block_size=block_size,
                block_slots=block_slots, scale=scale,
                backward_impl=self.backward_impl)
        B, S, _, _ = q.shape
        sp_axis = self._sp_for(S, block_size, required=True)
        b = self._batch_axes(B)
        tp = self._head_axis()
        qkv_spec = P(b, sp_axis, tp, None)
        espec = self._ef_spec(E)
        bi = self.backward_impl

        def body(q_l, k_l, v_l, E_l, F_l):
            if sp_axis is None:
                return kernel_ops.fused_blockwise_causal_attention(
                    q_l, k_l, v_l, E_l, F_l, block_size=block_size,
                    block_slots=block_slots, scale=scale, backward_impl=bi)
            return sp_lib.sp_blockwise_causal_attention(
                q_l, k_l, v_l, E_l, F_l, seq_axis=sp_axis,
                block_size=block_size, block_slots=block_slots, scale=scale,
                fused=True, backward_impl=bi)

        return self._smap(body, (qkv_spec,) * 3 + (espec, espec),
                          qkv_spec)(q, k, v, E, F)

    # -- train fwd/bwd: exact bidirectional (linformer) ---------------------

    def exact_attention(self, q, k, v, E, F, *, projection: str,
                        scale: float) -> jax.Array:
        """Exact (bidirectional) Linformer attention: sequence projection of
        K/V plus attention over the K compressed slots.

        The manual region covers the paper's default shared linear
        E ∈ R^{S×K} (rows sharded over sp, heads over tp). Per-head / conv /
        pool projections keep the pre-plan behaviour: reference projection +
        fused attention, partitioned by GSPMD."""
        if not self.fused:
            return lin_lib.exact_linformer_attention(q, k, v, E, F,
                                                     kind=projection)
        S = q.shape[1]
        linear_shared = projection == "linear" and E.ndim == 2
        if linear_shared:
            E = E[:S] if E.shape[0] != S else E
            F = F[:S] if F.shape[0] != S else F
        if not self.manual or not linear_shared:
            if linear_shared:
                block_q, block_s = _tuned_exact_blocks(q, E.shape[-1])
                kbar = kernel_ops.fused_seq_projection(k, E, block_s=block_s)
                vbar = kernel_ops.fused_seq_projection(v, F, block_s=block_s)
            else:
                kbar, vbar = lin_lib.project_kv(k, v, E, F, kind=projection)
                block_q, _ = _tuned_exact_blocks(q, kbar.shape[1])
            return kernel_ops.fused_linformer_attention(q, kbar, vbar,
                                                        scale=scale,
                                                        block_q=block_q)
        B = q.shape[0]
        sp_axis = self.sp_axis if (self.sp > 1 and S % self.sp == 0) else None
        b = self._batch_axes(B)
        tp = self._head_axis()
        qkv_spec = P(b, sp_axis, tp, None)
        espec = P(sp_axis, None)

        def body(q_l, k_l, v_l, E_l, F_l):
            if sp_axis is None:
                block_q, block_s = _tuned_exact_blocks(q_l, E_l.shape[-1])
                kbar = kernel_ops.fused_seq_projection(k_l, E_l,
                                                       block_s=block_s)
                vbar = kernel_ops.fused_seq_projection(v_l, F_l,
                                                       block_s=block_s)
                return kernel_ops.fused_linformer_attention(q_l, kbar, vbar,
                                                            scale=scale,
                                                            block_q=block_q)
            return sp_lib.sp_exact_linformer_attention(
                q_l, k_l, v_l, E_l, F_l, seq_axis=sp_axis, scale=scale,
                fused=True)

        return self._smap(body, (qkv_spec,) * 3 + (espec, espec),
                          qkv_spec)(q, k, v, E, F)

    # -- chunk prefill ------------------------------------------------------

    def chunk_prefill_attention(self, q, k, v, comp_k, comp_v, start_blocks,
                                *, block_size: int, block_slots: int,
                                scale: float) -> jax.Array:
        """Prefix-form attention for a prefill chunk at per-row offsets
        against the slot-resident compressed cache. q (B, P, H, Dh); comp_*
        (B, M, Hkv, Dh) full slot buffers; start_blocks (B,) int32."""
        if not self.fused:
            return causal_lib.blockwise_causal_prefix_attention(
                q, k, v, comp_k, comp_v, start_blocks,
                block_size=block_size, block_slots=block_slots, scale=scale)
        if not self.manual:
            return kernel_ops.fused_chunk_prefill_attention(
                q, k, v, comp_k, comp_v, start_blocks,
                block_size=block_size, block_slots=block_slots, scale=scale,
                backward_impl=self.backward_impl)
        B, Pq, _, _ = q.shape
        sp_axis = self._sp_for(Pq, block_size, required=False)
        nb_l = (Pq // self.sp) // block_size if sp_axis else 0
        b = self._batch_axes(B)
        tp = self._head_axis()
        qkv_spec = P(b, sp_axis, tp, None)
        comp_spec = P(b, None, tp, None)    # full pinned buffer per shard

        def body(q_l, k_l, v_l, ck_l, cv_l, sb_l):
            if sp_axis is not None:
                # shard d of the chunk starts nb_l blocks further in
                sb_l = sb_l + jax.lax.axis_index(sp_axis) * nb_l
            return kernel_ops.fused_chunk_prefill_attention(
                q_l, k_l, v_l, ck_l, cv_l, sb_l, block_size=block_size,
                block_slots=block_slots, scale=scale,
                backward_impl=self.backward_impl)

        return self._smap(
            body, (qkv_spec,) * 3 + (comp_spec, comp_spec, P(b)),
            qkv_spec)(q, k, v, comp_k, comp_v, start_blocks)

    # -- decode -------------------------------------------------------------

    def decode_attention(self, q_t, raw_k, raw_v, comp_k, comp_v, loc_ok,
                         glob_ok, *, scale: float) -> jax.Array:
        """Single-token decode attention over [raw ring | compressed slots]
        with per-row validity masks. q_t (B, 1, H, Dh); raw_* (B, c, Hkv,
        Dh); comp_* (B, M, Hkv, Dh); loc_ok (B, c) / glob_ok (B, M) bool."""
        if not self.fused:
            return causal_lib.masked_decode_attention(
                q_t, raw_k, raw_v, comp_k, comp_v, loc_ok, glob_ok,
                scale=scale)
        bias_loc = jnp.where(loc_ok, 0.0,
                             causal_lib.NEG_INF).astype(jnp.float32)
        bias_glob = jnp.where(glob_ok, 0.0,
                              causal_lib.NEG_INF).astype(jnp.float32)
        if not self.manual or self.tp <= 1:
            # decode has no sequence to shard: without tp the sp/data axes
            # ride replicated and the plain per-device call is the plan
            return kernel_ops.fused_decode_attention(
                q_t, raw_k, raw_v, comp_k, comp_v, bias_loc, bias_glob,
                scale=scale)
        B = q_t.shape[0]
        b = self._batch_axes(B)
        tp = self._head_axis()
        kv_spec = P(b, None, tp, None)      # per-shard pinned cache slots

        def body(q_l, rk_l, rv_l, ck_l, cv_l, bl_l, bg_l):
            return kernel_ops.fused_decode_attention(
                q_l, rk_l, rv_l, ck_l, cv_l, bl_l, bg_l, scale=scale)

        return self._smap(
            body,
            (kv_spec, kv_spec, kv_spec, kv_spec, kv_spec,
             P(b, None), P(b, None)),
            kv_spec)(q_t, raw_k, raw_v, comp_k, comp_v, bias_loc, bias_glob)

    # -- decode / chunk prefill, quantized paged cache ----------------------

    def decode_attention_q(self, q_t, raw_k, raw_v, raw_k_s, raw_v_s,
                           comp_k, comp_v, comp_k_s, comp_v_s, loc_ok,
                           glob_ok, *, scale: float) -> jax.Array:
        """Quantized-cache decode: the ring and the page-gathered slots
        arrive in their storage dtype (int8/fp8) with fp32 scales —
        raw_*_s (B, c, Hkv) per token, comp_*_s (B, M, Hkv) per slot. The
        fused path dequantizes INSIDE the kernel; the reference path
        dequantizes in jnp and reuses the dense reference (the parity
        oracle the tolerance bands are measured against). Sharding is the
        dense decode sharding — scales shard with their heads."""
        if not self.fused:
            deq = lambda x, s: x.astype(jnp.float32) * s[..., None]
            return causal_lib.masked_decode_attention(
                q_t, deq(raw_k, raw_k_s), deq(raw_v, raw_v_s),
                deq(comp_k, comp_k_s), deq(comp_v, comp_v_s),
                loc_ok, glob_ok, scale=scale)
        bias_loc = jnp.where(loc_ok, 0.0,
                             causal_lib.NEG_INF).astype(jnp.float32)
        bias_glob = jnp.where(glob_ok, 0.0,
                              causal_lib.NEG_INF).astype(jnp.float32)
        if not self.manual or self.tp <= 1:
            return kernel_ops.fused_decode_attention_q(
                q_t, raw_k, raw_v, raw_k_s, raw_v_s, comp_k, comp_v,
                comp_k_s, comp_v_s, bias_loc, bias_glob, scale=scale)
        B = q_t.shape[0]
        b = self._batch_axes(B)
        tp = self._head_axis()
        kv_spec = P(b, None, tp, None)      # per-shard pinned cache slots
        sc_spec = P(b, None, tp)            # (B, c|M, Hkv) scales

        def body(q_l, rk_l, rv_l, rks_l, rvs_l, ck_l, cv_l, cks_l, cvs_l,
                 bl_l, bg_l):
            return kernel_ops.fused_decode_attention_q(
                q_l, rk_l, rv_l, rks_l, rvs_l, ck_l, cv_l, cks_l, cvs_l,
                bl_l, bg_l, scale=scale)

        return self._smap(
            body,
            (kv_spec, kv_spec, kv_spec, sc_spec, sc_spec, kv_spec, kv_spec,
             sc_spec, sc_spec, P(b, None), P(b, None)),
            kv_spec)(q_t, raw_k, raw_v, raw_k_s, raw_v_s, comp_k, comp_v,
                     comp_k_s, comp_v_s, bias_loc, bias_glob)

    def chunk_prefill_attention_q(self, q, k, v, comp_k, comp_v, comp_k_s,
                                  comp_v_s, start_blocks, *, block_size: int,
                                  block_slots: int, scale: float) -> jax.Array:
        """Quantized-cache chunk prefill: the page-gathered compressed
        buffer stays in its storage dtype with per-slot scales
        (comp_*_s (B, M, Hkv)); the chunk's own K/V are full-precision
        activations. Same sharding shape as the dense chunk prefill."""
        if not self.fused:
            deq = lambda x, s: x.astype(jnp.float32) * s[..., None]
            return causal_lib.blockwise_causal_prefix_attention(
                q, k, v, deq(comp_k, comp_k_s), deq(comp_v, comp_v_s),
                start_blocks, block_size=block_size,
                block_slots=block_slots, scale=scale)
        if not self.manual:
            return kernel_ops.fused_chunk_prefill_attention_q(
                q, k, v, comp_k, comp_v, comp_k_s, comp_v_s, start_blocks,
                block_size=block_size, block_slots=block_slots, scale=scale)
        B, Pq, _, _ = q.shape
        sp_axis = self._sp_for(Pq, block_size, required=False)
        nb_l = (Pq // self.sp) // block_size if sp_axis else 0
        b = self._batch_axes(B)
        tp = self._head_axis()
        qkv_spec = P(b, sp_axis, tp, None)
        comp_spec = P(b, None, tp, None)    # full pinned buffer per shard
        sc_spec = P(b, None, tp)            # (B, M, Hkv) per-slot scales

        def body(q_l, k_l, v_l, ck_l, cv_l, cks_l, cvs_l, sb_l):
            if sp_axis is not None:
                sb_l = sb_l + jax.lax.axis_index(sp_axis) * nb_l
            return kernel_ops.fused_chunk_prefill_attention_q(
                q_l, k_l, v_l, ck_l, cv_l, cks_l, cvs_l, sb_l,
                block_size=block_size, block_slots=block_slots, scale=scale)

        return self._smap(
            body,
            (qkv_spec,) * 3 + (comp_spec, comp_spec, sc_spec, sc_spec, P(b)),
            qkv_spec)(q, k, v, comp_k, comp_v, comp_k_s, comp_v_s,
                      start_blocks)

    # -- cache / batch placement specs --------------------------------------

    def cache_pspecs(self, cache: Dict) -> Dict[str, P]:
        """PartitionSpec per decode-cache leaf: the KV-head axis shards over
        tp — the decode kernel's two pinned operands get PER-SHARD slots —
        everything else (layers, batch rows, slot/ring positions)
        replicated; `lengths` (B,) is host-consulted bookkeeping and stays
        replicated.

        Paged-cache leaves are name-aware: the page table (int32 indices,
        no head axis) replicates; scale leaves (``*_s`` — (..., c|page,
        Hkv), head axis LAST) shard their last axis; quantized payloads
        (ring (L, B, c, Hkv, Dh) and arena (L, Np, r, Hkv, Dh)) follow the
        generic Hkv-at-nd-2 rule."""
        tp = self._head_axis()
        specs = {}
        for name, leaf in cache.items():
            nd = getattr(leaf, "ndim", None) or len(leaf.shape)
            if name == "lengths" or name == "page_table" or nd < 2:
                specs[name] = P(*([None] * nd))
            elif name.endswith("_s"):
                parts = [None] * nd
                parts[nd - 1] = tp          # (..., Hkv) scales
                specs[name] = P(*parts)
            else:
                parts = [None] * nd
                parts[nd - 2] = tp          # (..., Hkv, Dh)
                specs[name] = P(*parts)
        return specs

    def cache_shardings(self, cache: Dict):
        """NamedSharding tree for a pool/decode cache (None without a
        mesh)."""
        if self.mesh is None:
            return None
        return {k: NamedSharding(self.mesh, s)
                for k, s in self.cache_pspecs(cache).items()}

    def place_cache(self, cache: Dict) -> Dict:
        """Lay a freshly initialized cache out per `cache_pspecs` (no-op
        without a mesh) so jit'd consumers inherit the per-shard-slot
        layout instead of re-deciding it per call."""
        sh = self.cache_shardings(cache)
        if sh is None:
            return cache
        return {k: jax.device_put(v, sh[k]) for k, v in cache.items()}


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _resolve_cached(acfg: AttentionConfig,
                    ctx: Optional[ParallelCtx]) -> AttentionPlan:
    backend = resolve_backend(acfg.backend)
    backward_impl = resolve_backward_impl(acfg.backward_impl)
    if ctx is None or ctx.mesh is None:
        return AttentionPlan(backend=backend, backward_impl=backward_impl)
    mesh = ctx.mesh
    tp_axis = (ctx.model_axis
               if axis_size(mesh, ctx.model_axis) > 1 else None)
    sp_axis = (ctx.seq_axis
               if axis_size(mesh, ctx.seq_axis) > 1 else None)
    if backend == "fused" and tp_axis is not None:
        # the model axis is shared (tensor AND expert parallelism): a width
        # that cannot shard Hkv warns and demotes attention to its pre-plan
        # unsharded-fused path instead of sinking the whole model
        if not validate_attention_mesh(
                mesh, num_heads=acfg.num_heads,
                num_kv_heads=acfg.num_kv_heads,
                model_axis=ctx.model_axis):
            tp_axis = None
    return AttentionPlan(backend=backend, backward_impl=backward_impl,
                         mesh=mesh, tp_axis=tp_axis, sp_axis=sp_axis,
                         data_axes=tuple(ctx.data_axes))


def resolve_attention_plan(acfg: AttentionConfig,
                           ctx: Optional[ParallelCtx] = None
                           ) -> AttentionPlan:
    """Resolve the execution plan for one attention config on one parallel
    context — cached, so repeated trace-time resolution is free. Fails fast
    (launch/mesh.py style) when the mesh cannot shard the config."""
    return _resolve_cached(acfg, ctx)


def as_plan(plan: Union["AttentionPlan", str, None]) -> AttentionPlan:
    """Normalize a plan-or-backend-string (the compatibility surface for
    direct kernel-level callers and tests): strings resolve to a
    single-device plan of that backend; None means the reference plan."""
    if isinstance(plan, AttentionPlan):
        return plan
    return AttentionPlan(backend=resolve_backend(plan or "reference"))


# ---------------------------------------------------------------------------
# Batch / pod placement specs (plan-driven spec selection for the trainer
# and the compressed-DP step — previously hand-written at the call sites)
# ---------------------------------------------------------------------------


def data_batch_pspec(ctx: ParallelCtx, ndim: int) -> P:
    """Batch tensors shard their leading dim over the data-like axes."""
    return P(ctx.data_axes if ctx.data_axes else None,
             *([None] * (ndim - 1)))


def pod_stacked_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """A tensor with an explicit leading pod axis (compressed-DP's per-pod
    params/residual stacks): P('pod') on dim 0, replicated elsewhere."""
    return NamedSharding(mesh, P("pod", *([None] * (ndim - 1))))


def pod_batch_sharding(mesh: Mesh, data_axes: Tuple[str, ...],
                       ndim: int) -> NamedSharding:
    """A batch reshaped to (n_pods, per_pod_batch, ...): pod axis leading,
    the per-pod batch over the remaining data axes."""
    return NamedSharding(
        mesh, P("pod", tuple(data_axes) if data_axes else None,
                *([None] * (ndim - 2))))
