"""Sharding rules: map parameter-tree paths and activations to PartitionSpecs.

The mesh has axes ("data", "model") single-pod or ("pod", "data", "model")
multi-pod (launch/mesh.py). Batch always shards over the data-like axes;
parameters shard over "model" (tensor/expert parallel) and optionally over the
data-like axes too (FSDP / ZeRO-3, per-arch `MeshConfig.fsdp`).
"""
from __future__ import annotations

import dataclasses
import inspect
import re
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax>=0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map_impl
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SM_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names: Optional[frozenset] = None):
    """Version-portable ``shard_map`` wrapper.

    Newer JAX spells the replication check ``check_vma`` and partial-manual
    mode ``axis_names`` (the MANUAL axes); older releases spell them
    ``check_rep`` and ``auto`` (the complement: axes left to GSPMD). Callers
    use the new-style keywords; this adapter translates for whichever JAX is
    installed — the root cause of the seed's test_distributed failures.
    ``check_vma`` defaults to True, matching upstream.
    """
    kw = {}
    if "check_vma" in _SM_PARAMS:
        kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
    else:
        kw["check_rep"] = check_vma
        if axis_names is not None:
            kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Static description of the parallel environment threaded through model
    apply functions. None everywhere = single-device (smoke tests)."""

    mesh: Optional[Mesh] = None
    model_axis: str = "model"
    # mesh axis carrying sequence parallelism for the fused attention plan
    # (parallel/plan.py); absent from the mesh = no sequence sharding
    seq_axis: str = "seq"
    # "none" | "data" | "pod_data" | "experts_data" | "experts_pod_data"
    # ("experts_*": only MoE expert stacks are FSDP-sharded — serving keeps
    #  the small attention/norm weights TP-only so decode never regathers
    #  them; §Perf iteration kimi/decode_32k #3)
    fsdp: str = "none"
    # axes excluded from activation sharding specs (used inside partial-auto
    # shard_map regions where an axis is manual — train/compressed_dp.py)
    exclude_data_axes: Tuple[str, ...] = ()

    @property
    def data_axes(self) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in self.mesh.axis_names
                     if a in ("pod", "data")
                     and a not in self.exclude_data_axes)

    @property
    def fsdp_scope(self) -> str:
        return "moe" if self.fsdp.startswith("experts") else "all"

    @property
    def fsdp_axes(self) -> Tuple[str, ...]:
        if self.fsdp in ("data", "experts_data"):
            return ("data",)
        if self.fsdp in ("pod_data", "experts_pod_data"):
            return tuple(a for a in ("pod", "data") if self.mesh is None
                         or a in self.mesh.axis_names)
        return ()

    @property
    def has_pod_axis(self) -> bool:
        """Whether the mesh carries the multi-pod DP axis. Call sites branch
        on THIS (trainer's compressed-DP selection, train/compressed_dp.py's
        precondition) instead of inspecting mesh.axis_names themselves —
        axis introspection stays in the parallel layer (repro-lint RL001)."""
        return self.mesh is not None and "pod" in self.mesh.axis_names

    @property
    def model_shards(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def seq_shards(self) -> int:
        if self.mesh is None or self.seq_axis not in self.mesh.axis_names:
            return 1
        return self.mesh.shape[self.seq_axis]


def shard_activation(x: jax.Array, ctx: Optional[ParallelCtx],
                     spec: Optional[P] = None) -> jax.Array:
    """Constrain an activation's sharding; no-op without a mesh.

    Default spec: batch over the data-like axes, rest replicated.
    """
    if ctx is None or ctx.mesh is None:
        return x
    if spec is None:
        spec = P(ctx.data_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

# (regex on '/'.join(path), builder(fsdp_axes) -> PartitionSpec)
# Layer-stacked params carry a leading L axis -> leading None.
# Convention for 2-D matmul weights: contract dim gets FSDP, output dim gets
# TP ("model") for the in-projections; mirrored for out-projections.


def _rules(fsdp):
    F = fsdp if fsdp else None      # tuple of axes or None
    return [
        # embeddings / lm head: vocab over model, d_model over fsdp
        (r"(^|/)embed/tok$", P("model", F)),
        (r"(^|/)embed/pos$", P(None, F)),
        (r"(^|/)lm_head$", P(F, "model")),
        # attention projections (leading L when stacked)
        (r"attn/wq$", P(None, F, "model")),
        (r"attn/wk$", P(None, F, "model")),
        (r"attn/wv$", P(None, F, "model")),
        (r"attn/wo$", P(None, "model", F)),
        (r"attn/b[qkv]$", P(None, "model")),
        # dense MLP
        (r"mlp/w_in$", P(None, F, "model")),
        (r"mlp/w_gate$", P(None, F, "model")),
        (r"mlp/w_out$", P(None, "model", F)),
        # MoE: experts over model (EP), hidden over fsdp
        (r"moe/router$", P(None, F, None)),
        (r"moe/w_in$", P(None, "model", F, None)),
        (r"moe/w_gate$", P(None, "model", F, None)),
        (r"moe/w_out$", P(None, "model", None, F)),
        # mamba2 / rwkv6 big projections
        (r"ssm/w_in$", P(None, F, "model")),
        (r"ssm/w_out$", P(None, "model", F)),
        (r"rwkv/w_(r|k|v|g)$", P(None, F, "model")),
        (r"rwkv/w_o$", P(None, "model", F)),
        (r"rwkv/cm_w_k$", P(None, F, "model")),
        (r"rwkv/cm_w_v$", P(None, "model", F)),
        (r"rwkv/cm_w_r$", P(None, F, "model")),
        # shared (unstacked) attention/mlp block (zamba2): same but no L axis
        (r"shared_block/attn/w[qkv]$", P(F, "model")),
        (r"shared_block/attn/wo$", P("model", F)),
        (r"shared_block/mlp/w_(in|gate)$", P(F, "model")),
        (r"shared_block/mlp/w_out$", P("model", F)),
        # linformer E/F and everything small: replicated
    ]


def spec_for_path(path: str, fsdp_axes: Sequence[str], ndim: int,
                  fsdp_scope: str = "all") -> P:
    fsdp = tuple(fsdp_axes) if fsdp_axes else None
    if fsdp_scope == "moe" and not re.search(r"(^|/)(moe|embed|lm_head)",
                                             path):
        fsdp = None
    for pat, spec in _rules(fsdp):
        if re.search(pat, path):
            # trim/extend to the leaf's rank (shared blocks lack the L axis)
            parts = list(spec)
            if len(parts) > ndim:
                parts = parts[len(parts) - ndim:]
            while len(parts) < ndim:
                parts.append(None)
            # normalize 1-tuples to bare axis names: P(("data",),) and
            # P("data") shard identically but only compare equal once
            # normalized (PartitionSpec equality is structural)
            parts = [p[0] if isinstance(p, tuple) and len(p) == 1 else p
                     for p in parts]
            return P(*parts)
    return P(*([None] * ndim))      # replicate by default


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def param_shardings(params, ctx: ParallelCtx):
    """PartitionSpec pytree (or NamedSharding pytree if mesh set) matching
    `params` by path rules."""

    def leaf(path, x):
        spec = spec_for_path(_path_str(path), ctx.fsdp_axes, x.ndim,
                             ctx.fsdp_scope)
        if ctx.mesh is None:
            return spec
        return NamedSharding(ctx.mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, params)
