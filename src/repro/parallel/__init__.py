from repro.parallel.sharding import (  # noqa: F401
    ParallelCtx,
    param_shardings,
    shard_activation,
)
from repro.parallel.plan import (  # noqa: F401
    AttentionPlan,
    as_plan,
    resolve_attention_plan,
)
