from repro.parallel.sharding import (  # noqa: F401
    ParallelCtx,
    param_shardings,
    shard_activation,
)
