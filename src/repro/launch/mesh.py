"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device state
(jax locks the device count on first backend initialization — the dry-run
sets XLA_FLAGS before any jax import).

Target: TPU v5e. Single pod = 16×16 = 256 chips, axes ("data", "model").
Multi-pod = 2 pods = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is the outer data-parallel axis whose collectives cross DCN.
"""
from __future__ import annotations

import jax

# v5e hardware constants (roofline §EXPERIMENTS.md)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_shards: int = 1, seq_shards: int = 1):
    """Debug mesh over whatever devices exist (tests use 8 host devices).

    `model_shards` is the tensor-parallel ("model") width, `seq_shards` the
    sequence-parallel ("seq") width; the remainder goes to "data". With
    seq_shards == 1 the mesh keeps its historical 2-axis ("data", "model")
    shape, so existing tp-only callers see no change."""
    n = len(jax.devices())
    assert n % (model_shards * seq_shards) == 0, (n, model_shards, seq_shards)
    if seq_shards == 1:
        return jax.make_mesh((n // model_shards, model_shards),
                             ("data", "model"))
    return jax.make_mesh(
        (n // (model_shards * seq_shards), seq_shards, model_shards),
        ("data", "seq", "model"))


def axis_size(mesh, axis: str) -> int:
    """Size of `axis` in `mesh`, 1 if the mesh lacks it (or is None)."""
    if mesh is None or axis not in mesh.axis_names:
        return 1
    return mesh.shape[axis]


def validate_attention_mesh(mesh, *, num_heads: int, num_kv_heads: int,
                            model_axis: str = "model",
                            strict: bool = False) -> bool:
    """Check whether the mesh can HEAD-SHARD the fused attention kernels,
    with a clear signal when it cannot (mirrors the PR 4 fail-fast wrapper
    style: without this check, an indivisible head count surfaced as a
    shape error deep inside Pallas/shard_map).

    Head parallelism shards the KV-head axis, so the tensor-parallel width
    must divide Hkv (each shard keeps whole GQA groups: H/Hkv is preserved
    per shard automatically once Hkv divides). Returns True when it does.
    When it does not: ``strict=True`` raises; the default warns once and
    returns False — the model axis is SHARED infrastructure (tensor AND
    expert parallelism), so e.g. a 4-wide expert axis over an Hkv=2
    attention must not be fatal: the plan then runs attention on its
    pre-plan unsharded-fused path and only the head sharding is lost."""
    assert num_heads % num_kv_heads == 0, (num_heads, num_kv_heads)
    tp = axis_size(mesh, model_axis)
    if num_kv_heads % tp == 0:
        return True
    msg = (
        f"mesh axis {model_axis!r} has {tp} shards, which does not divide "
        f"num_kv_heads={num_kv_heads}: the fused attention kernels shard "
        f"the KV-head axis, so every shard needs whole KV heads. Use a "
        f"tensor-parallel width that divides {num_kv_heads}, or raise "
        f"num_kv_heads.")
    if strict:
        raise ValueError(msg)
    import warnings
    warnings.warn(msg + " Falling back to unsharded fused attention "
                  "(GSPMD) on this mesh.", stacklevel=2)
    return False


def validate_seq_shards(seq_len: int, block_size: int, sp: int,
                        seq_axis: str = "seq") -> None:
    """Fail fast when a sequence length cannot shard over the sequence axis:
    each shard must hold a whole number of attention blocks."""
    if seq_len % (sp * block_size) != 0:
        raise ValueError(
            f"sequence length {seq_len} cannot shard over mesh axis "
            f"{seq_axis!r} ({sp} shards): each shard must hold a whole "
            f"number of {block_size}-token attention blocks, i.e. S must be "
            f"a multiple of sp·c = {sp * block_size}. Pad the sequence or "
            f"change the mesh.")


# Per-arch FSDP policy: how far parameters/optimizer state are sharded over
# the data-like axes, chosen from per-device memory needs (see DESIGN.md §6).
ARCH_FSDP = {
    "qwen3-8b": "data",
    "qwen3-14b": "data",
    "nemotron-4-15b": "data",
    "qwen1.5-110b": "data",
    "kimi-k2-1t-a32b": "pod_data",
    "qwen3-moe-30b-a3b": "data",
    "internvl2-2b": "none",
    "zamba2-1.2b": "none",
    "musicgen-large": "none",
    "rwkv6-1.6b": "none",
    "linformer-paper": "none",
}


def fsdp_for(arch: str, multi_pod: bool) -> str:
    f = ARCH_FSDP.get(arch, "none")
    if f == "pod_data" and not multi_pod:
        return "data"
    return f
