"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device state
(jax locks the device count on first backend initialization — the dry-run
sets XLA_FLAGS before any jax import).

Target: TPU v5e. Single pod = 16×16 = 256 chips, axes ("data", "model").
Multi-pod = 2 pods = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is the outer data-parallel axis whose collectives cross DCN.
"""
from __future__ import annotations

import jax

# v5e hardware constants (roofline §EXPERIMENTS.md)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_shards: int = 1):
    """Debug mesh over whatever devices exist (tests use 8 host devices)."""
    n = len(jax.devices())
    assert n % model_shards == 0
    return jax.make_mesh((n // model_shards, model_shards), ("data", "model"))


# Per-arch FSDP policy: how far parameters/optimizer state are sharded over
# the data-like axes, chosen from per-device memory needs (see DESIGN.md §6).
ARCH_FSDP = {
    "qwen3-8b": "data",
    "qwen3-14b": "data",
    "nemotron-4-15b": "data",
    "qwen1.5-110b": "data",
    "kimi-k2-1t-a32b": "pod_data",
    "qwen3-moe-30b-a3b": "data",
    "internvl2-2b": "none",
    "zamba2-1.2b": "none",
    "musicgen-large": "none",
    "rwkv6-1.6b": "none",
    "linformer-paper": "none",
}


def fsdp_for(arch: str, multi_pod: bool) -> str:
    f = ARCH_FSDP.get(arch, "none")
    if f == "pod_data" and not multi_pod:
        return "data"
    return f
