"""Production training launcher.

    python -m repro.launch.train --arch qwen3-8b --smoke --steps 50
    python -m repro.launch.train --arch qwen3-8b --shape train_4k \
        --mesh single_pod            # on a real v5e pod slice

On multi-host TPU, initialize with --coordinator/--num-processes/--process-id
(jax.distributed); this container runs the --smoke path on CPU.
"""
import argparse
import dataclasses
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--attention", default=None,
                    help="override attention kind: standard|linformer_causal")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single_pod", "multi_pod", "local"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        import jax
        jax.distributed.initialize(args.coordinator, args.num_processes,
                                   args.process_id)

    from repro.configs import SHAPES_BY_NAME, get_config, get_smoke_config
    from repro.configs.base import OptimizerConfig, TrainConfig
    from repro.launch import mesh as mesh_lib
    from repro.parallel.sharding import ParallelCtx
    from repro.train import Trainer

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    if args.attention and cfg.family != "ssm":
        cfg = cfg.with_attention_kind(args.attention)

    shape = SHAPES_BY_NAME[args.shape]
    seq = args.seq or (64 if args.smoke else shape.seq_len)
    batch = args.batch or (8 if args.smoke else shape.global_batch)

    ctx = None
    if args.mesh != "none":
        if args.mesh == "local":
            m = mesh_lib.make_local_mesh()
        else:
            m = mesh_lib.make_production_mesh(
                multi_pod=args.mesh == "multi_pod")
        ctx = ParallelCtx(mesh=m, fsdp=mesh_lib.fsdp_for(
            args.arch, args.mesh == "multi_pod"))

    tcfg = TrainConfig(
        seq_len=seq, global_batch=batch, microbatch=args.microbatch,
        steps=args.steps, log_every=max(args.steps // 20, 1),
        checkpoint_every=max(args.steps // 4, 1),
        checkpoint_dir=os.path.join(args.ckpt_dir, args.arch),
        optimizer=OptimizerConfig(lr=args.lr,
                                  warmup_steps=max(args.steps // 10, 1),
                                  total_steps=args.steps))
    trainer = Trainer(cfg, tcfg, ctx=ctx)
    metrics = trainer.run()
    print(f"[train] final: {metrics}")


if __name__ == "__main__":
    main()
