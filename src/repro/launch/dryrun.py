import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape) cell
on the production mesh, WITHOUT allocating real tensors, and extract the
roofline terms from the compiled artifact.

  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all            # every cell, both meshes

Per cell this prints/records:
  * compiled.memory_analysis()  — per-device bytes (proves it fits / doesn't)
  * compiled.cost_analysis()    — HLO FLOPs + bytes accessed
  * collective bytes parsed from the post-SPMD HLO, by collective kind
  * the three roofline terms (compute / memory / collective, seconds)

Artifacts land in benchmarks/artifacts/dryrun/<cell>.json and are consumed by
benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES_BY_NAME, get_config)
from repro.configs.base import OptimizerConfig, ShapeConfig
from repro.launch import mesh as mesh_lib
from repro.launch.specs import as_named, batch_specs, input_specs
from repro.models import model as model_lib
from repro.optim import adamw_init
from repro.parallel.sharding import ParallelCtx, param_shardings
from repro.train.trainer import make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")

def build_step(arch: str, cfg, shape: ShapeConfig, ctx: ParallelCtx,
               microbatch: int = 0):
    """Returns (step_fn, abstract_args tuple, in_shardings tuple)."""
    mesh = ctx.mesh
    rng = jax.random.PRNGKey(0)

    params_abs = jax.eval_shape(lambda: model_lib.init_params(rng, cfg))
    p_sh = param_shardings(params_abs, ctx)

    if shape.kind == "train":
        opt_abs = jax.eval_shape(
            lambda: adamw_init(params_abs, OptimizerConfig()))
        from jax.sharding import NamedSharding, PartitionSpec
        o_sh = {"mu": param_shardings(opt_abs["mu"], ctx),
                "nu": param_shardings(opt_abs["nu"], ctx),
                "step": NamedSharding(mesh, PartitionSpec())
                if mesh else None}
        batch_abs = input_specs(cfg, shape)
        b_sh = as_named(batch_specs(cfg, shape, ctx), mesh)
        step = make_train_step(cfg, OptimizerConfig(), ctx=ctx,
                               microbatch=microbatch)
        return step, (params_abs, opt_abs, batch_abs), (p_sh, o_sh, b_sh)

    if shape.kind == "prefill":
        batch_abs = input_specs(cfg, shape)
        b_sh = as_named(batch_specs(cfg, shape, ctx), mesh)

        def prefill_step(params, batch):
            logits, aux, cache = model_lib.forward(
                params, cfg, batch, ctx=ctx, return_cache=True,
                cache_max_seq=shape.seq_len)
            return logits, cache

        return prefill_step, (params_abs, batch_abs), (p_sh, b_sh)

    # decode
    tree = input_specs(cfg, shape)
    sh = as_named(batch_specs(cfg, shape, ctx), mesh)

    def serve_step(params, batch_t, cache):
        return model_lib.decode_step(params, cfg, batch_t, cache, ctx=ctx)

    return serve_step, (params_abs, tree["batch_t"], tree["cache"]), \
        (p_sh, sh["batch_t"], sh["cache"])


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             attention: Optional[str] = None,
             remat: Optional[str] = None,
             fsdp: Optional[str] = None,
             moe_overrides: Optional[Dict] = None,
             lin_overrides: Optional[Dict] = None,
             model_overrides: Optional[Dict] = None,
             microbatch: int = 0,
             extra_tag: str = "",
             out_dir: str = ARTIFACT_DIR) -> Dict:
    shape = SHAPES_BY_NAME[shape_name]
    cfg = get_config(arch)
    if attention and cfg.family != "ssm":
        cfg = cfg.with_attention_kind(attention)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if model_overrides:
        mo = dict(model_overrides)
        ssm_chunk = mo.pop("_ssm_chunk", None)
        if mo:
            cfg = dataclasses.replace(cfg, **mo)
        if ssm_chunk:
            cfg = dataclasses.replace(
                cfg, ssm=dataclasses.replace(cfg.ssm, chunk_size=ssm_chunk))
    if moe_overrides:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_overrides))
    if lin_overrides:
        cfg = dataclasses.replace(cfg, attention=dataclasses.replace(
            cfg.attention, linformer=dataclasses.replace(
                cfg.attention.linformer, **lin_overrides)))
    kind = cfg.attention.kind if cfg.family != "ssm" else "native"

    # skip rules (DESIGN.md §5.1): full attention at 524288 is not runnable
    if shape.name == "long_500k" and kind == "standard":
        return {"arch": arch, "shape": shape_name, "skipped":
                "pure full attention at 500k (O(n^2) / 21-214GB KV per seq)"}

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    ctx = ParallelCtx(mesh=mesh,
                      fsdp=fsdp if fsdp is not None
                      else mesh_lib.fsdp_for(arch, multi_pod))

    t0 = time.time()
    step, args, shardings = build_step(arch, cfg, shape, ctx,
                                       microbatch=microbatch)
    with mesh:
        lowered = jax.jit(step, in_shardings=shardings).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes":
                int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
        mem_d["total_bytes"] = sum(v for k, v in mem_d.items()
                                   if k != "generated_code_bytes")
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        xla_flops = float(cost.get("flops", 0.0))
        xla_bytes = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        xla_flops, xla_bytes = 0.0, 0.0

    # Trip-count-aware analysis (XLA's cost_analysis counts while bodies once
    # — ~L× undercount for scanned layers). See launch/hlo_cost.py.
    from repro.launch import hlo_cost
    hlo = compiled.as_text()
    a = hlo_cost.analyze_text(hlo)
    flops = a["flops"]
    # memory term: geometric mean of the perfect-fusion lower bound and the
    # op-boundary upper bound — TPU fusion lands between the two.
    bytes_min = a["bytes_min"]
    bytes_upper = a["bytes"]
    bytes_accessed = (max(bytes_min, 1.0) * max(bytes_upper, 1.0)) ** 0.5
    coll = a["collectives"]
    coll_total = a["collective_bytes"]

    chips = mesh.devices.size
    # cost_analysis flops/bytes are per-device for SPMD-partitioned modules.
    roofline = {
        "compute_s": flops / mesh_lib.PEAK_FLOPS_BF16,
        "memory_s": bytes_accessed / mesh_lib.HBM_BW,
        "collective_s": coll_total / mesh_lib.ICI_BW,
    }
    dom = max(roofline, key=roofline.get)

    n_params = cfg.param_count_estimate
    n_active = cfg.active_param_count_estimate
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else (shape.seq_len if shape.kind ==
                                         "prefill" else 1))
    mult = 6 if shape.kind == "train" else 2
    model_flops_global = mult * n_active * tokens
    model_flops_per_chip = model_flops_global / chips

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "attention_kind": kind,
        "fsdp": ctx.fsdp,
        "remat": cfg.remat,
        "tag": extra_tag,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops,
        "bytes_accessed_per_device": bytes_accessed,
        "bytes_lower_per_device": bytes_min,
        "bytes_upper_per_device": bytes_upper,
        "xla_cost_analysis": {"flops": xla_flops, "bytes": xla_bytes,
                              "note": "while bodies counted once"},
        "hlo_cost_warnings": a["warnings"],
        "collectives": coll,
        "collective_bytes_per_device": coll_total,
        "memory": mem_d,
        "roofline": roofline,
        "dominant": dom,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": (model_flops_per_chip / flops) if flops else 0.0,
        "tokens": tokens,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"-{extra_tag}" if extra_tag else ""
        name = f"{arch}-{shape_name}-{rec['mesh']}-{kind}{tag}"
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        # keep the post-SPMD HLO for offline re-analysis (hlo_cost tweaks
        # shouldn't require recompiling 80 cells)
        import gzip
        with gzip.open(os.path.join(out_dir, name + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--attention", default=None,
                    help="override attention kind (standard baseline)")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--fsdp", default=None,
                    help="override FSDP policy: none|data|pod_data")
    ap.add_argument("--capacity-floor-one", action="store_true")
    ap.add_argument("--weight-stationary", action="store_true")
    ap.add_argument("--block-slots", type=int, default=None)
    ap.add_argument("--block-size", type=int, default=None)
    ap.add_argument("--single-pass-cache", action="store_true")
    ap.add_argument("--seq-shard-acts", action="store_true")
    ap.add_argument("--chunked-ce", type=int, default=0)
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    moe_ov = {}
    if args.capacity_floor_one:
        moe_ov["capacity_floor_one"] = True
    if args.weight_stationary:
        moe_ov["weight_stationary_decode"] = True
    lin_ov = {}
    if args.block_slots:
        lin_ov["block_slots"] = args.block_slots
    if args.block_size:
        lin_ov["block_size"] = args.block_size
    model_ov = {}
    if args.single_pass_cache:
        model_ov["single_pass_cache"] = True
    if args.seq_shard_acts:
        model_ov["seq_shard_activations"] = True
    if args.chunked_ce:
        model_ov["chunked_ce"] = args.chunked_ce
    if args.ssm_chunk:
        from repro.configs.base import SSMConfig
        import dataclasses as _dc
        # applied in run_cell via a nested replace
        model_ov["_ssm_chunk"] = args.ssm_chunk

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES_BY_NAME:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        label = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
        try:
            rec = run_cell(arch, shape, multi_pod=mp,
                           attention=args.attention, remat=args.remat,
                           fsdp=args.fsdp, moe_overrides=moe_ov or None,
                           lin_overrides=lin_ov or None,
                           model_overrides=model_ov or None,
                           microbatch=args.microbatch,
                           extra_tag=args.tag)
            if "skipped" in rec:
                print(f"[dryrun] SKIP {label}: {rec['skipped']}")
                continue
            r = rec["roofline"]
            print(f"[dryrun] OK   {label} compile={rec['compile_s']}s "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"mem/dev={rec['memory'].get('total_bytes', 0)/2**30:.2f}GiB "
                  f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                  f"coll={r['collective_s']:.4f}s dom={rec['dominant']}")
        except Exception:
            failures += 1
            print(f"[dryrun] FAIL {label}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
