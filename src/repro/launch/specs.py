"""ShapeDtypeStruct stand-ins + PartitionSpecs for every (arch × shape) cell.

`input_specs(cfg, shape)` returns the exact abstract inputs the step function
is lowered with — weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as model_lib
from repro.parallel.sharding import ParallelCtx


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Abstract batch for a train/prefill cell, or (batch_t, cache) for a
    decode cell."""
    if shape.kind in ("train", "prefill"):
        return model_lib.make_train_batch_shapes(
            cfg, batch=shape.global_batch, seq=shape.seq_len)
    # decode: one new token with a cache of seq_len tokens
    from repro.models.model import _impl
    impl = _impl(cfg)
    cache = jax.eval_shape(
        lambda: impl.init_cache(cfg, batch=shape.global_batch,
                                max_seq=shape.seq_len, dtype=jnp.bfloat16))
    if cfg.embedding_inputs:
        batch_t = {"embeds": jax.ShapeDtypeStruct(
            (shape.global_batch, 1, cfg.d_model), jnp.dtype(cfg.dtype))}
    else:
        batch_t = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, 1), jnp.int32)}
    return {"batch_t": batch_t, "cache": cache}


# ---------------------------------------------------------------------------
# Input shardings
# ---------------------------------------------------------------------------


def _dp(ctx: ParallelCtx) -> Tuple[str, ...]:
    return ctx.data_axes


def _divisible(n: int, ctx: ParallelCtx, axes: Tuple[str, ...]) -> bool:
    if ctx.mesh is None or not axes:
        return False
    size = 1
    for a in axes:
        size *= ctx.mesh.shape[a]
    return n % size == 0 and n >= size


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, ctx: ParallelCtx):
    """PartitionSpec tree matching input_specs(cfg, shape)."""
    dp = _dp(ctx)
    B = shape.global_batch
    bspec = dp if _divisible(B, ctx, dp) else None

    def token_like(ndim):
        return P(bspec, *([None] * (ndim - 1)))

    if shape.kind in ("train", "prefill"):
        specs = {}
        tree = input_specs(cfg, shape)
        for k, v in tree.items():
            specs[k] = token_like(v.ndim)
        return specs

    # decode: shard caches. Batch over dp when divisible; the long-context
    # axis (cache slots / sequence) over "model" — and over EVERYTHING when
    # batch=1 (long_500k), which is sequence-parallel decode.
    tree = input_specs(cfg, shape)
    seq_axes: Tuple[str, ...]
    if bspec is None:
        seq_axes = tuple(dp) + (ctx.model_axis,)
    else:
        seq_axes = (ctx.model_axis,)

    def cache_spec(path_key: str, v) -> P:
        nd = v.ndim
        if path_key in ("comp_k", "comp_v", "k", "v"):
            # (L, B, X, Hkv, Dh)
            return P(None, bspec, seq_axes, None, None)
        if path_key in ("raw_k", "raw_v"):
            return P(None, bspec, None, None, None)
        if path_key in ("mamba_ssm", "wkv"):
            # (L, B, H, ...) — heads over model
            hs = v.shape[2]
            m = ctx.model_axis if hs % ctx.model_shards == 0 else None
            return P(None, bspec, m, *([None] * (nd - 3)))
        if path_key in ("mamba_conv", "tm_shift", "cm_shift"):
            return P(None, bspec, *([None] * (nd - 2)))
        if path_key == "length":        # legacy shared scalar (ssm/hybrid)
            return P()
        if path_key == "lengths":       # (B,) per-row position counters
            return P(bspec)
        return P(*([None] * nd))

    def walk(prefix, t):
        if isinstance(t, dict):
            return {k: walk(k, v) for k, v in t.items()}
        return cache_spec(prefix, t)

    cache_specs = walk("", tree["cache"])
    bt = {k: token_like(v.ndim) for k, v in tree["batch_t"].items()}
    return {"batch_t": bt, "cache": cache_specs}


def as_named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
