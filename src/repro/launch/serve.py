"""Serving launcher: load (or init) weights for an arch and serve synthetic
mixed-length traffic through the continuous-batching scheduler (default) or
the static bucketed baseline.

    python -m repro.launch.serve --arch qwen3-8b --smoke --requests 8
    python -m repro.launch.serve --arch qwen3-8b --smoke --scheduler static
    python -m repro.launch.serve --arch qwen3-8b --smoke --requests 12 \
        --max-batch 2 --priority-classes 3 --deadline-ticks 8 --max-queue 6
"""
import argparse
import dataclasses
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attention", default=None)
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from this checkpoint dir")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--backend", default=None,
                    choices=["auto", "reference", "fused"],
                    help="attention compute backend (default: config's "
                         "'auto' -> fused Pallas kernels)")
    ap.add_argument("--decode-chunk", type=int, default=32,
                    help="tokens per device-resident decode scan chunk")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked admission prefill: stream each prompt "
                         "into its slot in fixed chunks of this many tokens "
                         "(multiple of the attention block size), "
                         "interleaved with decode and batched across "
                         "co-prefilling requests; 0 = monolithic B=1 "
                         "admission prefill")
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "static"],
                    help="continuous: slot-based admission/eviction between "
                         "decode chunks; static: equal-length bucketed "
                         "batches (baseline)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="assign synthetic requests round-robin to this many "
                         "priority classes (0 = most urgent; urgent arrivals "
                         "preempt running lower-priority slots); 1 = all "
                         "priority 0, plain FCFS")
    ap.add_argument("--deadline-ticks", type=int, default=0,
                    help="give every priority-0 request an absolute deadline "
                         "this many scheduler ticks out (0 = no deadlines); "
                         "provably-infeasible deadlines are shed at "
                         "admission")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the admission queue to this many waiting "
                         "requests; overflow sheds the least-valued entry "
                         "(0 = unbounded)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(host spans + one lane per request) to this path; "
                         "enables telemetry")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics dump (scheduler counters, "
                         "per-priority TTFT/TPOT/queue-wait histograms, "
                         "plan cost attribution) as JSONL to this path; "
                         "enables telemetry")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.checkpoint import Checkpointer
    from repro.configs import get_config, get_smoke_config
    from repro.models import model as M
    from repro.serving import ServingEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(cfg, dtype="float32")
    if args.attention and cfg.family != "ssm":
        cfg = cfg.with_attention_kind(args.attention)

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        restored, meta = ck.restore_latest({"params": params})
        if restored:
            params = restored["params"]
            print(f"[serve] restored step {meta['step']} from {args.ckpt_dir}")

    from repro.telemetry import Telemetry
    telemetry = (Telemetry() if args.trace_out or args.metrics_out
                 else None)
    eng = ServingEngine(params, cfg, max_seq=args.max_seq,
                        cache_dtype=jnp.float32 if args.smoke else jnp.bfloat16,
                        temperature=args.temperature,
                        decode_chunk=args.decode_chunk,
                        attention_backend=args.backend,
                        prefill_chunk=args.prefill_chunk,
                        telemetry=telemetry)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(4, cfg.vocab_size,
                                 int(rng.choice([8, 16, 16, 32]))))
               for _ in range(args.requests)]
    mode = args.scheduler
    if mode == "continuous" and not eng.supports_continuous_batching:
        print(f"[serve] {cfg.family!r} cache has no per-row positions; "
              "falling back to the static bucketed scheduler")
        mode = "static"
    prios = ([i % args.priority_classes for i in range(len(prompts))]
             if args.priority_classes > 1 else None)
    deadlines = None
    if args.deadline_ticks:
        deadlines = [args.deadline_ticks if (prios is None or p == 0) else None
                     for p in (prios or [0] * len(prompts))]
    t0 = time.perf_counter()
    if mode == "continuous":
        outs, sched = eng.serve(prompts, args.max_new_tokens,
                                max_batch=args.max_batch,
                                priorities=prios,
                                deadlines=deadlines,
                                max_queue=args.max_queue or None,
                                return_scheduler=True)
    else:
        outs = eng.serve_static(prompts, args.max_new_tokens,
                                max_batch=args.max_batch)
        sched = None
    dt = time.perf_counter() - t0
    shed = [o for o in outs if not isinstance(o, list)]
    n_tok = sum(len(o) for o in outs if isinstance(o, list))
    occ = (f", occupancy {sched.stats.mean_occupancy:.2f} over "
           f"{sched.stats.chunks} chunks" if sched is not None else "")
    if sched is not None and args.prefill_chunk:
        occ += (f", {sched.stats.prefill_forwards} chunked-prefill launches "
                f"({sched.stats.prefill_tokens} prompt tokens)")
    print(f"[serve] {mode}: {len(prompts)} requests, {n_tok} "
          f"tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s){occ}; "
          f"cache/request ≈ "
          f"{eng.cache_bytes(args.max_batch) // args.max_batch} B")
    if sched is not None:
        print(f"[serve] {sched.stats.counters_line()}")
    for o in shed:
        print(f"  req{o.rid} SHED at tick {o.tick}: {o.reason} "
              f"(priority {o.priority})")
    for i, o in enumerate(outs[:4]):
        if isinstance(o, list):
            print(f"  req{i} ({len(prompts[i])} prompt toks) -> {o[:10]}")
    if telemetry is not None and args.trace_out:
        telemetry.export_trace(args.trace_out,
                               metadata={"arch": args.arch,
                                         "scheduler": mode})
        print(f"[serve] trace -> {args.trace_out} "
              "(load at https://ui.perfetto.dev)")
    if telemetry is not None and args.metrics_out:
        telemetry.export_metrics_jsonl(args.metrics_out)
        print(f"[serve] metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
