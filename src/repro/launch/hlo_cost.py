"""Trip-count-aware cost analysis over compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` visits a while-loop body ONCE —
for scan-over-layers models that undercounts FLOPs/bytes/collectives by ~L×.
This analyzer parses the post-optimization HLO, computes per-computation
costs, and multiplies loop bodies by their ``known_trip_count`` (recursively,
so nested scans — e.g. SSD chunk scans inside the layer scan — compound).

Cost model:
  * flops: dots = 2 · |result| · |contracted dims|; elementwise/reduce ops =
    1 flop per result element (transcendentals = 1 as well — dots dominate).
    Fusion ops recurse into the fused computation.
  * bytes: result + operand bytes at fusion/op boundaries WITHOUT recursing
    into fused computations (fusion internals live in registers/VMEM — this
    is a closer HBM-traffic model than HloCostAnalysis, which counts every
    internal op).
  * collectives: result bytes per kind, × the enclosing loops' trip counts.

Shapes in post-SPMD HLO are per-device shards, so all numbers are per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|token)\[([0-9,]*)\]")

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+"
                    r"([\w\-]+)\((.*)$")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "tanh", "rsqrt", "sqrt", "power", "negate", "abs", "compare",
    "select", "and", "or", "xor", "floor", "ceil", "sign", "cosine", "sine",
    "logistic", "clamp", "round-nearest-even", "atan2", "remainder",
    "exponential-minus-one", "log-plus-one", "erf",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dims(type_str: str) -> List[Tuple[str, List[int]]]:
    return [(dt, [int(d) for d in dims.split(",")] if dims else [])
            for dt, dims in _SHAPE_RE.findall(type_str)]


def _elems(type_str: str) -> int:
    total = 0
    for _, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    type_str: str
    kind: str
    rest: str               # text after the opening paren
    operands: List[str] = field(default_factory=list)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0          # op-boundary model: HBM-traffic UPPER bound
    bytes_min: float = 0.0      # perfect-fusion model: LOWER bound
    transcendental: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        c = Cost(self.flops + o.flops, self.bytes + o.bytes,
                 self.bytes_min + o.bytes_min,
                 self.transcendental + o.transcendental)
        c.collectives = {k: dict(v) for k, v in self.collectives.items()}
        for k, v in o.collectives.items():
            d = c.collectives.setdefault(k, {"bytes": 0.0, "count": 0.0})
            d["bytes"] += v["bytes"]
            d["count"] += v["count"]
        return c

    def scaled(self, n: float) -> "Cost":
        c = Cost(self.flops * n, self.bytes * n, self.bytes_min * n,
                 self.transcendental * n)
        c.collectives = {k: {"bytes": v["bytes"] * n, "count": v["count"] * n}
                         for k, v in self.collectives.items()}
        return c


# ops whose operands/results genuinely traverse HBM even under perfect TPU
# fusion (matmuls, data movement, collectives); elementwise chains are
# assumed fully fused and excluded from the lower bound.
# genuinely-HBM ops, counted for bytes_min even inside fused computations
# (weight streaming via dynamic-slice in scan bodies is real traffic):
_HBM_OPS_ALWAYS = {
    "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "sort",
}
# layout/data-movement ops counted only when unfused at top level:
_HBM_OPS_TOP = {"copy", "transpose", "concatenate"}


_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?"n"\s*:\s*"(\d+)"')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def parse_module(text: str) -> Dict[str, List[Op]]:
    """computation name -> ops."""
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        s = line.rstrip()
        if not s:
            continue
        if not s.startswith(" ") and "{" in s and ("->" in s or
                                                   s.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, type_str, kind, rest = m.groups()
        # operands: %names before any attribute section in `rest`
        paren = rest.split("),")[0] if ")," in rest else rest.rstrip(")")
        operands = _OPERAND_RE.findall(paren)
        comps[cur].append(Op(name, type_str, kind, rest, operands))
    return comps


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.shapes: Dict[str, str] = {}
        for ops in self.comps.values():
            for op in ops:
                self.shapes[op.name] = op.type_str
        self._memo: Dict[Tuple[str, bool], Cost] = {}
        self._param_memo: Dict[str, Dict[int, float]] = {}
        self.entry = next((n for n in self.comps if n.startswith("main")),
                          list(self.comps)[-1])
        self.warnings: List[str] = []

    # -- per-op flops -----------------------------------------------------

    def _dot_flops(self, op: Op) -> float:
        m = _CONTRACT_RE.search(op.rest)
        contract_elems = 1
        if m and op.operands:
            lhs_shape = self.shapes.get(op.operands[0], "")
            dims = _dims(lhs_shape)
            if dims:
                lhs = dims[0][1]
                for i in (int(x) for x in m.group(1).split(",") if x):
                    if i < len(lhs):
                        contract_elems *= lhs[i]
        return 2.0 * _elems(op.type_str) * contract_elems

    # -- computation cost --------------------------------------------------

    def cost(self, comp: str, inside_fusion: bool = False) -> Cost:
        key = (comp, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        for op in self.comps.get(comp, []):
            total = total + self._op_cost(op, inside_fusion)
        self._memo[key] = total
        return total

    def _op_cost(self, op: Op, inside_fusion: bool) -> Cost:
        c = Cost()
        k = op.kind
        if k == "while":
            m = _TRIP_RE.search(op.rest)
            n = float(m.group(1)) if m else 1.0
            if not m:
                self.warnings.append(f"while {op.name}: no known_trip_count")
            body = _BODY_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            if body:
                c = c + self.cost(body.group(1)).scaled(n)
            if cond:
                c = c + self.cost(cond.group(1)).scaled(n)
            return c
        if k == "fusion":
            m = _CALLS_RE.search(op.rest)
            if m:
                inner = self.cost(m.group(1), inside_fusion=True)
                c.flops += inner.flops
                c.transcendental += inner.transcendental
                c.bytes_min += inner.bytes_min
                for kk, v in inner.collectives.items():
                    d = c.collectives.setdefault(kk, {"bytes": 0, "count": 0})
                    d["bytes"] += v["bytes"]
                    d["count"] += v["count"]
            if not inside_fusion:
                c.bytes += self._fusion_io_bytes(op, m.group(1) if m else None)
            return c
        if k in ("call", "conditional"):
            m = _BRANCH_RE.search(op.rest)
            called = ([x.strip().lstrip("%") for x in m.group(1).split(",")]
                      if m else _CALLS_RE.findall(op.rest))
            for cc in called:
                c = c + self.cost(cc, inside_fusion)
            if not inside_fusion:
                c.bytes += self._io_bytes(op)
            return c
        for kind in _COLLECTIVES:
            if k == kind or k.startswith(kind + "-start"):
                d = c.collectives.setdefault(kind, {"bytes": 0, "count": 0})
                d["bytes"] += _bytes(op.type_str)
                d["count"] += 1
                if not inside_fusion:
                    c.bytes_min += self._io_bytes(op)
                break
        if k in _HBM_OPS_ALWAYS or (k in _HBM_OPS_TOP and not inside_fusion):
            c.bytes_min += self._io_bytes(op, force=True)
        if k == "dot":
            c.flops += self._dot_flops(op)
        elif k == "convolution":
            self.warnings.append(f"convolution {op.name}: flops approximated")
            c.flops += 2.0 * _elems(op.type_str)
        elif k == "custom-call":
            if "matmul" in op.rest or "dot" in op.rest:
                self.warnings.append(f"custom-call matmul {op.name} — flops "
                                     "not counted")
        elif k in _ELEMENTWISE or k.startswith("reduce"):
            c.flops += float(_elems(op.type_str))
            if k in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                     "logistic", "cosine", "sine", "erf"):
                c.transcendental += float(_elems(op.type_str))
        if not inside_fusion:
            c.bytes += self._io_bytes(op)
        return c

    def _io_bytes(self, op: Op, force: bool = False) -> float:
        if not force and op.kind in ("parameter", "constant",
                                     "get-tuple-element", "tuple", "bitcast"):
            return 0.0
        # in-place update ops: XLA aliases the buffer — traffic is the
        # updated slice (read-modify-write), not the whole operand/result.
        if op.kind == "dynamic-update-slice" and len(op.operands) >= 2:
            upd = float(_bytes(self.shapes.get(op.operands[1], "")))
            return 2.0 * upd
        if op.kind == "scatter" and len(op.operands) >= 3:
            upd = float(_bytes(self.shapes.get(op.operands[2], "")))
            idx = float(_bytes(self.shapes.get(op.operands[1], "")))
            return 2.0 * upd + idx
        # slicing reads only the slice (result), not the whole operand
        if op.kind in ("dynamic-slice", "slice"):
            return 2.0 * float(_bytes(op.type_str))
        if op.kind == "gather" and len(op.operands) >= 2:
            idx = float(_bytes(self.shapes.get(op.operands[1], "")))
            return 2.0 * float(_bytes(op.type_str)) + idx
        b = float(_bytes(op.type_str))
        for o in op.operands:
            b += float(_bytes(self.shapes.get(o, "")))
        return b

    def _param_read_bytes(self, comp: str) -> Dict[int, float]:
        """Effective read bytes per parameter of a fused computation: a
        parameter consumed ONLY by slicing ops is read slice-wise, not in
        full (scan bodies stream layer weights via fused dynamic-slice)."""
        if comp in self._param_memo:
            return self._param_memo[comp]
        ops = self.comps.get(comp, [])
        out: Dict[int, float] = {}
        for p in ops:
            if p.kind != "parameter":
                continue
            m = re.search(r"parameter\((\d+)", p.rest)
            if not m:
                continue
            idx = int(m.group(1))
            uses = [o for o in ops if p.name in o.operands]
            if uses and all(u.kind in ("dynamic-slice", "slice", "gather",
                                       "bitcast") for u in uses):
                eff = sum(float(_bytes(u.type_str)) for u in uses)
            else:
                eff = float(_bytes(p.type_str))
            out[idx] = eff
        self._param_memo[comp] = out
        return out

    def _fusion_io_bytes(self, op: Op, called: Optional[str]) -> float:
        b = float(_bytes(op.type_str))
        eff = self._param_read_bytes(called) if called else {}
        for i, o in enumerate(op.operands):
            full = float(_bytes(self.shapes.get(o, "")))
            b += min(full, eff.get(i, full)) if i in eff else full
        return b

    def analyze(self) -> Dict:
        c = self.cost(self.entry)
        coll = {k: c.collectives.get(k, {"bytes": 0.0, "count": 0.0})
                for k in _COLLECTIVES}
        return {
            "flops": c.flops,
            "bytes": c.bytes,
            "bytes_min": c.bytes_min,
            "transcendental": c.transcendental,
            "collectives": coll,
            "collective_bytes": sum(v["bytes"] for v in coll.values()),
            "warnings": self.warnings[:20],
        }


def analyze_text(text: str) -> Dict:
    return HloCost(text).analyze()
