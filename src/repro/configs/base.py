"""Configuration dataclasses for the repro framework.

Everything in the framework is driven by a single `ModelConfig` plus the
run-level `TrainConfig` / `ServeConfig` / `MeshConfig`. Configs are plain
frozen dataclasses so they are hashable (usable as jit static args) and
trivially serializable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Attention / Linformer
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinformerConfig:
    """Configuration of the paper's technique.

    The exact (bidirectional) form follows Eq. 7 of the paper: learned
    E,F in R^{n x k} compress the sequence axis of K and V.

    The causal form ("linformer_causal") uses the paper's convolutional
    projection (kernel = stride = block_size, producing `block_slots`
    compressed slots per block) with block-granular causality; see DESIGN.md §4.
    """

    # projected dimension k (exact form). Paper sweeps 64..512; 128/256 typical.
    k: int = 128
    # E/F parameter sharing: "none" | "headwise" | "kv" | "layerwise"
    sharing: str = "layerwise"
    # projection kind for the exact form: "linear" | "conv" | "pool"
    projection: str = "linear"
    # --- causal (blockwise) form ---
    block_size: int = 256          # c: tokens per compressed block
    block_slots: int = 16          # r: compressed slots per block
    # non-uniform k: optional per-layer scaling (higher layers lower rank).
    # fraction of k kept at the last layer; 1.0 = uniform.
    k_decay: float = 1.0


@dataclass(frozen=True)
class AttentionConfig:
    kind: str = "standard"          # "standard" | "linformer" | "linformer_causal"
    # compute backend for the linformer kinds:
    #   "auto"      — resolved per platform by kernels/ops.resolve_backend
    #                 (fused Pallas kernels: Mosaic on TPU, interpret on CPU)
    #   "fused"     — force the Pallas kernel path
    #   "reference" — force the pure-jnp einsum implementations
    backend: str = "auto"
    # backward implementation of the fused blockwise-causal attention
    # (linformer_causal training through the Pallas kernels):
    #   "fused"     — Pallas backward from saved (m, denom) softmax residuals
    #   "reference" — recompute through the pure-jnp reference VJP (parity
    #                 oracle; a second unfused attention pass per step)
    backward_impl: str = "fused"
    num_heads: int = 8
    num_kv_heads: int = 8           # GQA: kv heads (== num_heads -> MHA)
    head_dim: int = 64
    qk_norm: bool = False           # Qwen3-style RMSNorm on q,k head dims
    qkv_bias: bool = False          # Qwen1.5-style bias on q,k,v projections
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    linformer: LinformerConfig = field(default_factory=LinformerConfig)

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0
        return self.num_heads // self.num_kv_heads


# ---------------------------------------------------------------------------
# Feed-forward / MoE / SSM
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLPConfig:
    d_ff: int = 2048
    activation: str = "swiglu"      # "swiglu" | "squared_relu" | "gelu"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # 0 -> dense MLP
    top_k: int = 2
    expert_d_ff: int = 2048
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # shard experts over this mesh axis
    expert_axis: str = "model"
    # --- perf knobs (EXPERIMENTS.md §Perf) ---
    # per-expert capacity floor of 1 instead of top_k: removes the 8x padded
    # expert compute at tiny decode batches (iteration kimi/decode_32k #1).
    # Tradeoff: at very small token counts, routing collisions can drop
    # tokens unless capacity_factor gives headroom (serving configs should
    # size cf so C >= expected load x skew; tests use dropless cf).
    capacity_floor_one: bool = True
    # decode-time weight-stationary EP: tokens replicate (tiny), expert
    # weights stay sharded over (model x fsdp) — no per-step weight gather
    # (iteration kimi/decode_32k #2)
    weight_stationary_decode: bool = True


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block config."""

    state_dim: int = 64             # N
    head_dim: int = 64              # P
    num_heads: int = 0              # derived from d_inner/head_dim if 0
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 128           # SSD chunk for parallel training


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) config."""

    head_dim: int = 64
    chunk_size: int = 128


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int = 12
    d_model: int = 768
    vocab_size: int = 32000
    max_seq_len: int = 4096
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    mlp: MLPConfig = field(default_factory=MLPConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    rwkv: RWKVConfig = field(default_factory=RWKVConfig)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # decoder ("causal_lm") or encoder ("mlm") objective
    objective: str = "causal_lm"
    # hybrid (zamba2): attention block shared across invocations, applied
    # every `hybrid_attn_every` mamba layers.
    hybrid_attn_every: int = 6
    # vlm/audio frontends are stubs: inputs may include precomputed embeddings
    # of this many positions (prepended to token embeddings).
    frontend_embed_len: int = 0
    # embedding-only input (musicgen: EnCodec frame embeddings, no token lookup)
    embedding_inputs: bool = False
    dtype: str = "bfloat16"         # params/activations
    remat: str = "full"             # "none" | "dots" | "full"
    # scan layers (stacked params). Always true for prod; smoke may disable.
    scan_layers: bool = True
    # embedding/lm-head vocab rows are padded up to a multiple of this so the
    # vocab axis shards evenly under tensor parallelism (standard practice;
    # padded ids are never used as labels).
    vocab_pad_multiple: int = 256
    # --- perf knobs (EXPERIMENTS.md §Perf) ---
    # build the decode cache inside the SAME forward pass at prefill instead
    # of a second full pass (iteration qwen3-8b/prefill_32k #1)
    single_pass_cache: bool = True
    # shard the residual stream's sequence axis over "model" between blocks
    # (sequence parallelism for norms/activations; Korthikanti et al.) —
    # cuts saved-carry memory by the TP width (iteration qwen1.5/train #2)
    seq_shard_activations: bool = False
    # compute the LM-head matmul + cross-entropy in sequence chunks of this
    # many tokens (0 = off): logits are never fully materialized
    # (iteration qwen1.5/train #3)
    chunked_ce: int = 0

    @property
    def padded_vocab_size(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    def with_attention_kind(self, kind: str) -> "ModelConfig":
        return dataclasses.replace(
            self, attention=dataclasses.replace(self.attention, kind=kind)
        )

    def with_attention_backend(self, backend: str) -> "ModelConfig":
        return dataclasses.replace(
            self, attention=dataclasses.replace(self.attention, backend=backend)
        )

    def with_backward_impl(self, backward_impl: str) -> "ModelConfig":
        return dataclasses.replace(
            self, attention=dataclasses.replace(self.attention,
                                                backward_impl=backward_impl)
        )

    @property
    def param_count_estimate(self) -> int:
        """Rough parameter count (embeddings + blocks), for roofline MODEL_FLOPS."""
        a, D, L = self.attention, self.d_model, self.num_layers
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6
            d_ff = self.mlp.d_ff
            per = (
                4 * D * D            # r,k,v,g (time-mix)
                + D * D              # output
                + D * d_ff + d_ff * D  # channel mix
                + 10 * D             # mus/decay small params (approx)
            )
            return emb + L * per
        attn = D * (a.num_heads * a.head_dim) + 2 * D * (a.num_kv_heads * a.head_dim) \
            + (a.num_heads * a.head_dim) * D
        if self.moe.num_experts > 0:
            ff = self.moe.num_experts * 3 * D * self.moe.expert_d_ff \
                + D * self.moe.num_experts
        else:
            mult = 3 if self.mlp.activation == "swiglu" else 2
            ff = mult * D * self.mlp.d_ff
        if self.family == "hybrid":
            # mamba trunk + one shared attention+mlp block
            d_inner = self.ssm.expand * D
            per_mamba = D * (2 * d_inner + 2 * self.ssm.state_dim *
                             (d_inner // self.ssm.head_dim if self.ssm.head_dim else 1))
            per_mamba = 2 * D * d_inner + d_inner * D + 2 * d_inner * self.ssm.state_dim
            mult = 3 if self.mlp.activation == "swiglu" else 2
            return emb + L * per_mamba + (attn + mult * D * self.mlp.d_ff)
        return emb + L * (attn + ff)

    @property
    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.moe.num_experts == 0:
            return self.param_count_estimate
        a, D, L = self.attention, self.d_model, self.num_layers
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        attn = D * (a.num_heads * a.head_dim) + 2 * D * (a.num_kv_heads * a.head_dim) \
            + (a.num_heads * a.head_dim) * D
        ff = self.moe.top_k * 3 * D * self.moe.expert_d_ff + D * self.moe.num_experts
        return emb + L * (attn + ff)


# ---------------------------------------------------------------------------
# Run-level configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""

    name: str = "train_4k"
    seq_len: int = 4096
    global_batch: int = 256
    kind: str = "train"             # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # fsdp axes that parameters are additionally sharded over ("" = none)
    fsdp: str = "none"              # "none" | "data" | "pod_data"


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"        # "cosine" | "linear" | "constant"
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # dtype of Adam moments ("float32" | "bfloat16") — bf16 halves opt memory
    moment_dtype: str = "float32"


@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 512
    global_batch: int = 8
    microbatch: int = 0             # 0 = no accumulation
    # error-feedback int8 gradient reduction across the "pod" axis (DCN):
    # requires a multi-pod mesh; see train/compressed_dp.py
    compressed_pod_grads: bool = False
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    mlm_mask_prob: float = 0.15


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq_len: int = 2048
    prefill_chunk: int = 512
    temperature: float = 0.0        # 0 = greedy
