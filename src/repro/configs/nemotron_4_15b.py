"""nemotron-4-15b — [dense] 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU. [arXiv:2402.16819; unverified]
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    vocab_size=256000,
    max_seq_len=524288,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        qk_norm=False,
        linformer=LinformerConfig(k=256, sharing="layerwise",
                                  block_size=256, block_slots=16),
    ),
    mlp=MLPConfig(d_ff=24576, activation="squared_relu"),
)

SMOKE = ModelConfig(
    name="nemotron-4-15b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    max_seq_len=256,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        linformer=LinformerConfig(k=16, block_size=16, block_slots=4),
    ),
    mlp=MLPConfig(d_ff=128, activation="squared_relu"),
    remat="none",
)
