"""kimi-k2-1t-a32b — [moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE. [arXiv:2501.kimi2; unverified]
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    MoEConfig,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    vocab_size=163840,
    max_seq_len=524288,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        linformer=LinformerConfig(k=256, sharing="layerwise",
                                  block_size=256, block_slots=16),
    ),
    mlp=MLPConfig(d_ff=2048, activation="swiglu"),
    moe=MoEConfig(num_experts=384, top_k=8, expert_d_ff=2048,
                  capacity_factor=1.25),
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    max_seq_len=256,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        linformer=LinformerConfig(k=16, block_size=16, block_slots=4),
    ),
    mlp=MLPConfig(d_ff=64, activation="swiglu"),
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=64,
                  capacity_factor=8.0),
    remat="none",
)
