"""The paper's own model: RoBERTa-base-like 12L encoder with exact Linformer
attention (Eq. 7), n=512, k=128/256, MLM objective. This is the
paper-faithful reproduction config used by the Figure-3 / Table-2 benchmarks.
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="linformer-paper-base",
    family="dense",
    num_layers=12,
    d_model=768,
    vocab_size=50265,
    max_seq_len=512,
    objective="mlm",
    attention=AttentionConfig(
        kind="linformer",       # exact bidirectional form, Eq. 7
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        causal=False,
        use_rope=False,         # learned positions, RoBERTa-style
        linformer=LinformerConfig(k=128, sharing="layerwise",
                                  projection="linear"),
    ),
    mlp=MLPConfig(d_ff=3072, activation="gelu"),
)

SMOKE = ModelConfig(
    name="linformer-paper-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    max_seq_len=128,
    objective="mlm",
    attention=AttentionConfig(
        kind="linformer",
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        causal=False,
        use_rope=False,
        linformer=LinformerConfig(k=16, sharing="layerwise"),
    ),
    mlp=MLPConfig(d_ff=128, activation="gelu"),
    remat="none",
)
