"""qwen3-14b — [dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936
qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    vocab_size=151936,
    max_seq_len=524288,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        linformer=LinformerConfig(k=256, sharing="layerwise",
                                  block_size=256, block_slots=16),
    ),
    mlp=MLPConfig(d_ff=17408, activation="swiglu"),
)

SMOKE = ModelConfig(
    name="qwen3-14b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    max_seq_len=256,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        qk_norm=True,
        linformer=LinformerConfig(k=16, block_size=16, block_slots=4),
    ),
    mlp=MLPConfig(d_ff=128, activation="swiglu"),
    remat="none",
)
