"""qwen1.5-110b — [dense] 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    vocab_size=152064,
    max_seq_len=524288,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        qkv_bias=True,
        linformer=LinformerConfig(k=256, sharing="layerwise",
                                  block_size=256, block_slots=16),
    ),
    mlp=MLPConfig(d_ff=49152, activation="swiglu"),
)

SMOKE = ModelConfig(
    name="qwen1.5-110b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    max_seq_len=256,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        qkv_bias=True,
        linformer=LinformerConfig(k=16, block_size=16, block_slots=4),
    ),
    mlp=MLPConfig(d_ff=128, activation="swiglu"),
    remat="none",
)
