"""musicgen-large — [audio] 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

The EnCodec audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S, d_model); ``embedding_inputs``
skips the token-embedding lookup. The LM head projects to the 2048-entry
EnCodec codebook. MusicGen's 4-codebook delay pattern is collapsed to a single
interleaved stream (backbone compute is equivalent; see DESIGN.md §5.1).
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    vocab_size=2048,
    max_seq_len=524288,
    embedding_inputs=True,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        linformer=LinformerConfig(k=256, sharing="layerwise",
                                  block_size=256, block_slots=16),
    ),
    mlp=MLPConfig(d_ff=8192, activation="gelu"),
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    vocab_size=128,
    max_seq_len=256,
    embedding_inputs=True,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        linformer=LinformerConfig(k=16, block_size=16, block_slots=4),
    ),
    mlp=MLPConfig(d_ff=128, activation="gelu"),
    remat="none",
)
