"""internvl2-2b — [vlm] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
InternViT + InternLM2. [arXiv:2404.16821; hf]

The InternViT vision frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed patch embeddings (B, n_patches, d_model) prepended to the
token stream. The InternLM2 language backbone is fully implemented.
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    vocab_size=92553,
    max_seq_len=524288,
    frontend_embed_len=256,   # ViT patch embeddings prepended (448px/14 -> 1024 -> pixel-shuffle 256)
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        linformer=LinformerConfig(k=256, sharing="layerwise",
                                  block_size=256, block_slots=16),
    ),
    mlp=MLPConfig(d_ff=8192, activation="swiglu"),
)

SMOKE = ModelConfig(
    name="internvl2-2b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    max_seq_len=256,
    frontend_embed_len=8,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        linformer=LinformerConfig(k=16, block_size=16, block_slots=4),
    ),
    mlp=MLPConfig(d_ff=128, activation="swiglu"),
    remat="none",
)
