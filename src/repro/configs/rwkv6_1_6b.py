"""rwkv6-1.6b — [ssm] 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
Finch — data-dependent decay. [arXiv:2404.05892; unverified]

Linformer is INAPPLICABLE here (no attention matrix to approximate — the model
is already O(n) time / O(1) state); implemented without the technique per the
assignment. See DESIGN.md §5.1 Arch-applicability.
"""
from repro.configs.base import (
    AttentionConfig,
    MLPConfig,
    ModelConfig,
    RWKVConfig,
)

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    vocab_size=65536,
    max_seq_len=524288,
    attention=AttentionConfig(kind="standard", num_heads=32, num_kv_heads=32,
                              head_dim=64),  # unused; kept for uniform API
    mlp=MLPConfig(d_ff=7168, activation="squared_relu"),  # rwkv channel-mix uses relu^2
    rwkv=RWKVConfig(head_dim=64, chunk_size=128),
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    max_seq_len=256,
    attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
    mlp=MLPConfig(d_ff=128, activation="squared_relu"),
    rwkv=RWKVConfig(head_dim=16, chunk_size=16),
    remat="none",
)
