"""qwen3-8b — [dense] 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936
qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    vocab_size=151936,
    max_seq_len=524288,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        linformer=LinformerConfig(k=256, sharing="layerwise",
                                  block_size=256, block_slots=16),
    ),
    mlp=MLPConfig(d_ff=12288, activation="swiglu"),
)

SMOKE = ModelConfig(
    name="qwen3-8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    max_seq_len=256,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        qk_norm=True,
        linformer=LinformerConfig(k=16, block_size=16, block_slots=4),
    ),
    mlp=MLPConfig(d_ff=128, activation="swiglu"),
    remat="none",
)
