"""qwen3-moe-30b-a3b — [moe] 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128e top-8. [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    MoEConfig,
    ModelConfig,
)

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    vocab_size=151936,
    max_seq_len=524288,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        linformer=LinformerConfig(k=256, sharing="layerwise",
                                  block_size=256, block_slots=16),
    ),
    mlp=MLPConfig(d_ff=768, activation="swiglu"),
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=768,
                  capacity_factor=1.25),
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    max_seq_len=256,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        qk_norm=True,
        linformer=LinformerConfig(k=16, block_size=16, block_slots=4),
    ),
    mlp=MLPConfig(d_ff=64, activation="swiglu"),
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=64,
                  capacity_factor=8.0),
    remat="none",
)
