"""zamba2-1.2b — [hybrid] 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks. [arXiv:2411.15242; hf]

Trunk of Mamba2 (SSD) blocks; a single attention+MLP block with SHARED weights
is invoked every `hybrid_attn_every` trunk layers (Zamba2's weight-tied global
block). The shared attention block is where Linformer applies.
"""
from repro.configs.base import (
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    ModelConfig,
    SSMConfig,
)

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    vocab_size=32000,
    max_seq_len=524288,
    hybrid_attn_every=6,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=32,
        num_kv_heads=32,     # MHA in the shared block
        head_dim=64,
        linformer=LinformerConfig(k=256, sharing="layerwise",
                                  block_size=256, block_slots=16),
    ),
    mlp=MLPConfig(d_ff=8192, activation="swiglu"),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=128),
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    vocab_size=512,
    max_seq_len=256,
    hybrid_attn_every=2,
    attention=AttentionConfig(
        kind="linformer_causal",
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        linformer=LinformerConfig(k=16, block_size=16, block_slots=4),
    ),
    mlp=MLPConfig(d_ff=128, activation="swiglu"),
    ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, conv_width=4,
                  chunk_size=16),
    remat="none",
)
