"""Architecture registry: ``--arch <id>`` resolves here.

Each assigned architecture lives in its own module with the exact published
config (``CONFIG``) and a reduced smoke-test config (``SMOKE``).
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import (  # noqa: F401  (re-exported)
    AttentionConfig,
    LinformerConfig,
    MLPConfig,
    MeshConfig,
    MoEConfig,
    ModelConfig,
    OptimizerConfig,
    RWKVConfig,
    SHAPES,
    SHAPES_BY_NAME,
    SSMConfig,
    ServeConfig,
    ShapeConfig,
    TrainConfig,
)

# arch id (public, dashed) -> module name
_ARCH_MODULES: Dict[str, str] = {
    "qwen3-8b": "qwen3_8b",
    "qwen3-14b": "qwen3_14b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen1.5-110b": "qwen1_5_110b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "internvl2-2b": "internvl2_2b",
    "zamba2-1.2b": "zamba2_1_2b",
    "musicgen-large": "musicgen_large",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "linformer-paper": "linformer_paper",
}

ARCH_IDS: Tuple[str, ...] = tuple(k for k in _ARCH_MODULES if k != "linformer-paper")
ALL_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)


def _module(arch_id: str):
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    """Full published config for an assigned architecture."""
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return _module(arch_id).SMOKE
