"""repro-lint: static enforcement of the stack's structural invariants.

Two layers, one driver (`scripts/check_static.py`, wired into
`scripts/check.sh` before tier-1):

* :mod:`repro.analysis.astlint` — pure-`ast` rules RL000–RL006 over the
  `src/` tree (dispatch purity, host-sync discipline, kernel fail-fast
  contract, donation safety, PartitionSpec hygiene). Stdlib-only: runs
  without jax.
* :mod:`repro.analysis.jaxpr_audit` — traces the canonical entry points
  (train fwd/bwd, chunk prefill, decode scan, sequence-parallel forms) to
  closed jaxprs and asserts the collective counts/byte volumes match the
  comm-cost model in `core/seq_parallel.py`, that `decode_scan`'s scanned
  body is host-effect-free, and the decode precision policy.

Rule catalog, pragma grammar, and the jaxpr contract: docs/static-analysis.md.
"""
