"""repro-lint layer 1: AST rules over the `src/` tree.

Rules (full catalog with rationale: docs/static-analysis.md):

  RL000  hygiene — no committed bytecode/artifact paths, no `print(` in
         library code (only `launch/` may print; benchmarks/scripts live
         outside the linted tree), and every `repro-lint` pragma must be
         well-formed and carry a reason.
  RL001  dispatch purity — backend-string comparisons, `resolve_backend`/
         `resolve_backward_impl` calls, and branching on mesh axis names
         only inside the plan layer (`parallel/plan.py`,
         `parallel/sharding.py`, `kernels/common.py`, `launch/mesh.py`).
  RL002  host-sync discipline — implicit device→host syncs (`float()`/
         `int()`/`bool()`/`.item()`/`np.asarray`/`jax.device_get`/
         `block_until_ready`) in the serving/decode hot-path modules need
         an inline `# repro-lint: allow[RL002] <reason>` pragma, so every
         sync is named and justified.
  RL003  kernel contract — `pl.pallas_call` is reachable only through
         wrappers with a fail-fast check (MAX_EXACT_K / MAX_PINNED_SLOTS
         bound, `divisor_block` grid floor, or a shape-divisibility
         assert), direct kernel entry points are only called from inside
         `kernels/`, and every VMEM scratch accumulator is a literal
         `jnp.float32`.
  RL004  donation safety — `donate_argnums`/`donate_argnames` only in the
         SlotPool-owned serving jits (`serving/engine.py`) and the trainer's
         own step jit (`train/trainer.py`).
  RL005  spec hygiene — string axis names passed to `PartitionSpec`/`P`
         must come from the `DECLARED_AXES` registry in `parallel/plan.py`.
  RL006  tuning discipline — kernel grid knobs (`block_q`/`block_s`/
         `q_chunk_blocks`) may not be pinned to integer literals at fused
         call sites outside `kernels/common.py` (the defaults) and
         `tune/` (the autotuner): a literal there silently bypasses the
         TUNING.json lookup the call sites are wired through.

Waiver grammar (same line as the finding, or the line directly above):

    # repro-lint: allow[RL002] <reason — required>

Pure stdlib (`ast`, `re`, `subprocess` for `git ls-files`) — no jax and no
repo imports, so the linter runs anywhere, before the environment can trace.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import subprocess
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "RL000": "hygiene: no committed artifacts, no print() in library code, "
             "well-formed pragmas",
    "RL001": "dispatch purity: backend/mesh branching only in the plan layer",
    "RL002": "host-sync discipline: device->host syncs in hot paths need a "
             "reasoned pragma",
    "RL003": "kernel contract: pallas_call behind fail-fast wrappers, fp32 "
             "scratch accumulators",
    "RL004": "donation safety: donate_argnums only in pool/trainer jits",
    "RL005": "spec hygiene: PartitionSpec axis names from the declared "
             "registry",
    "RL006": "tuning discipline: no literal block_q/block_s/q_chunk_blocks "
             "at fused call sites outside kernels/common.py and tune/",
}

# -- scope ------------------------------------------------------------------

# RL001: the plan layer — parallel/plan.py + kernels/common.py own backend
# resolution (the ISSUE contract); parallel/sharding.py and launch/mesh.py
# are the mesh-introspection utilities the plan itself is built from.
RL001_ALLOWED = (
    "src/repro/parallel/plan.py",
    "src/repro/parallel/sharding.py",
    "src/repro/kernels/common.py",
    "src/repro/launch/mesh.py",
)
BACKEND_STRINGS = frozenset({"auto", "fused", "reference"})
DISPATCH_RESOLVERS = frozenset({"resolve_backend", "resolve_backward_impl"})

# RL002: hot-path modules (serving decode/prefill loop + kernels).
RL002_HOT = (
    "src/repro/serving/scheduler.py",
    "src/repro/serving/engine.py",
    "src/repro/core/cache.py",
    "src/repro/models/transformer.py",
    "src/repro/models/model.py",
    "src/repro/kernels/",
)

# RL003: fail-fast guard vocabulary (kernels/common.py).
GUARD_CONSTS = frozenset({"MAX_PINNED_SLOTS", "MAX_EXACT_K",
                          "MIN_DIVISOR_BLOCK"})
GUARD_CALLS = frozenset({"divisor_block", "_divisor_block"})
KERNEL_PKG = "src/repro/kernels/"
KERNEL_WRAPPER_MOD = "src/repro/kernels/ops.py"

# RL004: jits allowed to donate — the SlotPool-owned serving step jits and
# the trainer's own (params, opt_state[, residual]) step jit.
RL004_ALLOWED = (
    "src/repro/serving/engine.py",
    "src/repro/train/trainer.py",
)

# RL000: only the CLI layer may print.
PRINT_ALLOWED = ("src/repro/launch/",)
ARTIFACT_PATTERNS = ("__pycache__", ".pyc", ".pyo", ".DS_Store", ".egg-info")

PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*allow\[(RL\d{3})\]\s*(.*)$")

PLAN_PATH = "src/repro/parallel/plan.py"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str      # repo-relative POSIX path ("src/repro/...")
    line: int      # 1-based; 0 = whole-file finding
    msg: str

    @property
    def key(self) -> str:
        """Stable id used by the grandfather baseline."""
        return f"{self.rule}:{self.path}:{self.line}"

    def as_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "msg": self.msg, "key": self.key}


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]
    files_checked: int
    pragmas_used: int


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _call_name(call: ast.Call) -> str:
    """Bare name of the called object: `f(..)` -> 'f', `a.b.f(..)` -> 'f'."""
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _attr_root(node: ast.expr) -> str:
    """`np.asarray` -> 'np'; `a.b.c` -> 'a'."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def _contains(node: ast.AST, *, attr: Optional[str] = None,
              call: Optional[str] = None) -> bool:
    for sub in ast.walk(node):
        if attr and isinstance(sub, ast.Attribute) and sub.attr == attr:
            return True
        if call and isinstance(sub, ast.Call) and _call_name(sub) == call:
            return True
    return False


def _str_constants(node: ast.AST) -> Iterable[Tuple[ast.Constant, str]]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub, sub.value


def _collect_pragmas(source: str, rel: str,
                     findings: List[Finding]) -> Dict[int, Set[str]]:
    """line -> set of waived rule ids. Malformed / reason-less pragmas are
    RL000 findings and waive nothing. Only real comment tokens count —
    docstrings and string literals mentioning repro-lint are not pragmas."""
    pragmas: Dict[int, Set[str]] = {}
    try:
        comments = [(t.start[0], t.string)
                    for t in tokenize.generate_tokens(
                        io.StringIO(source).readline)
                    if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return pragmas
    for i, line in comments:
        if not re.search(r"repro-lint\s*:", line):
            continue
        m = PRAGMA_RE.search(line)
        if m is None:
            findings.append(Finding(
                "RL000", rel, i,
                "malformed repro-lint pragma (grammar: "
                "'# repro-lint: allow[RLxxx] <reason>')"))
            continue
        rule, reason = m.group(1), m.group(2).strip()
        if rule not in RULES:
            findings.append(Finding(
                "RL000", rel, i, f"pragma waives unknown rule {rule!r}"))
            continue
        if not reason:
            findings.append(Finding(
                "RL000", rel, i,
                f"pragma for {rule} has no reason — every waiver must be "
                "justified inline"))
            continue
        pragmas.setdefault(i, set()).add(rule)
    return pragmas


def declared_axes_from_source(plan_source: str) -> Set[str]:
    """Extract the DECLARED_AXES registry literal from parallel/plan.py."""
    tree = ast.parse(plan_source)
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        if any(isinstance(t, ast.Name) and t.id == "DECLARED_AXES"
               for t in targets):
            return {s for _, s in _str_constants(node)}
    return set()


# ---------------------------------------------------------------------------
# Per-file rules
# ---------------------------------------------------------------------------


def _rl000_prints(rel: str, tree: ast.AST, findings: List[Finding]) -> None:
    if any(rel.startswith(p) for p in PRINT_ALLOWED):
        return
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "print"):
            findings.append(Finding(
                "RL000", rel, node.lineno,
                "print() in library code — route output through "
                "telemetry/logging, or move the CLI into launch/"))


def _rl001(rel: str, tree: ast.AST, findings: List[Finding]) -> None:
    if rel in RL001_ALLOWED:
        return

    def axis_branch(test: ast.AST) -> bool:
        # membership/equality tests on .axis_names are caught by the
        # Compare rule below; here: branching on axis_size() widths
        return _contains(test, call="axis_size")

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _call_name(node) in \
                DISPATCH_RESOLVERS:
            findings.append(Finding(
                "RL001", rel, node.lineno,
                f"{_call_name(node)}() outside the plan layer — thread a "
                "resolved AttentionPlan instead"))
        elif isinstance(node, ast.Compare):
            hits = sorted({s for _, s in _str_constants(node)
                           if s in BACKEND_STRINGS})
            if hits:
                findings.append(Finding(
                    "RL001", rel, node.lineno,
                    f"comparison against backend string(s) {hits} — "
                    "dispatch belongs to parallel/plan.py"))
        elif isinstance(node, (ast.If, ast.IfExp, ast.While, ast.Assert)):
            if axis_branch(node.test):
                findings.append(Finding(
                    "RL001", rel, node.lineno,
                    "branching on axis_size() outside the plan layer — "
                    "expose the decision as a plan/ctx property"))
        if isinstance(node, ast.Compare) and \
                _contains(node, attr="axis_names"):
            findings.append(Finding(
                "RL001", rel, node.lineno,
                "membership test on mesh.axis_names outside the plan layer "
                "— expose the decision as a plan/ctx property "
                "(e.g. ParallelCtx.has_pod_axis)"))
        elif isinstance(node, ast.comprehension):
            for test in node.ifs:
                if axis_branch(test):
                    findings.append(Finding(
                        "RL001", rel, test.lineno,
                        "comprehension filtering on mesh axis names outside "
                        "the plan layer"))


_HOST_SAFE_ATTRS = frozenset({"shape", "ndim", "size", "itemsize"})


def _attr_chain_only(node: ast.expr) -> bool:
    while isinstance(node, ast.Attribute):
        node = node.value
    return isinstance(node, ast.Name)


def _host_safe(node: ast.expr, allow_attr: bool = False) -> bool:
    """Conservatively true when an expression cannot hold device data, so
    `int(...)`/`np.asarray(...)` over it is not a sync: literals, python
    containers, `len()`/`getattr()`/`prod()`, and shape/dtype metadata
    (python ints on jax arrays). `allow_attr` additionally trusts bare
    attribute chains (`c.value`, `self.pool.pages_freed`) — python-object
    bookkeeping reads, used for the cast family only; subscripted
    containers (`self.cache["lengths"]`) stay suspect."""
    if isinstance(node, (ast.Constant, ast.JoinedStr)):
        return True
    if isinstance(node, (ast.List, ast.Tuple, ast.Set, ast.Dict,
                         ast.ListComp, ast.SetComp, ast.DictComp,
                         ast.GeneratorExp)):
        return True
    if isinstance(node, ast.Attribute):
        return node.attr in _HOST_SAFE_ATTRS or \
            (allow_attr and _attr_chain_only(node))
    if isinstance(node, ast.Subscript):
        return _host_safe(node.value)
    if isinstance(node, ast.Call):
        name = _call_name(node)
        if name in ("len", "getattr"):
            return True
        if name == "prod":
            return all(_host_safe(a, allow_attr) for a in node.args)
        return False
    if isinstance(node, ast.BinOp):
        return (_host_safe(node.left, allow_attr)
                and _host_safe(node.right, allow_attr))
    if isinstance(node, ast.UnaryOp):
        return _host_safe(node.operand, allow_attr)
    if isinstance(node, ast.BoolOp):
        return all(_host_safe(v, allow_attr) for v in node.values)
    if isinstance(node, ast.Compare):
        return (_host_safe(node.left, allow_attr)
                and all(_host_safe(c, allow_attr)
                        for c in node.comparators))
    if isinstance(node, ast.IfExp):
        return (_host_safe(node.body, allow_attr)
                and _host_safe(node.orelse, allow_attr))
    return False


def _rl002(rel: str, tree: ast.AST, findings: List[Finding]) -> None:
    if not any(rel.startswith(p) for p in RL002_HOT):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f, msg = node.func, None
        if isinstance(f, ast.Attribute):
            if f.attr in ("item", "block_until_ready", "device_get"):
                msg = f".{f.attr}() forces a device->host sync"
            elif (f.attr in ("asarray", "array")
                  and _attr_root(f) in ("np", "numpy")):
                if node.args and not _host_safe(node.args[0]):
                    msg = (f"np.{f.attr}() on device data forces a "
                           "device->host sync")
        elif isinstance(f, ast.Name) and f.id in ("float", "int", "bool"):
            if node.args and not _host_safe(node.args[0], allow_attr=True):
                msg = (f"{f.id}() on (potentially) device data forces a "
                       "device->host sync")
        if msg:
            findings.append(Finding(
                "RL002", rel, node.lineno,
                msg + " in a hot-path module — batch it onto the chunk's "
                "single sync or waive with a reasoned pragma"))


# RL006: who may pin a tuned grid knob to a literal — the defaults module
# that DEFINES the fallbacks, and the autotuner that sweeps candidates.
RL006_ALLOWED = (
    "src/repro/kernels/common.py",
    "src/repro/tune/",
)
# kwargs resolved through the tuning table (tune/table.py TUNABLE_PARAMS)
RL006_TUNED_KWARGS = ("block_q", "block_s", "q_chunk_blocks")


def _rl006(rel: str, tree: ast.AST, findings: List[Finding]) -> None:
    if any(rel.startswith(p) for p in RL006_ALLOWED):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        callee = f.id if isinstance(f, ast.Name) else \
            f.attr if isinstance(f, ast.Attribute) else None
        if callee is None or not (
                callee.startswith("fused_")
                or callee == "blockwise_causal_attention_chunked"):
            continue
        for kw in node.keywords:
            if kw.arg in RL006_TUNED_KWARGS and \
                    isinstance(kw.value, ast.Constant) and \
                    isinstance(kw.value.value, int):
                findings.append(Finding(
                    "RL006", rel, node.lineno,
                    f"literal {kw.arg}={kw.value.value} pins a tuned grid "
                    f"knob at a {callee} call site — route it through the "
                    "tuning-table lookup (parallel/plan.py, core/causal.py) "
                    "or hoist the constant into kernels/common.py"))


def _rl004(rel: str, tree: ast.AST, findings: List[Finding]) -> None:
    if rel in RL004_ALLOWED:
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg in ("donate_argnums", "donate_argnames"):
                findings.append(Finding(
                    "RL004", rel, node.lineno,
                    f"{kw.arg} outside the SlotPool/trainer jits — donated "
                    "buffers alias their inputs; only the owning step "
                    "functions may donate"))


def _partition_spec_names(tree: ast.AST) -> Set[str]:
    """Local names bound to jax.sharding.PartitionSpec in this module."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module and \
                "sharding" in node.module:
            for alias in node.names:
                if alias.name == "PartitionSpec":
                    names.add(alias.asname or alias.name)
    return names


def _rl005(rel: str, tree: ast.AST, declared: Set[str],
           findings: List[Finding]) -> None:
    spec_names = _partition_spec_names(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        is_spec = (isinstance(f, ast.Name) and f.id in spec_names) or \
                  (isinstance(f, ast.Attribute) and f.attr == "PartitionSpec")
        if not is_spec:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            for const, s in _str_constants(arg):
                if s not in declared:
                    findings.append(Finding(
                        "RL005", rel, const.lineno,
                        f"PartitionSpec axis {s!r} is not in the "
                        "DECLARED_AXES registry (parallel/plan.py)"))


# ---------------------------------------------------------------------------
# RL003: kernel contract (cross-file)
# ---------------------------------------------------------------------------


def _is_pallas_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "pallas_call")


def _has_guard(fn: ast.FunctionDef) -> bool:
    """A fail-fast check: an `if`-guarded raise over a kernel bound
    constant, a divisor_block() grid floor, or a shape-divisibility
    assert/raise (`% == 0` style)."""
    for node in ast.walk(fn):
        if isinstance(node, ast.If):
            mentions = any(isinstance(s, ast.Name) and s.id in GUARD_CONSTS
                           for s in ast.walk(node.test))
            has_mod = any(isinstance(s, ast.BinOp)
                          and isinstance(s.op, ast.Mod)
                          for s in ast.walk(node.test))
            raises = any(isinstance(s, ast.Raise) for s in ast.walk(node))
            if raises and (mentions or has_mod):
                return True
        elif isinstance(node, ast.Call) and _call_name(node) in GUARD_CALLS:
            return True
        elif isinstance(node, ast.Assert):
            if any(isinstance(s, ast.BinOp) and isinstance(s.op, ast.Mod)
                   for s in ast.walk(node.test)):
                return True
    return False


def _kernel_module_aliases(tree: ast.AST,
                           kernel_mods: Set[str]) -> Tuple[Set[str],
                                                           Dict[str, str]]:
    """(names bound to kernel-entry functions, alias -> kernel module)."""
    fn_names: Set[str] = set()
    mod_aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            if node.module == "repro.kernels":
                for alias in node.names:
                    if alias.name in kernel_mods:
                        mod_aliases[alias.asname or alias.name] = alias.name
            elif node.module.startswith("repro.kernels."):
                mod = node.module.rsplit(".", 1)[1]
                if mod in kernel_mods:
                    for alias in node.names:
                        fn_names.add(alias.asname or alias.name)
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith("repro.kernels."):
                    mod = alias.name.rsplit(".", 1)[1]
                    if mod in kernel_mods and alias.asname:
                        mod_aliases[alias.asname] = mod
    return fn_names, mod_aliases


def _rl003(files: Dict[str, ast.Module],
           findings: List[Finding]) -> None:
    # 1. kernel entry points: top-level functions in kernels/ (minus the
    #    wrapper module) whose body contains a pl.pallas_call, plus the
    #    scratch-accumulator dtype check on every pallas_call.
    kernel_fns: Dict[str, Set[str]] = {}      # module basename -> fn names
    for rel, tree in files.items():
        if not rel.startswith(KERNEL_PKG):
            continue
        for node in ast.walk(tree):
            if _is_pallas_call(node):
                _check_scratch(rel, node, findings)
        if rel == KERNEL_WRAPPER_MOD:
            continue
        mod = os.path.basename(rel)[:-3]
        for node in tree.body:
            if isinstance(node, ast.FunctionDef) and \
                    any(_is_pallas_call(s) for s in ast.walk(node)):
                kernel_fns.setdefault(mod, set()).add(node.name)
    kernel_mods = set(kernel_fns)
    all_kernel_fn_names = {n for fns in kernel_fns.values() for n in fns}

    # 2. direct kernel calls are kernels/-internal: everything else goes
    #    through the fail-fast wrappers in kernels/ops.py.
    for rel, tree in files.items():
        if rel.startswith(KERNEL_PKG):
            continue
        fn_names, mod_aliases = _kernel_module_aliases(tree, kernel_mods)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            direct = (isinstance(f, ast.Name) and f.id in fn_names) or \
                     (isinstance(f, ast.Attribute)
                      and f.attr in all_kernel_fn_names
                      and isinstance(f.value, ast.Name)
                      and f.value.id in mod_aliases)
            if direct:
                findings.append(Finding(
                    "RL003", rel, node.lineno,
                    f"direct call to kernel entry {_call_name(node)}() — "
                    "go through the fail-fast wrappers in kernels/ops.py"))

    # 3. every public wrapper in kernels/ops.py that (transitively) reaches
    #    a pallas_call must itself contain a fail-fast guard.
    ops_tree = files.get(KERNEL_WRAPPER_MOD)
    if ops_tree is None:
        return
    ops_fns = {n.name: n for n in ops_tree.body
               if isinstance(n, ast.FunctionDef)}
    _, ops_mod_aliases = _kernel_module_aliases(ops_tree, kernel_mods)
    calls: Dict[str, Set[str]] = {}
    reaches: Set[str] = set()
    for name, fn in ops_fns.items():
        callees: Set[str] = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name) and f.id in ops_fns:
                callees.add(f.id)
            elif (isinstance(f, ast.Attribute)
                  and isinstance(f.value, ast.Name)
                  and f.value.id in ops_mod_aliases
                  and f.attr in kernel_fns.get(
                      ops_mod_aliases[f.value.id], ())):
                reaches.add(name)
        calls[name] = callees
    changed = True
    while changed:
        changed = False
        for name, callees in calls.items():
            if name not in reaches and callees & reaches:
                reaches.add(name)
                changed = True
    for name in sorted(reaches):
        if name.startswith("_"):
            continue      # private plumbing of a guarded public wrapper
        if not _has_guard(ops_fns[name]):
            findings.append(Finding(
                "RL003", KERNEL_WRAPPER_MOD, ops_fns[name].lineno,
                f"public wrapper {name}() reaches a pl.pallas_call without "
                "a fail-fast check (MAX_* bound, divisor_block, or "
                "divisibility assert)"))


def _check_scratch(rel: str, call: ast.Call,
                   findings: List[Finding]) -> None:
    """Every VMEM scratch accumulator must be a literal jnp.float32."""
    for kw in call.keywords:
        if kw.arg != "scratch_shapes":
            continue
        if not isinstance(kw.value, (ast.List, ast.Tuple)):
            findings.append(Finding(
                "RL003", rel, kw.value.lineno,
                "scratch_shapes must be a literal list so the accumulator "
                "dtype is statically auditable"))
            continue
        for elt in kw.value.elts:
            if not (isinstance(elt, ast.Call)
                    and isinstance(elt.func, ast.Attribute)
                    and elt.func.attr == "VMEM"):
                continue      # semaphores etc. — not accumulators
            dtype = None
            if len(elt.args) >= 2:
                dtype = elt.args[1]
            for ekw in elt.keywords:
                if ekw.arg == "dtype":
                    dtype = ekw.value
            ok = (isinstance(dtype, ast.Attribute)
                  and dtype.attr == "float32")
            if not ok:
                findings.append(Finding(
                    "RL003", rel, elt.lineno,
                    "VMEM scratch accumulator is not a literal "
                    "jnp.float32 — kernel reductions must accumulate in "
                    "fp32"))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def lint_mapping(sources: Dict[str, str], *,
                 declared_axes: Optional[Set[str]] = None,
                 tracked_paths: Optional[Sequence[str]] = None) -> LintResult:
    """Lint a {repo-relative path: source} mapping (the unit the tests
    drive directly). Only `src/` paths are linted."""
    findings: List[Finding] = []
    pragmas_by_file: Dict[str, Dict[int, Set[str]]] = {}
    trees: Dict[str, ast.Module] = {}

    for rel in sorted(tracked_paths or ()):
        if any(pat in rel for pat in ARTIFACT_PATTERNS):
            findings.append(Finding(
                "RL000", rel, 0,
                "committed build artifact — delete it and rely on "
                ".gitignore"))

    for rel in sorted(sources):
        if not rel.startswith("src/"):
            continue
        source = sources[rel]
        try:
            tree = ast.parse(source)
        except SyntaxError as e:
            findings.append(Finding(
                "RL000", rel, e.lineno or 0, f"syntax error: {e.msg}"))
            continue
        trees[rel] = tree
        pragmas_by_file[rel] = _collect_pragmas(source, rel, findings)

    if declared_axes is None:
        declared_axes = set()
        if PLAN_PATH in sources:
            declared_axes = declared_axes_from_source(sources[PLAN_PATH])

    for rel, tree in trees.items():
        _rl000_prints(rel, tree, findings)
        _rl001(rel, tree, findings)
        _rl002(rel, tree, findings)
        _rl004(rel, tree, findings)
        _rl005(rel, tree, declared_axes, findings)
        _rl006(rel, tree, findings)
    _rl003(trees, findings)

    kept: List[Finding] = []
    pragmas_used = 0
    for f in findings:
        waivers = pragmas_by_file.get(f.path, {})
        if f.rule in waivers.get(f.line, ()) or \
                f.rule in waivers.get(f.line - 1, ()):
            pragmas_used += 1
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule, f.msg))
    return LintResult(findings=kept, files_checked=len(trees),
                      pragmas_used=pragmas_used)


def _git_tracked(root: str) -> Sequence[str]:
    try:
        out = subprocess.run(["git", "ls-files"], cwd=root,
                             capture_output=True, text=True, timeout=30)
    except (OSError, subprocess.TimeoutExpired):
        return ()
    if out.returncode != 0:
        return ()
    return [line for line in out.stdout.splitlines() if line]


def lint_tree(root: str) -> LintResult:
    """Lint the repo's `src/` tree on disk (plus the git index for RL000
    artifact paths)."""
    sources: Dict[str, str] = {}
    src = os.path.join(root, "src")
    for dirpath, dirnames, filenames in os.walk(src):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for name in sorted(filenames):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as fh:
                sources[rel] = fh.read()
    return lint_mapping(sources, tracked_paths=_git_tracked(root))
