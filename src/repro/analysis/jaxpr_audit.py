"""Layer 2 of repro-lint: jaxpr-level audits of the stack's contracts.

The AST layer (`repro.analysis.astlint`) checks what the SOURCE says; this
module checks what the TRACED PROGRAM does. It builds tiny canonical
instances of the stack's entry points — train forward/backward, chunked
prefill, the device-resident decode chunk, and both sequence-parallel
attention forms — traces them with `jax.make_jaxpr`, and walks the
resulting equations to enforce three invariants:

* **JX001 — host-effect-free decode body.** `model.decode_scan`'s scanned
  step is the serving hot loop; its one host sync happens at the CHUNK
  boundary (`np.asarray` in the engine), never inside the scan. Any
  callback / debug / infeed primitive inside a scanned body (or anywhere
  in the train/prefill traces) is a regression.

* **JX002 — collective bytes match the comm-cost model.** The
  sequence-parallel bodies in `core/seq_parallel.py` advertise their
  communication through `blockwise_sp_comm_bytes` and
  `seq_parallel_comm_bytes` (quoted in docs/parallelism.md and
  EXPERIMENTS.md). The audit traces the shard-local bodies under an
  `AbstractMesh`, measures the actual gathered / reduced operand bytes
  from the jaxpr's avals, and asserts equality with the model — the
  claimed O(k·d) cost is checked against the program, not prose.

* **JX003 — no dtype widening on the decode hot path.** No
  `convert_element_type` to float64/complex may appear in the decode
  trace (an accidental f64 constant would silently double cache
  bandwidth, or crash on accelerators without f64).

Tracing uses `jax.sharding.AbstractMesh`, so the audit runs on a
single-device host with no XLA device-count forcing. Findings reuse
:class:`repro.analysis.astlint.Finding` with paths like
``jaxpr:decode_scan`` and line 0 (there is no source line for a traced
equation). Expectation parameters are injectable so tests can prove each
audit actually fires (see tests/test_static_analysis.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.astlint import Finding

JX_RULES: Dict[str, str] = {
    "JX001": "host-effect primitive on a traced hot path",
    "JX002": "collective bytes diverge from the comm-cost model",
    "JX003": "dtype widening (f64/complex) on the decode hot path",
}

# primitive-name fragments that mean "this equation talks to the host"
HOST_EFFECT_FRAGMENTS = (
    "callback", "debug", "infeed", "outfeed", "host_",
)

WIDE_DTYPES = frozenset({"float64", "complex64", "complex128"})


@dataclasses.dataclass
class AuditResult:
    """Findings plus the measured-vs-model numbers behind them."""

    findings: List[Finding]
    stats: Dict[str, Dict[str, object]]

    @property
    def ok(self) -> bool:
        return not self.findings


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _as_jaxpr(obj):
    """Unwrap ClosedJaxpr -> Jaxpr; pass Jaxpr through; else None."""
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    if hasattr(obj, "eqns"):
        return obj
    return None


def _sub_jaxprs(eqn) -> Iterator[object]:
    """Yield every jaxpr nested in an equation's params (scan/cond/jit/
    shard_map bodies, custom-vjp branches, ...)."""
    for val in eqn.params.values():
        for item in (val if isinstance(val, (list, tuple)) else (val,)):
            sub = _as_jaxpr(item)
            if sub is not None:
                yield sub


def iter_eqns(jaxpr) -> Iterator[object]:
    """All equations of `jaxpr`, recursing into nested jaxprs."""
    jaxpr = _as_jaxpr(jaxpr)
    if jaxpr is None:
        return
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub)


def aval_bytes(aval) -> int:
    size = 1
    for d in aval.shape:
        size *= int(d)
    return size * aval.dtype.itemsize


def collectives(jaxpr, names: Tuple[str, ...] = ("all_gather", "psum"),
                ) -> List[Dict[str, object]]:
    """Every collective equation with its OUTPUT aval byte volume (for an
    all-gather that is the gathered buffer; for a psum the reduced one —
    both are what the comm-cost model counts per device)."""
    out = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in names:
            out.append({
                "prim": name,
                "bytes": sum(aval_bytes(v.aval) for v in eqn.outvars),
                "shapes": [tuple(v.aval.shape) for v in eqn.outvars],
            })
    return out


def host_effect_prims(jaxpr) -> List[str]:
    found = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if any(frag in name for frag in HOST_EFFECT_FRAGMENTS):
            found.append(name)
    return found


def widenings(jaxpr, forbidden=WIDE_DTYPES) -> List[str]:
    """convert_element_type equations whose target dtype is forbidden."""
    found = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        new = str(eqn.params.get("new_dtype", ""))
        if new in forbidden:
            found.append(new)
    return found


def scan_bodies(jaxpr) -> List[object]:
    """Body jaxprs of every `scan` equation (recursively)."""
    bodies = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name == "scan":
            sub = _as_jaxpr(eqn.params.get("jaxpr"))
            if sub is not None:
                bodies.append(sub)
    return bodies


def _finding(rule: str, entry: str, msg: str) -> Finding:
    return Finding(rule=rule, path=f"jaxpr:{entry}", line=0, msg=msg)


# ---------------------------------------------------------------------------
# Canonical tiny instances
# ---------------------------------------------------------------------------

# sequence-parallel audit dims: B=1 and float32 so the measured per-device
# aval bytes equal the comm model's (batch-free) count at dtype_bytes=4
_SP = dict(B=1, S=32, shards=2, H=4, Hkv=2, Dh=4, c=8, r=2)


def _tiny_cfg():
    from repro.configs.base import (AttentionConfig, LinformerConfig,
                                    ModelConfig)
    attn = AttentionConfig(
        kind="linformer_causal", backend="reference", num_heads=4,
        num_kv_heads=2, head_dim=8,
        linformer=LinformerConfig(block_size=8, block_slots=2))
    return ModelConfig(name="jaxpr-audit", num_layers=2, d_model=32,
                       vocab_size=256, max_seq_len=64, attention=attn,
                       dtype="float32", remat="none")


def _sp_inputs(rng_seed: int = 0):
    import jax
    import jax.numpy as jnp
    d = _SP
    ks = jax.random.split(jax.random.PRNGKey(rng_seed), 5)
    q = jax.random.normal(ks[0], (d["B"], d["S"], d["H"], d["Dh"]),
                          jnp.float32)
    k = jax.random.normal(ks[1], (d["B"], d["S"], d["Hkv"], d["Dh"]),
                          jnp.float32)
    v = jax.random.normal(ks[2], (d["B"], d["S"], d["Hkv"], d["Dh"]),
                          jnp.float32)
    return q, k, v, ks[3], ks[4]


# ---------------------------------------------------------------------------
# Audits
# ---------------------------------------------------------------------------


def audit_sp_causal(expect_lin: Optional[int] = None,
                    ) -> Tuple[List[Finding], Dict[str, object]]:
    """Trace the blockwise-causal sequence-parallel body and assert its
    all-gather volume equals `blockwise_sp_comm_bytes`.

    expect_lin overrides the model's expected byte count (tests inject a
    wrong value to prove the audit fires)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import AbstractMesh, PartitionSpec as P

    from repro.core.seq_parallel import (blockwise_sp_comm_bytes,
                                         sp_blockwise_causal_attention)
    from repro.parallel.sharding import shard_map

    d = _SP
    q, k, v, ke, kf = _sp_inputs()
    E = jax.random.normal(ke, (d["c"], d["r"]), jnp.float32) * 0.3
    F = jax.random.normal(kf, (d["c"], d["r"]), jnp.float32) * 0.3
    mesh = AbstractMesh((("seq", d["shards"]),))

    def body(q_l, k_l, v_l):
        return sp_blockwise_causal_attention(
            q_l, k_l, v_l, E, F, seq_axis="seq", block_size=d["c"],
            block_slots=d["r"], scale=d["Dh"] ** -0.5, fused=False)

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "seq"), P(None, "seq"), P(None, "seq")),
        out_specs=P(None, "seq"), check_vma=False)
    jpr = jax.make_jaxpr(sharded)(q, k, v)

    gathers = [c for c in collectives(jpr) if c["prim"] == "all_gather"]
    measured = sum(c["bytes"] for c in gathers)
    d_total = d["Hkv"] * d["Dh"]
    model, _ = blockwise_sp_comm_bytes(
        d["S"], d["c"], d["r"], d_total, d["shards"], dtype_bytes=4)
    expected = model if expect_lin is None else expect_lin

    findings: List[Finding] = []
    if len(gathers) != 2:
        findings.append(_finding(
            "JX002", "sp_causal",
            f"expected exactly 2 all_gathers (compressed k/v prefix), "
            f"traced {len(gathers)}"))
    if measured != expected:
        findings.append(_finding(
            "JX002", "sp_causal",
            f"all-gather volume {measured}B != comm model "
            f"blockwise_sp_comm_bytes={expected}B"))
    stats = {"all_gathers": len(gathers), "gathered_bytes": measured,
             "model_bytes": model}
    return findings, stats


def audit_sp_exact(expect_lin: Optional[int] = None,
                   ) -> Tuple[List[Finding], Dict[str, object]]:
    """Trace the exact-form sequence-parallel body and assert its psum
    volume equals `seq_parallel_comm_bytes`."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import AbstractMesh, PartitionSpec as P

    from repro.core.seq_parallel import (seq_parallel_comm_bytes,
                                         sp_exact_linformer_attention)
    from repro.parallel.sharding import shard_map

    d = _SP
    K = (d["S"] // d["c"]) * d["r"]          # compressed width
    q, k, v, ke, kf = _sp_inputs()
    E = jax.random.normal(ke, (d["S"], K), jnp.float32) * 0.3
    F = jax.random.normal(kf, (d["S"], K), jnp.float32) * 0.3
    mesh = AbstractMesh((("seq", d["shards"]),))

    def body(q_l, k_l, v_l, E_l, F_l):
        return sp_exact_linformer_attention(
            q_l, k_l, v_l, E_l, F_l, seq_axis="seq",
            scale=d["Dh"] ** -0.5, fused=False)

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "seq"), P(None, "seq"), P(None, "seq"),
                  P("seq"), P("seq")),
        out_specs=P(None, "seq"), check_vma=False)
    jpr = jax.make_jaxpr(sharded)(q, k, v, E, F)

    psums = [c for c in collectives(jpr) if c["prim"] == "psum"]
    measured = sum(c["bytes"] for c in psums)
    d_total = d["Hkv"] * d["Dh"]
    model, _ = seq_parallel_comm_bytes(
        d["S"], K, d_total, d["shards"], dtype_bytes=4)
    expected = model if expect_lin is None else expect_lin

    findings: List[Finding] = []
    if len(psums) != 2:
        findings.append(_finding(
            "JX002", "sp_exact",
            f"expected exactly 2 psums (compressed k/v), traced "
            f"{len(psums)}"))
    if measured != expected:
        findings.append(_finding(
            "JX002", "sp_exact",
            f"psum volume {measured}B != comm model "
            f"seq_parallel_comm_bytes={expected}B"))
    stats = {"psums": len(psums), "psum_bytes": measured,
             "model_bytes": model}
    return findings, stats


def audit_decode(n_steps: int = 4, forbidden=WIDE_DTYPES,
                 ) -> Tuple[List[Finding], Dict[str, object]]:
    """Trace `model.decode_scan` (the serving decode chunk) and assert the
    scanned body is host-effect-free and nothing widens to f64/complex."""
    import jax
    import jax.numpy as jnp

    from repro.models import model as model_lib

    cfg = _tiny_cfg()
    B = 2
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    cache = model_lib.init_cache(cfg, batch=B, max_seq=cfg.max_seq_len,
                                 dtype=jnp.float32)
    cur = jnp.zeros((B,), jnp.int32)
    fin = jnp.zeros((B,), bool)
    rng = jax.random.PRNGKey(1)

    jpr = jax.make_jaxpr(
        lambda p, c, f, ca, r: model_lib.decode_scan(
            p, cfg, c, f, ca, r, n_steps=n_steps, eos_id=1,
            temperature=0.7))(params, cur, fin, cache, rng)

    bodies = scan_bodies(jpr)
    findings: List[Finding] = []
    if not bodies:
        findings.append(_finding(
            "JX001", "decode_scan",
            "decode_scan traced without a scan equation — the decode "
            "chunk is no longer a device-resident lax.scan"))
    effects = [p for b in bodies for p in host_effect_prims(b)]
    for prim in sorted(set(effects)):
        findings.append(_finding(
            "JX001", "decode_scan",
            f"host-effect primitive '{prim}' inside the scanned decode "
            f"body (the chunk contract allows one host sync per chunk, "
            f"at the boundary)"))
    wide = widenings(jpr, forbidden)
    for dt in sorted(set(wide)):
        findings.append(_finding(
            "JX003", "decode_scan",
            f"convert_element_type to {dt} on the decode hot path"))
    stats = {"scan_eqns": len(bodies),
             "body_eqns": sum(len(b.eqns) for b in bodies),
             "host_effects": len(effects), "widenings": len(wide)}
    return findings, stats


def audit_prefill() -> Tuple[List[Finding], Dict[str, object]]:
    """Trace the chunked-prefill entry point; it must be host-effect-free
    (the scheduler owns its one sync, after the traced region)."""
    import jax
    import jax.numpy as jnp

    from repro.models import model as model_lib

    cfg = _tiny_cfg()
    B, P_chunk = 2, 16
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    cache = model_lib.init_cache(cfg, batch=B, max_seq=cfg.max_seq_len,
                                 dtype=jnp.float32)
    toks = jnp.zeros((B, P_chunk), jnp.int32)
    n_valid = jnp.full((B,), P_chunk, jnp.int32)

    jpr = jax.make_jaxpr(
        lambda p, t, ca, nv: model_lib.prefill_chunk(
            p, cfg, {"tokens": t}, ca, nv))(params, toks, cache, n_valid)

    findings: List[Finding] = []
    effects = host_effect_prims(jpr)
    for prim in sorted(set(effects)):
        findings.append(_finding(
            "JX001", "prefill_chunk",
            f"host-effect primitive '{prim}' in the chunked-prefill "
            f"trace"))
    stats = {"eqns": sum(1 for _ in iter_eqns(jpr)),
             "host_effects": len(effects)}
    return findings, stats


def audit_train() -> Tuple[List[Finding], Dict[str, object]]:
    """Trace the train step's forward+backward (value_and_grad of loss_fn);
    it must be host-effect-free."""
    import jax
    import jax.numpy as jnp

    from repro.models import model as model_lib

    cfg = _tiny_cfg()
    B, S = 2, 32
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.zeros((B, S), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }

    def loss(p, b):
        total, _ = model_lib.loss_fn(p, cfg, b, ctx=None)
        return total

    jpr = jax.make_jaxpr(jax.value_and_grad(loss))(params, batch)

    findings: List[Finding] = []
    effects = host_effect_prims(jpr)
    for prim in sorted(set(effects)):
        findings.append(_finding(
            "JX001", "train_step",
            f"host-effect primitive '{prim}' in the train fwd/bwd trace"))
    stats = {"eqns": sum(1 for _ in iter_eqns(jpr)),
             "host_effects": len(effects)}
    return findings, stats


def run_audit() -> AuditResult:
    """Run every jaxpr audit; the driver merges these findings with the
    AST layer's."""
    findings: List[Finding] = []
    stats: Dict[str, Dict[str, object]] = {}
    for name, fn in (("sp_causal", audit_sp_causal),
                     ("sp_exact", audit_sp_exact),
                     ("decode_scan", audit_decode),
                     ("prefill_chunk", audit_prefill),
                     ("train_step", audit_train)):
        f, s = fn()
        findings.extend(f)
        stats[name] = s
    return AuditResult(findings=findings, stats=stats)
