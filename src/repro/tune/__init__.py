"""Benchmark-driven kernel/scheduler autotuning.

`table.py` is the runtime side: the persistent `TUNING.json` tuning
table (shape-bucketed winners per platform and attention form) consulted
by the plan layer (`parallel/plan.py`), the chunked-attention threshold
(`core/causal.py`), and the serving engine's decode-chunk default — all
at trace/construction time, with a safe fallback to the hand-picked
defaults in `kernels/common.py` when no entry matches.

`autotune.py` is the offline side: the sweep that times the real fused
entry points (`kernels/ops.py`) and regenerates the table
(`python -m benchmarks.autotune`). See docs/kernels.md §Autotuner.
"""
from repro.tune.table import (TuningTable, clear_table_cache,  # noqa: F401
                              consume_stats, get_table, next_pow2,
                              override, shape_bucket, validate_doc)
