"""Benchmark-driven sweep that regenerates the tuning table.

Times the REAL entry points — `kernels/ops.py` fused wrappers for the
kernel grid knobs, a live `ServingEngine.serve` loop for the scheduler
scalars — with warmup + median-of-k per candidate, a telemetry span per
trial (``autotune_trial``) and an ``autotune_trials_total`` counter, and
persists winners to `TUNING.json` via `tune.table`. Run offline through
the CLI (``python -m benchmarks.autotune [--smoke]``); never imported on
the serving/training hot path.

Search space (docs/kernels.md §Autotuner):

* **exact** (per shape bucket): ``block_s`` for the fused sequence
  projection, then ``block_q`` for the fused attention at the winning
  ``block_s`` — one-pass coordinate descent over divisor-deduped
  candidates, the hand-picked default combo always among the timed
  candidates so ``default_us`` is measured, not assumed.
* **causal_chunked** (per seq bucket): ``q_chunk_blocks`` over the
  divisors of the block count.
* **scalars** (platform-wide): ``decode_chunk`` and ``prefill_chunk``
  timed through real `ServingEngine.serve` runs (per generated token;
  KNEE winner — the smallest candidate within 10% of the best — so the
  scheduler's tick granularity is never coarsened for a noise-level
  win), and ``chunked_min_seq`` as the smallest probed S where the
  memory-bounded chunked reference beats the plain form (full mode
  only; smoke keeps the default).

Determinism: candidate order is fixed, the winner is the FIRST minimal
candidate (`min` is stable), and every timing call routes through one
`_measure(label, fn)` choke point whose `timer` argument tests replace
with a fixed injector — same injected times, same table, bit for bit.
Trial labels are stable strings, e.g.
``exact/S2048_K128_H4_float32/bq256_bs512``.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import causal as causal_lib
from repro.kernels import common as kcommon
from repro.kernels import ops as kernel_ops
from repro.telemetry import as_telemetry
from repro.tune.table import TuningTable, shape_bucket

# Candidate grids. Values are divisor-deduped per shape before timing
# (divisor_block collapses e.g. 512 and 1024 on S=256), so the trial
# count adapts to the shape instead of re-timing identical grids.
BQ_CANDIDATES = (64, 128, 256, 512)
BS_CANDIDATES = (128, 256, 512, 1024)
QCB_CANDIDATES = (1, 2, 4, 8, 16)
DECODE_CHUNK_CANDIDATES = {"smoke": (4, 8, 32), "full": (8, 16, 32, 64)}
PREFILL_CHUNK_MULTS = {"smoke": (2, 4), "full": (4, 8, 16)}
MIN_SEQ_PROBES = (2048, 4096, 8192)   # full mode only

# A scalar winner must beat the next-larger candidate by more than this
# before the scheduler's tick granularity is refined for it: decode /
# prefill chunk lengths trade host-round overhead against scheduling
# granularity, so noise-level wins keep the coarser (cheaper) setting.
KNEE_TOLERANCE = 1.10

# exact-form sweep shapes (S, K, H, Hkv, Dh) fp32 — full mode covers the
# committed train-step exact leg's bucket (benchmarks/train_step.py)
EXACT_SHAPES = {
    "smoke": ((256, 64, 2, 2, 8),),
    "full": ((2048, 128, 4, 2, 16), (512, 64, 4, 2, 16)),
}
# causal_chunked sweep shapes (S, c, r, H, Hkv, Dh)
CAUSAL_SHAPES = {
    "smoke": ((512, 64, 8, 2, 2, 16),),
    "full": ((8192, 64, 8, 2, 2, 16),),
}

Timer = Callable[[str], float]


def _block(x) -> None:
    try:
        jax.block_until_ready(x)
    except (TypeError, ValueError):
        pass                           # host-side results (token lists)


def _measure(label: str, fn: Callable[[], object], *, warmup: int,
             iters: int, tel, timer: Optional[Timer]) -> float:
    """Median wall µs of `fn()` after `warmup` calls — or the injected
    `timer(label)` when tests replace real timing. One telemetry span +
    one `autotune_trials_total` increment per trial either way."""
    tel.metrics.counter("autotune_trials_total").inc()
    if timer is not None:
        return float(timer(label))
    with tel.span("autotune_trial", cat="autotune", label=label,
                  iters=iters):
        for _ in range(warmup):
            _block(fn())
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            _block(fn())
            times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def _dedup_divisors(size: int, candidates: Sequence[int],
                    default: int) -> List[int]:
    """Effective (divisor-resolved) candidate blocks for `size`, default
    included, ascending — the order ties are broken in."""
    eff = {kcommon.divisor_block(size, c) for c in candidates}
    eff.add(kcommon.divisor_block(size, default))
    return sorted(eff)


def _knee(results: Sequence[Tuple[int, float]],
          tol: float = KNEE_TOLERANCE) -> Tuple[int, float]:
    """(candidate, µs) of the SMALLEST candidate within `tol` of the
    best — candidates arrive smallest-first."""
    best_us = min(us for _, us in results)
    for cand, us in results:
        if us <= tol * best_us:
            return cand, us
    return results[-1]


# ---------------------------------------------------------------------------
# exact form: block_s (fused_seq_projection) × block_q (fused attention)
# ---------------------------------------------------------------------------


def tune_exact(table: TuningTable, *, shapes: Sequence[Tuple[int, ...]],
               warmup: int = 1, iters: int = 3, telemetry=None,
               timer: Optional[Timer] = None,
               platform: Optional[str] = None) -> None:
    """Sweep the exact bidirectional form's grid knobs per shape and add
    one entry per shape bucket. One-pass coordinate descent: block_s at
    the default block_q, then block_q at the winning block_s."""
    tel = as_telemetry(telemetry)
    platform = platform or jax.default_backend()
    for (S, K, H, Hkv, Dh) in shapes:
        key = jax.random.PRNGKey(0)
        kq, kk, kv, ke, kf = jax.random.split(key, 5)
        q = jax.random.normal(kq, (1, S, H, Dh), jnp.float32)
        k = jax.random.normal(kk, (1, S, Hkv, Dh), jnp.float32)
        v = jax.random.normal(kv, (1, S, Hkv, Dh), jnp.float32)
        E = jax.random.normal(ke, (S, K), jnp.float32) / np.sqrt(S)
        F = jax.random.normal(kf, (S, K), jnp.float32) / np.sqrt(S)
        tag = f"exact/S{S}_K{K}_H{H}_float32"

        def timed(bq: int, bs: int) -> float:
            fn = jax.jit(lambda q_, k_, v_, E_, F_: (
                kernel_ops.fused_linformer_attention(
                    q_,
                    kernel_ops.fused_seq_projection(k_, E_, block_s=bs),
                    kernel_ops.fused_seq_projection(v_, F_, block_s=bs),
                    scale=Dh ** -0.5, block_q=bq)))
            return _measure(f"{tag}/bq{bq}_bs{bs}",
                            lambda: fn(q, k, v, E, F), warmup=warmup,
                            iters=iters, tel=tel, timer=timer)

        bq0 = kcommon.divisor_block(S, kcommon.DEFAULT_BLOCK_Q)
        bs0 = kcommon.divisor_block(S, kcommon.DEFAULT_BLOCK_S)
        combos = [((bq0, bs), timed(bq0, bs))
                  for bs in _dedup_divisors(S, BS_CANDIDATES,
                                            kcommon.DEFAULT_BLOCK_S)]
        best_bs = min(combos, key=lambda r: r[1])[0][1]
        combos += [((bq, best_bs), timed(bq, best_bs))
                   for bq in _dedup_divisors(S, BQ_CANDIDATES,
                                             kcommon.DEFAULT_BLOCK_Q)]
        # winner over EVERY timed combo — the default (bq0, bs0) is in the
        # first pass, so trial_us can never regress below default_us just
        # because the second pass re-timed a noisier round
        (best_bq, best_bs), trial_us = min(combos, key=lambda r: r[1])
        default_us = dict(combos)[(bq0, bs0)]
        table.add(platform=platform, form="exact",
                  bucket=shape_bucket(seq=S, slots=K, heads=H,
                                      dtype="float32"),
                  params={"block_q": int(best_bq), "block_s": int(best_bs)},
                  trial_us=trial_us, default_us=default_us, trials=iters)


# ---------------------------------------------------------------------------
# causal_chunked form: q_chunk_blocks for the memory-bounded reference
# ---------------------------------------------------------------------------


def tune_causal_chunked(table: TuningTable, *,
                        shapes: Sequence[Tuple[int, ...]],
                        warmup: int = 1, iters: int = 3, telemetry=None,
                        timer: Optional[Timer] = None,
                        platform: Optional[str] = None) -> None:
    """Sweep the chunked reference form's lax.map granularity per seq
    bucket (candidates restricted to divisors of the block count — a
    non-divisor silently degrades to 1 chunk inside the kernel)."""
    tel = as_telemetry(telemetry)
    platform = platform or jax.default_backend()
    for (S, c, r, H, Hkv, Dh) in shapes:
        nb = S // c
        cands = [n for n in QCB_CANDIDATES if nb % n == 0]
        default = kcommon.DEFAULT_Q_CHUNK_BLOCKS if \
            nb % kcommon.DEFAULT_Q_CHUNK_BLOCKS == 0 else 1
        if default not in cands:
            cands.append(default)
        key = jax.random.PRNGKey(1)
        kq, kk, kv, ke, kf = jax.random.split(key, 5)
        q = jax.random.normal(kq, (1, S, H, Dh), jnp.float32)
        k = jax.random.normal(kk, (1, S, Hkv, Dh), jnp.float32)
        v = jax.random.normal(kv, (1, S, Hkv, Dh), jnp.float32)
        E = jax.random.normal(ke, (c, r), jnp.float32) / np.sqrt(c)
        F = jax.random.normal(kf, (c, r), jnp.float32) / np.sqrt(c)
        tag = f"causal_chunked/S{S}_c{c}_r{r}"

        def timed(n: int) -> float:
            fn = jax.jit(lambda q_, k_, v_, E_, F_:
                         causal_lib.blockwise_causal_attention_chunked(
                             q_, k_, v_, E_, F_, block_size=c,
                             q_chunk_blocks=n))
            return _measure(f"{tag}/qcb{n}", lambda: fn(q, k, v, E, F),
                            warmup=warmup, iters=iters, tel=tel,
                            timer=timer)

        results = [(n, timed(n)) for n in sorted(cands)]
        best, trial_us = min(results, key=lambda r: r[1])
        default_us = dict(results)[default]
        table.add(platform=platform, form="causal_chunked",
                  bucket=shape_bucket(seq=S),
                  params={"q_chunk_blocks": int(best)},
                  trial_us=trial_us, default_us=default_us, trials=iters)


# ---------------------------------------------------------------------------
# scalars: decode_chunk / prefill_chunk (live serve loops), chunked_min_seq
# ---------------------------------------------------------------------------


def _serving_setup(max_seq: int, *, block: int = 8, backend: str = "auto"):
    """A tiny linformer_causal model for the scheduler-scalar sweeps —
    the serving benchmarks' smoke shape, built here so the sweep never
    imports from benchmarks/."""
    from repro.configs.base import (AttentionConfig, LinformerConfig,
                                    ModelConfig)
    from repro.models import model as model_lib
    cfg = ModelConfig(
        name="autotune-serving", num_layers=2, d_model=64, vocab_size=512,
        max_seq_len=max_seq,
        attention=AttentionConfig(
            kind="linformer_causal", backend=backend, num_heads=4,
            num_kv_heads=2, head_dim=16,
            linformer=LinformerConfig(block_size=block, block_slots=4)),
        dtype="float32", remat="none")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def tune_scalars(table: TuningTable, *, mode: str = "full",
                 warmup: int = 1, iters: int = 3, telemetry=None,
                 timer: Optional[Timer] = None,
                 platform: Optional[str] = None) -> None:
    """Sweep the platform-wide scheduler scalars through REAL serve
    loops (µs per generated token) and add one combined scalars entry.
    `prefill_chunk` is ADVISORY: 0 (monolithic admission) stays the
    engine's semantic default — the recorded value is the best chunk
    length when chunked admission is requested."""
    from repro.serving.engine import DEFAULT_DECODE_CHUNK, ServingEngine
    tel = as_telemetry(telemetry)
    platform = platform or jax.default_backend()
    quick = mode != "full"
    rng = np.random.default_rng(0)
    params_out: Dict[str, int] = {}

    # -- decode_chunk: per-token serve wall over a short decode-heavy trace
    n_req, budget, pool = (4, 12, 2) if quick else (8, 24, 4)
    prompts = [list(rng.integers(4, 512, 16)) for _ in range(n_req)]
    budgets = [budget] * n_req
    max_seq = ((16 + budget + 64 + 7) // 8) * 8
    cfg, mparams = _serving_setup(max_seq)
    cands = DECODE_CHUNK_CANDIDATES["smoke" if quick else "full"]
    cands = sorted(set(cands) | {DEFAULT_DECODE_CHUNK})
    n_tok = float(sum(budgets))

    def timed_decode(n: int) -> float:
        eng = ServingEngine(mparams, cfg, max_seq=max_seq,
                            cache_dtype=jnp.float32, decode_chunk=n)
        return _measure(f"scalars/decode_chunk/{n}",
                        lambda: eng.serve(prompts, budgets, max_batch=pool),
                        warmup=warmup, iters=iters, tel=tel,
                        timer=timer) / n_tok

    dec_results = [(n, timed_decode(n)) for n in cands]
    best_dc, trial_us = _knee(dec_results)
    default_us = dict(dec_results)[DEFAULT_DECODE_CHUNK]
    params_out["decode_chunk"] = int(best_dc)

    # -- prefill_chunk: per-token serve wall, long prompts, chunked mode
    block = 16
    long_lens = (96, 112) if quick else (192, 224, 256)
    p_budget = 4
    p_prompts = [list(rng.integers(4, 512, L)) for L in long_lens]
    p_budgets = [p_budget] * len(p_prompts)
    p_cands = sorted(block * m for m in
                     PREFILL_CHUNK_MULTS["smoke" if quick else "full"])
    p_max = max(long_lens) + p_budget + max(p_cands)
    p_max = ((p_max + max(p_cands) - 1) // max(p_cands)) * max(p_cands)
    # reference backend, like the long_prompt bench: the scalar measures
    # admission scheduling, not interpret-mode kernel overhead
    p_cfg, p_params = _serving_setup(p_max, block=block,
                                     backend="reference")
    p_tok = float(sum(len(p) + b for p, b in zip(p_prompts, p_budgets)))

    def timed_prefill(P: int) -> float:
        eng = ServingEngine(p_params, p_cfg, max_seq=p_max,
                            cache_dtype=jnp.float32, decode_chunk=4,
                            prefill_chunk=P)
        return _measure(f"scalars/prefill_chunk/{P}",
                        lambda: eng.serve(p_prompts, p_budgets,
                                          max_batch=2),
                        warmup=warmup, iters=iters, tel=tel,
                        timer=timer) / p_tok

    pf_results = [(P, timed_prefill(P)) for P in p_cands]
    best_pf, _ = _knee(pf_results)
    params_out["prefill_chunk"] = int(best_pf)

    # -- chunked_min_seq: smallest probed S where the chunked reference
    # form beats the plain one (full mode only — the probes are the
    # expensive part of the sweep, and smoke keeps the default anyway)
    if not quick:
        threshold = causal_lib.CHUNKED_ATTENTION_MIN_SEQ
        c, r_, H, Hkv, Dh = 64, 8, 2, 2, 16
        for S in MIN_SEQ_PROBES:
            key = jax.random.PRNGKey(2)
            kq, kk, kv, ke, kf = jax.random.split(key, 5)
            q = jax.random.normal(kq, (1, S, H, Dh), jnp.float32)
            k = jax.random.normal(kk, (1, S, Hkv, Dh), jnp.float32)
            v = jax.random.normal(kv, (1, S, Hkv, Dh), jnp.float32)
            E = jax.random.normal(ke, (c, r_), jnp.float32) / np.sqrt(c)
            F = jax.random.normal(kf, (c, r_), jnp.float32) / np.sqrt(c)
            plain = jax.jit(lambda *a: causal_lib.blockwise_causal_attention(
                *a, block_size=c))
            chunk = jax.jit(
                lambda *a: causal_lib.blockwise_causal_attention_chunked(
                    *a, block_size=c))
            t_plain = _measure(f"scalars/chunked_min_seq/plain_S{S}",
                               lambda: plain(q, k, v, E, F), warmup=warmup,
                               iters=iters, tel=tel, timer=timer)
            t_chunk = _measure(f"scalars/chunked_min_seq/chunked_S{S}",
                               lambda: chunk(q, k, v, E, F), warmup=warmup,
                               iters=iters, tel=tel, timer=timer)
            if t_chunk <= t_plain:
                threshold = min(threshold, S)
                break
        params_out["chunked_min_seq"] = int(threshold)

    table.add(platform=platform, form="scalars", bucket=None,
              params=params_out, trial_us=trial_us, default_us=default_us,
              trials=iters)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def build_table(mode: str = "full", *, telemetry=None,
                timer: Optional[Timer] = None,
                platform: Optional[str] = None) -> TuningTable:
    """Run the full sweep and return the resulting table (not yet
    saved). mode: "full" | "smoke" — smoke shrinks shapes/candidates to
    gate-speed and skips the chunked_min_seq probes."""
    quick = mode != "full"
    iters = 3 if quick else 5
    table = TuningTable(meta={"generated_by": "benchmarks.autotune",
                              "mode": mode})
    kw = dict(warmup=1, iters=iters, telemetry=telemetry, timer=timer,
              platform=platform)
    tune_exact(table, shapes=EXACT_SHAPES["smoke" if quick else "full"],
               **kw)
    tune_causal_chunked(
        table, shapes=CAUSAL_SHAPES["smoke" if quick else "full"], **kw)
    tune_scalars(table, mode=mode, **kw)
    return table
