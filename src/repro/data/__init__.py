from repro.data.pipeline import (  # noqa: F401
    ByteTokenizer,
    DataState,
    SyntheticCorpus,
    make_causal_batch,
    make_mlm_batch,
)
