"""Deterministic, checkpointable data pipeline.

Offline container ⇒ the corpus is synthetic but *structured*: a mixture of
Zipfian unigram draws, copy/recall segments and arithmetic-progression spans,
so language-model losses are meaningfully comparable between attention kinds
(structure is learnable; pure iid noise would saturate at the entropy floor).

Determinism/fault tolerance: every batch is a pure function of
(seed, step, shard) — a restarted job regenerates the exact batch stream with
no skipped or duplicated data (DESIGN.md §6). `DataState` is what gets
checkpointed: {seed, step}.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

VOCAB_RESERVED = 4          # pad=0, bos=1, eos=2, mask=3
PAD, BOS, EOS, MASK = range(VOCAB_RESERVED)


@dataclasses.dataclass
class DataState:
    seed: int = 0
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"seed": self.seed, "step": self.step}

    @staticmethod
    def from_dict(d) -> "DataState":
        return DataState(int(d["seed"]), int(d["step"]))


class ByteTokenizer:
    """Reversible byte-level tokenizer (offsets past the reserved ids)."""

    vocab_size = 256 + VOCAB_RESERVED

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32) \
            + VOCAB_RESERVED

    def decode(self, ids: np.ndarray) -> str:
        ids = np.asarray(ids)
        ids = ids[ids >= VOCAB_RESERVED] - VOCAB_RESERVED
        return bytes(ids.astype(np.uint8)).decode("utf-8", errors="replace")


class SyntheticCorpus:
    """Structured synthetic token streams over an arbitrary vocab."""

    def __init__(self, vocab_size: int, seed: int = 0):
        assert vocab_size > VOCAB_RESERVED + 8
        self.vocab_size = vocab_size
        self.seed = seed
        # Zipfian unigram distribution over the non-reserved vocab
        ranks = np.arange(1, vocab_size - VOCAB_RESERVED + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))

    def sequence(self, rng: np.random.Generator, length: int) -> np.ndarray:
        """One document: zipf tokens + copy spans + progressions."""
        toks = rng.choice(len(self._p), size=length, p=self._p).astype(np.int32) \
            + VOCAB_RESERVED
        # copy/recall: repeat an earlier span later in the sequence
        n_copies = max(1, length // 128)
        for _ in range(n_copies):
            span = int(rng.integers(4, 17))
            if length < 3 * span:
                break
            src = int(rng.integers(0, length - 2 * span))
            dst = int(rng.integers(src + span, length - span))
            toks[dst:dst + span] = toks[src:src + span]
        # arithmetic progression (locally predictable structure)
        span = min(16, length // 4)
        if span >= 4:
            start = int(rng.integers(0, length - span))
            base = int(rng.integers(VOCAB_RESERVED, self.vocab_size - span - 1))
            toks[start:start + span] = base + np.arange(span)
        toks[0] = BOS
        return toks

    def batch(self, step: int, shard: int, batch: int, seq: int) -> np.ndarray:
        rng = self._rng(step, shard)
        return np.stack([self.sequence(rng, seq) for _ in range(batch)])


def make_causal_batch(corpus: SyntheticCorpus, state: DataState, *,
                      batch: int, seq: int, shard: int = 0
                      ) -> Dict[str, np.ndarray]:
    """Next-token-prediction batch: inputs t, labels t+1."""
    toks = corpus.batch(state.step, shard, batch, seq + 1)
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
        "loss_mask": np.ones((batch, seq), np.int32),
    }


def make_mlm_batch(corpus: SyntheticCorpus, state: DataState, *,
                   batch: int, seq: int, mask_prob: float = 0.15,
                   shard: int = 0) -> Dict[str, np.ndarray]:
    """BERT-style masking: 80% [MASK] / 10% random / 10% keep."""
    rng = corpus._rng(state.step, shard + 1_000_003)
    toks = corpus.batch(state.step, shard, batch, seq)
    labels = toks.copy()
    is_masked = rng.random(toks.shape) < mask_prob
    is_masked[:, 0] = False                       # keep BOS
    roll = rng.random(toks.shape)
    inp = toks.copy()
    inp[is_masked & (roll < 0.8)] = MASK
    rnd = rng.integers(VOCAB_RESERVED, corpus.vocab_size, toks.shape)
    sel = is_masked & (roll >= 0.8) & (roll < 0.9)
    inp[sel] = rnd[sel]
    return {
        "tokens": inp,
        "labels": labels,
        "loss_mask": is_masked.astype(np.int32),
    }


def batches(corpus: SyntheticCorpus, state: DataState, *, batch: int,
            seq: int, objective: str = "causal_lm", mask_prob: float = 0.15,
            shard: int = 0) -> Iterator[Tuple[Dict[str, np.ndarray], DataState]]:
    """Infinite deterministic batch stream; yields (batch, next_state)."""
    step = state.step
    while True:
        st = DataState(state.seed, step)
        if objective == "mlm":
            b = make_mlm_batch(corpus, st, batch=batch, seq=seq,
                               mask_prob=mask_prob, shard=shard)
        else:
            b = make_causal_batch(corpus, st, batch=batch, seq=seq,
                                  shard=shard)
        step += 1
        yield b, DataState(state.seed, step)
