"""Document packing and file-backed corpora.

Production LM pipelines pack variable-length documents into fixed-length
training rows (BOS/EOS delimited, no padding waste) and mask the loss across
document boundaries. `pack_documents` implements the standard greedy packer;
`FileCorpus` feeds real text through the ByteTokenizer when a directory of
.txt files is available (this container trains on the synthetic corpus, but
the serving/training stack is text-ready).
"""
from __future__ import annotations

import os
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.data.pipeline import BOS, EOS, PAD, ByteTokenizer


def pack_documents(
    docs: Sequence[np.ndarray],
    seq_len: int,
    *,
    mask_cross_document: bool = True,
) -> Dict[str, np.ndarray]:
    """Greedy-pack documents into (N, seq_len+1) rows of [BOS doc EOS ...].

    Returns causal-LM fields: tokens/labels shifted by one, loss_mask zeros
    on PAD and (optionally) on positions whose LABEL starts a new document
    (cross-document next-token prediction is noise).
    """
    rows: List[np.ndarray] = []
    seg_ids: List[np.ndarray] = []          # document id per position
    cur = np.full((seq_len + 1,), PAD, np.int32)
    cur_seg = np.zeros((seq_len + 1,), np.int32)
    pos = 0
    seg = 0

    def flush():
        nonlocal cur, cur_seg, pos
        if pos > 0:
            rows.append(cur)
            seg_ids.append(cur_seg)
            cur = np.full((seq_len + 1,), PAD, np.int32)
            cur_seg = np.zeros((seq_len + 1,), np.int32)
            pos = 0

    for doc in docs:
        seg += 1
        piece = np.concatenate([[BOS], doc.astype(np.int32), [EOS]])
        off = 0
        while off < len(piece):
            take = min(len(piece) - off, seq_len + 1 - pos)
            cur[pos:pos + take] = piece[off:off + take]
            cur_seg[pos:pos + take] = seg
            pos += take
            off += take
            if pos == seq_len + 1:
                flush()
    flush()

    if not rows:
        return {"tokens": np.zeros((0, seq_len), np.int32),
                "labels": np.zeros((0, seq_len), np.int32),
                "loss_mask": np.zeros((0, seq_len), np.int32)}
    toks = np.stack(rows)
    segs = np.stack(seg_ids)
    tokens = toks[:, :-1]
    labels = toks[:, 1:]
    mask = (labels != PAD).astype(np.int32)
    if mask_cross_document:
        # label must belong to the same document as its input position
        mask &= (segs[:, 1:] == segs[:, :-1]).astype(np.int32)
    return {"tokens": tokens, "labels": labels, "loss_mask": mask}


def packing_efficiency(batch: Dict[str, np.ndarray]) -> float:
    """Fraction of positions carrying real (non-PAD) tokens."""
    if batch["tokens"].size == 0:
        return 0.0
    return float((batch["tokens"] != PAD).mean())


class FileCorpus:
    """Reads .txt files from a directory, tokenizes (byte-level), packs.

    Deterministic given (seed, epoch); document order shuffles per epoch.
    """

    def __init__(self, directory: str, seq_len: int, seed: int = 0):
        self.tokenizer = ByteTokenizer()
        self.seq_len = seq_len
        self.seed = seed
        self.paths = sorted(
            os.path.join(directory, f) for f in os.listdir(directory)
            if f.endswith(".txt"))
        if not self.paths:
            raise FileNotFoundError(f"no .txt files in {directory}")

    @property
    def vocab_size(self) -> int:
        return self.tokenizer.vocab_size

    def _docs(self, epoch: int) -> List[np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch]))
        order = rng.permutation(len(self.paths))
        docs = []
        for i in order:
            with open(self.paths[i], "rb") as f:
                text = f.read().decode("utf-8", errors="replace")
            docs.append(self.tokenizer.encode(text))
        return docs

    def batches(self, batch_size: int, epoch: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        packed = pack_documents(self._docs(epoch), self.seq_len)
        n = packed["tokens"].shape[0]
        for i in range(0, n - batch_size + 1, batch_size):
            yield {k: v[i:i + batch_size] for k, v in packed.items()}
