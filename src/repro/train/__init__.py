from repro.train.trainer import Trainer, make_train_step  # noqa: F401
from repro.train.compressed_dp import (  # noqa: F401
    init_residual,
    make_compressed_train_step,
)
