"""Cross-pod gradient reduction with error-feedback int8 compression.

At multi-pod scale the gradient all-reduce crosses DCN — the slowest link in
the system (EXPERIMENTS.md §Perf: qwen1.5-110b multi-pod is bound by it at
36 s/step). This module restructures the data-parallel reduction so the
cross-pod hop runs on int8 payloads with error feedback (Karimireddy et al.,
2019): within-pod reductions stay exact (fast ICI), the pod axis exchanges
quantized gradients, and each pod's quantization error is fed back into its
next step — unbiased over time, 4× fewer DCN bytes than fp32 (2× vs bf16).

Built with an EXPLICIT pod axis under plain GSPMD (no shard_map): the batch
is reshaped to a leading (n_pods, ...) axis sharded P("pod"), params are
broadcast along it (each device holds its own pod's copy — the same bytes as
replication), and `jax.vmap` over that axis yields per-pod gradients with a
materialized pod dimension. The error-feedback quantize → int32 sum →
dequantize then runs as ordinary array ops whose cross-pod all-reduce the
partitioner inserts for the `sum(axis=0)`. An earlier partial-manual
shard_map formulation (only "pod" manual, data/model under GSPMD) hits an
XLA SPMD-partitioner CHECK (`sharding.IsManualSubgroup()`) when a scanned
layer stack is partitioned inside the partial-manual region on the pinned
toolchain — the explicit-axis form is equivalent math with none of that
fragility.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.models import model as model_lib
from repro.optim import adamw_update, clip_by_global_norm, make_schedule
from repro.optim.grad_utils import quantize_int8
from repro.parallel.plan import pod_batch_sharding, pod_stacked_sharding
from repro.parallel.sharding import ParallelCtx


def compressed_pod_reduce(grads_pod, residual_pod, n_pods: int):
    """Error-feedback int8 mean-reduction over an explicit leading pod axis.

    grads_pod: per-pod gradients (n_pods, ...) per leaf — each pod's own
    (uncompressed) contribution. residual_pod: matching feedback state.
    Returns (mean-reduced fp32 grads without the pod axis, new residual).
    int8 payloads are summed in int32; each pod keeps `tot - sent` so
    quantization error re-enters its next step (unbiased over time).
    """

    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q, scale = jax.vmap(quantize_int8)(tot)          # scale: (n_pods,)
        qsum = q.astype(jnp.int32).sum(axis=0)           # the DCN hop
        ssum = scale.mean()                              # shared scale (mean)
        reduced = qsum.astype(jnp.float32) * ssum / n_pods
        bshape = (n_pods,) + (1,) * (tot.ndim - 1)
        sent = q.astype(jnp.float32) * scale.reshape(bshape)
        return reduced, tot - sent

    pairs = jax.tree.map(one, grads_pod, residual_pod)
    red = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda t: isinstance(t, tuple))
    return red, res


def init_residual(params, n_pods: int):
    """Per-pod error-feedback state: leading pod axis, sharded P('pod')."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)


def make_compressed_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    ctx: ParallelCtx,
) -> Callable:
    """Train step whose cross-pod gradient hop is int8-compressed.

    Signature: (params, opt_state, residual, batch) ->
               (params, opt_state, residual, metrics)
    `residual` comes from :func:`init_residual` (leading pod axis;
    checkpoint it alongside the optimizer state).

    Requires a mesh with a "pod" axis and params NOT FSDP-sharded over it
    (the pod axis is pure DP, so per-pod grads are defined).
    """
    mesh = ctx.mesh
    assert ctx.has_pod_axis, "compressed DP needs a mesh with a pod axis"
    assert "pod" not in ctx.fsdp_axes, \
        "compressed DP needs params replicated across pods"
    n_pods = mesh.shape["pod"]
    sched = make_schedule(opt_cfg)
    # inside the vmapped per-pod body, activation constraints must not
    # mention the pod axis (it is the vmapped dimension)
    inner_ctx = dataclasses.replace(ctx, exclude_data_axes=("pod",))

    def step(params, opt_state, residual, batch):
        # explicit pod axis: each pod sees its own batch shard and its own
        # copy of the params (broadcast_to + P('pod') = one copy per pod on
        # device, the same bytes as plain replication). Placement specs come
        # from parallel/plan.py — the same module that owns the attention
        # sharding — instead of being hand-written here.
        params_pod = jax.tree.map(
            lambda p: jax.lax.with_sharding_constraint(
                jnp.broadcast_to(p[None], (n_pods,) + p.shape),
                pod_stacked_sharding(mesh, p.ndim + 1)), params)
        batch_pod = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:]),
                pod_batch_sharding(mesh, inner_ctx.data_axes, x.ndim + 1)),
            batch)

        def mean_loss(pp):
            losses, metrics = jax.vmap(
                lambda p, b: model_lib.loss_fn(p, cfg, b, ctx=inner_ctx)
            )(pp, batch_pod)
            return losses.mean(), metrics

        (_, metrics), grads_pod = jax.value_and_grad(
            mean_loss, has_aux=True)(params_pod)
        # d(mean over pods)/d params_pod[i] = grad_i / n_pods; scale back to
        # each pod's OWN gradient so the EF residual semantics match the
        # per-pod formulation
        grads_pod = jax.tree.map(lambda g: g * n_pods, grads_pod)
        grads, residual = compressed_pod_reduce(grads_pod, residual, n_pods)
        metrics = jax.tree.map(lambda m: m.mean(axis=0), metrics)

        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        lr = sched(opt_state["step"])
        params, opt_state = adamw_update(grads, opt_state, params, opt_cfg,
                                         lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, residual, metrics

    return step
