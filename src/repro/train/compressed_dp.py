"""Cross-pod gradient reduction with error-feedback int8 compression.

At multi-pod scale the gradient all-reduce crosses DCN — the slowest link in
the system (EXPERIMENTS.md §Perf: qwen1.5-110b multi-pod is bound by it at
36 s/step). This module restructures the data-parallel reduction so the
cross-pod hop runs on int8 payloads with error feedback (Karimireddy et al.,
2019): within-pod reductions stay exact (fast ICI), the pod axis exchanges
quantized gradients, and each pod's quantization error is fed back into its
next step — unbiased over time, 4× fewer DCN bytes than fp32 (2× vs bf16).

Built with a partial-auto shard_map: only the "pod" axis is manual (its psum
is replaced by quantize → psum(int32) → dequantize); the within-pod
data/model axes stay under GSPMD as usual.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.models import model as model_lib
from repro.optim import adamw_update, clip_by_global_norm, make_schedule
from repro.optim.grad_utils import quantize_int8
from repro.parallel.sharding import ParallelCtx

try:
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def compressed_pod_psum(grads, residual, axis: str = "pod"):
    """Error-feedback int8 psum over `axis` (call inside shard_map).

    grads: per-pod fp32/bf16 gradient pytree. residual: this pod's feedback
    state (fp32, same structure). Returns (mean-reduced fp32 grads, new
    residual). int8 payloads are summed in int32."""
    n = jax.lax.psum(1, axis)

    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q, scale = quantize_int8(tot)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        ssum = jax.lax.psum(scale, axis) / n     # shared scale (mean)
        reduced = qsum.astype(jnp.float32) * ssum / n
        sent = q.astype(jnp.float32) * scale     # what this pod contributed
        return reduced, tot - sent

    pairs = jax.tree.map(one, grads, residual)
    red = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda t: isinstance(t, tuple))
    return red, res


def init_residual(params, n_pods: int):
    """Per-pod error-feedback state: leading pod axis, sharded P('pod')."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)


def make_compressed_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    ctx: ParallelCtx,
) -> Callable:
    """Train step whose cross-pod gradient hop is int8-compressed.

    Signature: (params, opt_state, residual, batch) ->
               (params, opt_state, residual, metrics)
    `residual` comes from :func:`init_residual` (leading pod axis;
    checkpoint it alongside the optimizer state).

    Requires a mesh with a "pod" axis and params NOT FSDP-sharded over it
    (the pod axis is pure DP, so per-pod grads are defined).

    Known limitation: with params explicitly PLACED as 2-axis-sharded
    (vocab over "model" + FSDP over "data"), XLA's SPMD partitioner hits a
    CHECK failure partitioning the embedding gather inside the partial-manual
    region (ExpandDeviceGroupsWithIota, observed in XLA for jax 0.8). Use
    TP-only placement (fsdp="none") with compressed DP, or leave params
    unplaced and let GSPMD choose.
    """
    mesh = ctx.mesh
    assert mesh is not None and "pod" in mesh.axis_names
    assert "pod" not in ctx.fsdp_axes, \
        "compressed DP needs params replicated across pods"
    sched = make_schedule(opt_cfg)
    # inside the pod-manual region, activation constraints must not mention
    # the manual axis
    inner_ctx = dataclasses.replace(ctx, exclude_data_axes=("pod",))

    def step(params, opt_state, residual, batch):
        def per_pod(params_, residual_, batch_):
            residual_ = jax.tree.map(lambda r: r[0], residual_)

            def loss_fn(p):
                return model_lib.loss_fn(p, cfg, batch_, ctx=inner_ctx)

            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params_)
            grads, residual_ = compressed_pod_psum(grads, residual_, "pod")
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
            residual_ = jax.tree.map(lambda r: r[None], residual_)
            return grads, residual_, metrics

        rep = jax.tree.map(lambda _: P(), params)
        pod0 = jax.tree.map(lambda _: P("pod"), residual)
        mspec = {"loss": P(), "aux_loss": P(), "tokens": P(),
                 "perplexity": P()}
        # partial-manual shard_map: only "pod" is manual; data/model stay
        # under GSPMD inside the body
        grads, residual, metrics = _shard_map(
            per_pod, mesh=mesh,
            in_specs=(rep, pod0, jax.tree.map(lambda _: P("pod"), batch)),
            out_specs=(rep, pod0, mspec),
            check_vma=False, axis_names=frozenset({"pod"}),
        )(params, residual, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        lr = sched(opt_state["step"])
        params, opt_state = adamw_update(grads, opt_state, params, opt_cfg,
                                         lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, residual, metrics

    return step
