"""Training loop: jit'd train step (with microbatch gradient accumulation),
checkpoint/auto-resume fault tolerance, preemption handling and a straggler
watchdog.

`make_train_step` is also what the multi-pod dry-run lowers — the exact
production step (fwd + bwd + clip + AdamW), not a simplified proxy.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, OptimizerConfig, TrainConfig
from repro.data import DataState, SyntheticCorpus, pipeline
from repro.models import model as model_lib
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    make_schedule
from repro.parallel.sharding import ParallelCtx
from repro.telemetry import MS_BUCKETS, as_telemetry, plan_attribution


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    *,
    ctx: Optional[ParallelCtx] = None,
    microbatch: int = 0,
) -> Callable:
    """Build the pure train step: (params, opt_state, batch) -> (params,
    opt_state, metrics). With microbatch > 0, the global batch is split and
    gradients are accumulated in fp32 over a lax.scan (bf16 activations,
    fp32 accumulation — grad-reduction precision control per DESIGN §6)."""
    sched = make_schedule(opt_cfg)

    def loss_for(p, b):
        return model_lib.loss_fn(p, cfg, b, ctx=ctx)

    def compute_grads(params, batch):
        if not microbatch:
            (_, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
            return grads, metrics

        gb = jax.tree.leaves(batch)[0].shape[0]
        assert gb % microbatch == 0, (gb, microbatch)
        n_micro = gb // microbatch
        stacked = jax.tree.map(
            lambda x: x.reshape((n_micro, microbatch) + x.shape[1:]), batch)

        def body(acc, mb):
            (_, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_micro, acc, grads)
            return acc, metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, metrics = jax.lax.scan(body, zeros, stacked)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        lr = sched(opt_state["step"])
        params, opt_state = adamw_update(grads, opt_state, params, opt_cfg, lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


class Trainer:
    """Drives the step function with fault tolerance.

    * auto-resume: scans `checkpoint_dir` at startup and restores the latest
      complete checkpoint (params, optimizer, data state).
    * preemption: `preempt_check()` (injectable — SIGTERM flag, file flag, or
      test hook) triggers an immediate checkpoint + clean exit.
    * straggler watchdog: logs steps slower than `straggler_factor` × the
      running median (on real fleets this feeds the controller's evictions;
      here it is observability + tests).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        *,
        ctx: Optional[ParallelCtx] = None,
        preempt_check: Optional[Callable[[], bool]] = None,
        log_fn: Callable[[str], None] = print,
        attention_backend: Optional[str] = None,
        backward_impl: Optional[str] = None,
        telemetry=None,
    ):
        # attention_backend overrides cfg.attention.backend for this run
        # ("reference" | "fused"; None keeps the config's knob, whose "auto"
        # default resolves to the fused Pallas kernels — kernels/ops.py).
        # backward_impl overrides cfg.attention.backward_impl the same way
        # ("fused" Pallas backward | "reference" recompute oracle) for the
        # blockwise-causal training path.
        if attention_backend is not None:
            cfg = cfg.with_attention_backend(attention_backend)
        if backward_impl is not None:
            cfg = cfg.with_backward_impl(backward_impl)
        # Resolve the attention execution plan up front: under a mesh this
        # fails fast (launch/mesh.py divisibility errors) at construction
        # instead of deep inside the first jitted step, and the resolved
        # plan is what every attention call of the step function threads.
        from repro.parallel.plan import resolve_attention_plan
        self.plan = resolve_attention_plan(cfg.attention, ctx)
        self.cfg = cfg
        self.tcfg = tcfg
        self.ctx = ctx
        self.preempt_check = preempt_check or (lambda: False)
        self.log = log_fn
        self.ckpt = Checkpointer(tcfg.checkpoint_dir)
        self.corpus = SyntheticCorpus(cfg.vocab_size, seed=tcfg.seed)
        self.step_times = []
        # telemetry: per-step spans + a "train_step" JSONL record per step
        # (loss, grad-norm, tokens/s) + the resolved plan's cost attribution
        # (docs/observability.md); None = the disabled no-op singleton.
        self.telemetry = as_telemetry(telemetry)
        if self.telemetry.enabled:
            rec = plan_attribution(self.plan, cfg.attention,
                                   max_seq=tcfg.seq_len,
                                   batch=tcfg.global_batch)
            self.telemetry.record(rec.pop("kind"), **rec)

        self.compressed = bool(
            tcfg.compressed_pod_grads and ctx is not None
            and ctx.has_pod_axis)
        if self.compressed:
            from repro.train.compressed_dp import make_compressed_train_step
            step_fn = make_compressed_train_step(cfg, tcfg.optimizer, ctx)
            self.train_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        else:
            step_fn = make_train_step(cfg, tcfg.optimizer, ctx=ctx,
                                      microbatch=tcfg.microbatch)
            self.train_step = jax.jit(step_fn, donate_argnums=(0, 1))

    def _place(self, params, opt_state):
        """On a mesh, lay params/optimizer out per the sharding rules (the
        elastic-restart path flows through here too: restored host arrays are
        device_put with the *current* mesh's shardings)."""
        if self.ctx is None or self.ctx.mesh is None:
            return params, opt_state
        from repro.parallel.sharding import param_shardings
        p_sh = param_shardings(params, self.ctx)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = {
            "mu": jax.tree.map(jax.device_put, opt_state["mu"],
                               param_shardings(opt_state["mu"], self.ctx)),
            "nu": jax.tree.map(jax.device_put, opt_state["nu"],
                               param_shardings(opt_state["nu"], self.ctx)),
            "step": opt_state["step"],
        }
        return params, opt_state

    # -- state --------------------------------------------------------------

    def init_state(self, rng: Optional[jax.Array] = None):
        rng = rng if rng is not None else jax.random.PRNGKey(self.tcfg.seed)
        params = model_lib.init_params(rng, self.cfg)
        opt_state = adamw_init(params, self.tcfg.optimizer)
        if self.compressed:
            from repro.train.compressed_dp import init_residual
            self._residual = init_residual(
                params, self.ctx.mesh.shape["pod"])
        return params, opt_state, DataState(self.tcfg.seed, 0)

    def restore_or_init(self):
        params, opt_state, dstate = self.init_state()
        latest = self.ckpt.latest_step()
        if latest is not None:
            tmpl = {"params": params, "opt_state": opt_state}
            if self.compressed:
                tmpl["residual"] = self._residual
            restored, meta = self.ckpt.restore(latest, tmpl)
            params, opt_state = restored["params"], restored["opt_state"]
            if self.compressed:
                self._residual = restored["residual"]
            dstate = DataState.from_dict(meta["data_state"])
            params, opt_state = self._place(params, opt_state)
            self.log(f"[trainer] resumed from step {latest}")
            return params, opt_state, dstate, latest
        params, opt_state = self._place(params, opt_state)
        return params, opt_state, dstate, 0

    def save(self, step, params, opt_state, dstate):
        state = {"params": params, "opt_state": opt_state}
        if self.compressed:
            state["residual"] = self._residual
        self.ckpt.save(step, state,
                       metadata={"data_state": dstate.to_dict()})

    # -- loop ---------------------------------------------------------------

    def run(self, steps: Optional[int] = None) -> Dict[str, float]:
        tcfg = self.tcfg
        steps = steps if steps is not None else tcfg.steps
        params, opt_state, dstate, start = self.restore_or_init()
        stream = pipeline.batches(
            self.corpus, dstate, batch=tcfg.global_batch, seq=tcfg.seq_len,
            objective=self.cfg.objective, mask_prob=tcfg.mlm_mask_prob)
        last_metrics: Dict[str, float] = {}
        for step in range(start, steps):
            np_batch, dstate = next(stream)
            batch = jax.tree.map(jnp.asarray, np_batch)
            t0 = time.perf_counter()
            with self.telemetry.span("train_step", cat="trainer", step=step):
                if self.compressed:
                    params, opt_state, self._residual, metrics = \
                        self.train_step(params, opt_state, self._residual,
                                        batch)
                else:
                    params, opt_state, metrics = self.train_step(
                        params, opt_state, batch)
                # the float() casts below are the step's host sync; keeping
                # them inside the span times the actual device work
                metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            self._watchdog(step, dt)
            self._record_step(step, dt, metrics)
            last_metrics = metrics
            if (step + 1) % tcfg.log_every == 0:
                self.log(f"[trainer] step {step + 1} "
                         f"loss={metrics['loss']:.4f} "
                         f"gnorm={metrics['grad_norm']:.3f} {dt * 1e3:.0f}ms")
            if (step + 1) % tcfg.checkpoint_every == 0:
                self.save(step + 1, params, opt_state, dstate)
            if self.preempt_check():
                self.save(step + 1, params, opt_state, dstate)
                self.log(f"[trainer] preempted at step {step + 1}; "
                         "checkpointed and exiting")
                last_metrics["preempted_at"] = step + 1
                return last_metrics
        self.save(steps, params, opt_state, dstate)
        self._params = params
        return last_metrics

    def _record_step(self, step: int, dt: float,
                     metrics: Dict[str, float]) -> None:
        """One JSONL record + histogram/gauge updates per executed step."""
        if not self.telemetry.enabled:
            return
        tokens = metrics.get("tokens",
                             self.tcfg.global_batch * self.tcfg.seq_len)
        tokens_per_s = tokens / dt if dt > 0 else 0.0
        self.telemetry.record(
            "train_step", step=step, step_ms=round(dt * 1e3, 3),
            tokens_per_s=round(tokens_per_s, 1),
            loss=metrics.get("loss"), grad_norm=metrics.get("grad_norm"),
            lr=metrics.get("lr"))
        reg = self.telemetry.metrics
        reg.histogram("train_step_ms", buckets=MS_BUCKETS).observe(dt * 1e3)
        reg.counter("train_steps_total").inc()
        reg.counter("train_tokens_total").inc(tokens)
        reg.gauge("train_loss").set(metrics.get("loss", float("nan")))
        reg.gauge("train_grad_norm").set(
            metrics.get("grad_norm", float("nan")))

    def _watchdog(self, step: int, dt: float, factor: float = 2.0):
        if len(self.step_times) >= 8:
            med = float(np.median(self.step_times[-32:]))
            if dt > factor * med:
                self.log(f"[watchdog] step {step} took {dt:.3f}s "
                         f"(median {med:.3f}s) — straggler")
