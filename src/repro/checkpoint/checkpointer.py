"""Fault-tolerant checkpointing.

* Atomic: written to `step_<N>.tmp/` then os.rename'd — a preempted writer
  never corrupts the latest checkpoint.
* Mesh-independent: arrays are stored as full (unsharded) host arrays keyed by
  pytree path, so a restart may use a *different* mesh/device count (elastic
  restart): `restore(..., shardings=...)` device_puts each leaf with the new
  sharding.
* Self-describing: metadata.json holds step + data-pipeline state, so the
  deterministic loader resumes at the exact batch boundary.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # npz has no native bf16/fp8 — store widened; restore re-narrows
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    def leaf(path, t):
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(t.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {t.shape}")
        try:
            return arr.astype(t.dtype)
        except (ValueError, TypeError):
            import ml_dtypes  # noqa: F401 — registers bf16 etc. with numpy
            return arr.astype(np.dtype(str(t.dtype)))

    return jax.tree_util.tree_map_with_path(leaf, template)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Dict[str, Any],
             metadata: Optional[Dict] = None) -> str:
        tmp = os.path.join(self.directory, f"step_{step:08d}.tmp")
        final = os.path.join(self.directory, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, tree in state.items():
            np.savez(os.path.join(tmp, f"{name}.npz"), **_flatten(tree))
        meta = dict(metadata or {})
        meta["step"] = step
        with open(os.path.join(tmp, "metadata.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, templates: Dict[str, Any],
                shardings: Optional[Dict[str, Any]] = None
                ) -> Tuple[Dict[str, Any], Dict]:
        """Restore named pytrees; `templates` provides structure/shape/dtype.
        `shardings` (same keys) reshards onto the *current* mesh — this is the
        elastic-restart path (checkpoint written on N devices, restored on M).
        """
        d = os.path.join(self.directory, f"step_{step:08d}")
        out = {}
        for name, template in templates.items():
            with np.load(os.path.join(d, f"{name}.npz")) as z:
                flat = {k: z[k] for k in z.files}
            tree = _unflatten_into(template, flat)
            if shardings and name in shardings:
                tree = jax.tree.map(jax.device_put, tree, shardings[name])
            else:
                tree = jax.tree.map(jax.numpy.asarray, tree)
            out[name] = tree
        with open(os.path.join(d, "metadata.json")) as f:
            meta = json.load(f)
        return out, meta

    def restore_latest(self, templates, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return self.restore(step, templates, shardings)
