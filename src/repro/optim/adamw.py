"""AdamW in pure JAX over pytrees.

Moments can be stored in bf16 (`moment_dtype`) to halve optimizer memory —
the update math always runs in fp32. Optimizer state mirrors parameter
sharding (ZeRO by construction: the dry-run shards opt state exactly like the
FSDP'd parameters).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def adamw_init(params, cfg: OptimizerConfig) -> Dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads, opt_state: Dict, params, cfg: OptimizerConfig, lr: jax.Array,
) -> Tuple[Dict, Dict]:
    """Returns (new_params, new_opt_state). lr is the scheduled step size."""
    step = opt_state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = mu32 / c1
        vhat = nu32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2), standard
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), mu32.astype(mu.dtype),
                nu32.astype(nu.dtype))

    flat = jax.tree.map(upd, grads, opt_state["mu"], opt_state["nu"], params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}
