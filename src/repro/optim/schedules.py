"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def make_schedule(cfg: OptimizerConfig):
    def lr_at(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "constant":
            decay = 1.0
        elif cfg.schedule == "linear":
            frac = jnp.clip((s - cfg.warmup_steps) /
                            max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
            decay = 1.0 - frac
        else:  # cosine
            frac = jnp.clip((s - cfg.warmup_steps) /
                            max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return cfg.lr * warm * decay

    return lr_at
