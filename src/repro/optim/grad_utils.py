"""Gradient utilities: global-norm clipping, microbatch accumulation, and
error-feedback int8 gradient compression for bandwidth-limited (cross-pod)
reductions.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Dict, jax.Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# Error-feedback int8 compression (for cross-pod / DCN gradient reduction)
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, residual):
    """Error-feedback compression: quantize (g + residual); the quantization
    error becomes the next step's residual, so the compressed reduction is
    unbiased over time (Karimireddy et al., 2019). The int8 payload is what
    would cross the DCN — a 4× byte reduction vs fp32 (2× vs bf16).

    Returns (quantized {q, scale} tree, new_residual tree).
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                grads)

    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q, s = quantize_int8(tot)
        deq = dequantize_int8(q, s)
        return {"q": q, "scale": s}, tot - deq

    pairs = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_res


def decompress(comp):
    return jax.tree.map(
        lambda c: dequantize_int8(c["q"], c["scale"]),
        comp, is_leaf=lambda c: isinstance(c, dict) and "q" in c)
