from repro.optim.adamw import adamw_init, adamw_update  # noqa: F401
from repro.optim.schedules import make_schedule  # noqa: F401
from repro.optim.grad_utils import (  # noqa: F401
    clip_by_global_norm,
    global_norm,
)
