"""Telemetry: span tracing, metrics, per-request serving timelines.

The `Telemetry` facade bundles the three layers (docs/observability.md):

* a ring-buffer span `Tracer` with Chrome-trace/Perfetto JSON export,
* a `MetricsRegistry` (counters / gauges / fixed-bucket histograms) with
  Prometheus-text and JSONL export,
* `ServingTimelines` — per-request lifecycle stamps folded into
  per-priority SLO histograms (queue wait, TTFT, TPOT, deadline slack),

plus a free-form JSONL record stream (`record`) for one-shot structured
facts: trainer step metrics, `cost.plan_attribution` dumps, run config.

One `Telemetry` can span several scheduler runs (a warm benchmark reruns
`serve()` with the same engine): each `Scheduler` gets a FRESH timelines
object + metrics registry via `new_timelines()` / `adopt_registry()`, so
request ids and counters never collide across runs; the facade stitches
every run back together at export time (one Perfetto process per run).

Disabled contract: `Telemetry(enabled=False)` — and the module-level
`NULL_TELEMETRY` singleton — makes every hot-path call a no-op without
call sites branching: `span()` returns the null span, `new_timelines()`
returns the shared `NULL_TIMELINES`, `record()` returns immediately.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from .cost import (causal_attention_flops, chunk_prefill_flops,
                   decode_token_flops, exact_attention_flops,
                   plan_attribution)
from .metrics import (MS_BUCKETS, TICK_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, percentile_from_cumulative)
from .trace import HOST_PID, Tracer, write_chrome_trace
from .timeline import NULL_TIMELINES, NullTimelines, ServingTimelines

# pid block for synthesized per-request run tracks (HOST_PID=0 is the
# host spans/instants track)
RUN_PID_BASE = 100


class Telemetry:
    """Facade over tracer + metrics + timelines + JSONL records."""

    def __init__(self, enabled: bool = True, trace_capacity: int = 1 << 16):
        self.enabled = enabled
        self.tracer = Tracer(enabled=enabled, capacity=trace_capacity)
        self.metrics = MetricsRegistry()
        self.records: List[Dict] = []
        self._runs: List[Dict] = []        # {label, timelines?, registry?}

    # -- hot-path surface (all no-ops when disabled) -----------------------

    def span(self, name: str, cat: str = "span", **args):
        return self.tracer.span(name, cat, **args)

    def instant(self, name: str, cat: str = "event", **args) -> None:
        self.tracer.instant(name, cat, **args)

    def record(self, kind: str, **fields) -> None:
        """Append one structured JSONL record (e.g. a train step)."""
        if not self.enabled:
            return
        self.records.append({"kind": kind, **fields})

    # -- per-run attachments ----------------------------------------------

    def new_timelines(self, label: str = "serving"):
        """A fresh per-request timeline namespace for one scheduler run."""
        if not self.enabled:
            return NULL_TIMELINES
        tl = ServingTimelines(self.tracer)
        self._runs.append({"label": f"{label}#{len(self._runs)}",
                           "timelines": tl})
        return tl

    def adopt_registry(self, registry: MetricsRegistry,
                       label: str = "serving") -> None:
        """Adopt a run-local registry (a Scheduler's ScheduleStats backing
        store) so its counters/histograms land in this facade's exports."""
        if not self.enabled:
            return
        for run in reversed(self._runs):
            if run["label"].startswith(label) and "registry" not in run:
                run["registry"] = registry
                return
        self._runs.append({"label": f"{label}#{len(self._runs)}",
                           "registry": registry})

    # -- export ------------------------------------------------------------

    def chrome_events(self) -> List[Dict]:
        events = self.tracer.chrome_events()
        events.append({"ph": "M", "name": "process_name", "pid": HOST_PID,
                       "args": {"name": "host"}})
        for i, run in enumerate(self._runs):
            tl = run.get("timelines")
            if tl is not None:
                events.extend(tl.trace_events(pid=RUN_PID_BASE + i,
                                              run_label=run["label"]))
        return events

    def export_trace(self, path: str,
                     metadata: Optional[Dict] = None) -> str:
        meta = {"dropped_events": self.tracer.dropped}
        if metadata:
            meta.update(metadata)
        return write_chrome_trace(path, self.chrome_events(), metadata=meta)

    def metrics_records(self) -> List[Dict]:
        """All JSONL records: free-form `record()` entries, the facade
        registry, and every adopted per-run registry (tagged with its run
        label)."""
        out = list(self.records)
        out.extend(self.metrics.jsonl_records())
        for run in self._runs:
            reg = run.get("registry")
            if reg is not None:
                for rec in reg.jsonl_records():
                    rec["run"] = run["label"]
                    out.append(rec)
        return out

    def export_metrics_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for rec in self.metrics_records():
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        return path

    def prometheus_text(self) -> str:
        parts = [self.metrics.prometheus_text()]
        for run in self._runs:
            reg = run.get("registry")
            if reg is not None:
                parts.append(f"# run: {run['label']}\n"
                             + reg.prometheus_text())
        return "".join(p for p in parts if p)


NULL_TELEMETRY = Telemetry(enabled=False)


def as_telemetry(telemetry: Optional[Telemetry]) -> Telemetry:
    """None -> the shared disabled singleton (zero-overhead call sites)."""
    return telemetry if telemetry is not None else NULL_TELEMETRY


__all__ = [
    "Counter", "Gauge", "Histogram", "HOST_PID", "MetricsRegistry",
    "MS_BUCKETS", "NULL_TELEMETRY", "NULL_TIMELINES", "NullTimelines",
    "RUN_PID_BASE", "ServingTimelines", "Telemetry", "Tracer", "TICK_BUCKETS",
    "as_telemetry", "causal_attention_flops", "chunk_prefill_flops",
    "decode_token_flops", "exact_attention_flops",
    "percentile_from_cumulative", "plan_attribution", "write_chrome_trace",
]
