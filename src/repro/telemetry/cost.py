"""Cost attribution for a resolved `AttentionPlan`.

Turns a (plan, AttentionConfig, shape) triple into a JSON-serializable
attribution record: per attention form, the resolved backend, which mesh
axes it actually shards over, an analytic FLOPs estimate, and the
per-device communication bytes from the comm-cost model in
`core/seq_parallel.py` (docs/parallelism.md §Comm bytes). The launchers
and benchmarks dump one such record per run into the telemetry JSONL so
a committed BENCH number always travels with the execution plan that
produced it.

FLOPs conventions: one multiply-accumulate = 2 FLOPs; estimates cover
the attention contractions only (QK^T + PV, plus the K/V sequence
projection for the exact form) — projections to/from the residual stream
belong to the surrounding block, not the mixer.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.configs.base import AttentionConfig
from repro.core.seq_parallel import (blockwise_sp_comm_bytes,
                                     seq_parallel_comm_bytes)


def exact_attention_flops(batch: int, seq: int, acfg: AttentionConfig) -> int:
    """Exact Linformer form: project K/V to k slots (2 projections), then
    QK̄^T + P·V̄ over the k compressed slots."""
    h, hkv, dh = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    k = acfg.linformer.k
    proj = 2 * (2 * batch * seq * k * hkv * dh)
    attn = 2 * (2 * batch * seq * k * h * dh)
    return proj + attn

def _m_total(seq: int, acfg: AttentionConfig) -> int:
    lin = acfg.linformer
    return (seq // lin.block_size) * lin.block_slots


def causal_attention_flops(batch: int, seq: int, acfg: AttentionConfig) -> int:
    """Blockwise-causal form: each query attends to its c-token local block
    plus (at most) all M = (S/c)·r compressed slots — the O(n) claim is
    that c + M grows ~linearly in n for fixed c, r."""
    h, dh = acfg.num_heads, acfg.head_dim
    lin = acfg.linformer
    ctx = lin.block_size + _m_total(seq, acfg)
    comp = 2 * (2 * batch * seq * lin.block_slots * dh)   # conv compression
    attn = 2 * (2 * batch * seq * ctx * h * dh)
    return comp + attn


def chunk_prefill_flops(batch: int, chunk: int, seq: int,
                        acfg: AttentionConfig) -> int:
    """One admission-prefill chunk of `chunk` tokens against a cache
    provisioned for `seq` (the pinned compressed buffer is M(seq) slots)."""
    h, dh = acfg.num_heads, acfg.head_dim
    ctx = acfg.linformer.block_size + _m_total(seq, acfg)
    return 2 * (2 * batch * chunk * ctx * h * dh)


def decode_token_flops(batch: int, seq: int, acfg: AttentionConfig) -> int:
    """One decode step: a single query row against [raw ring | compressed
    slots] — c + M(seq) keys per head."""
    h, dh = acfg.num_heads, acfg.head_dim
    ctx = acfg.linformer.block_size + _m_total(seq, acfg)
    return 2 * (2 * batch * 1 * ctx * h * dh)


def plan_attribution(plan, acfg: AttentionConfig, *, max_seq: int,
                     batch: int = 1,
                     prefill_chunk: Optional[int] = None) -> Dict:
    """One JSON-serializable record describing how `plan` will execute each
    attention form of `acfg` at (batch, max_seq) scale."""
    lin = acfg.linformer
    d_total = acfg.num_kv_heads * acfg.head_dim
    sp = plan.sp
    lin_bytes, ring_bytes = blockwise_sp_comm_bytes(
        max_seq, lin.block_size, lin.block_slots, d_total, max(sp, 2))
    exact_lin, exact_ring = seq_parallel_comm_bytes(
        max_seq, lin.k, d_total, max(sp, 2))
    chunk = prefill_chunk or lin.block_size

    def form(name: str, *, sharded_seq: bool, flops: int,
             comm_bytes: int) -> Dict:
        return {
            "form": name,
            "backend": plan.backend,
            "manual": bool(plan.manual),
            "tp_axis": plan.tp_axis if plan.tp > 1 else None,
            "sp_axis": plan.sp_axis if (plan.sp > 1 and sharded_seq) else None,
            "est_flops": int(flops),
            "comm_bytes_per_device": int(comm_bytes if sp > 1 else 0),
        }

    return {
        "kind": "plan_attribution",
        "attention_kind": acfg.kind,
        "backend": plan.backend,
        "backward_impl": plan.backward_impl,
        "tp": plan.tp,
        "sp": plan.sp,
        "data_axes": list(plan.data_axes),
        "batch": batch,
        "max_seq": max_seq,
        "block_size": lin.block_size,
        "block_slots": lin.block_slots,
        "compressed_slots_total": _m_total(max_seq, acfg),
        # ring_bytes: what a ring-attention exchange of raw K/V would cost —
        # the denominator of the Linformer comm win quoted in
        # docs/parallelism.md.
        "ring_bytes_per_device": int(ring_bytes if sp > 1 else 0),
        "exact_ring_bytes_per_device": int(exact_ring if sp > 1 else 0),
        "forms": [
            form("train_causal", sharded_seq=True,
                 flops=causal_attention_flops(batch, max_seq, acfg),
                 comm_bytes=lin_bytes),
            form("exact", sharded_seq=True,
                 flops=exact_attention_flops(batch, max_seq, acfg),
                 comm_bytes=exact_lin),
            form("chunk_prefill", sharded_seq=True,
                 flops=chunk_prefill_flops(batch, chunk, max_seq, acfg),
                 comm_bytes=lin_bytes),
            # decode is head-parallel only: the sp axis idles (plan.py §decode)
            form("decode", sharded_seq=False,
                 flops=decode_token_flops(batch, max_seq, acfg),
                 comm_bytes=0),
        ],
    }
