"""Metrics registry: counters, gauges, fixed-bucket histograms, with
Prometheus-style text and JSONL export.

Design constraints (docs/observability.md §Metric name registry):

* **Label-aware** — a metric is keyed by (name, sorted label items), so
  ``reg.counter("serving_sheds_total", reason="queue_full")`` and the
  ``reason="deadline_infeasible"`` variant are distinct series, exactly
  like Prometheus.
* **Fixed buckets** — histograms take their bucket boundaries at creation
  and never rebucket; observation is O(log n_buckets) with zero
  allocation. Percentiles are reconstructed by linear interpolation
  within the hit bucket (the standard Prometheus ``histogram_quantile``
  approximation), so a percentile is as accurate as the bucket grid —
  good enough for SLO attribution, never a replacement for a raw trace.
* **Plain objects** — `Counter.value` is a float attribute; incrementing
  one is an attribute add, cheap enough for per-chunk host-side counting.
  `serving/scheduler.ScheduleStats` is a *view* over these counters, not
  a parallel set of hand-rolled ints.
"""
from __future__ import annotations

import bisect
import json
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# Default grids: virtual-time (scheduler ticks) and host milliseconds.
TICK_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, math.inf)
MS_BUCKETS = (0.5, 1, 2.5, 5, 10, 25, 50, 100, 250, 500, 1000, 2500, 5000,
              math.inf)


class Counter:
    """Monotonic-by-convention float counter. `value` is directly
    assignable so stat *views* (ScheduleStats) can restore/overwrite."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: cumulative-export compatible counts plus
    sum/count/min/max."""

    __slots__ = ("buckets", "counts", "sum", "count", "min", "max")

    def __init__(self, buckets: Sequence[float]):
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("histogram needs at least one bucket bound")
        if bs[-1] != math.inf:
            bs = bs + (math.inf,)
        self.buckets = bs
        self.counts = [0] * len(bs)         # per-bucket (NOT cumulative)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def cumulative(self) -> List[Tuple[float, int]]:
        """[(le, cumulative_count)] — the Prometheus export shape."""
        out, acc = [], 0
        for le, c in zip(self.buckets, self.counts):
            acc += c
            out.append((le, acc))
        return out

    def percentile(self, p: float) -> float:
        """Approximate p∈[0,100] percentile by linear interpolation inside
        the hit bucket (clamped to observed min/max so a sparse histogram
        cannot report a value outside its data)."""
        return percentile_from_cumulative(self.cumulative(), self.count, p,
                                          lo=self.min, hi=self.max)


def percentile_from_cumulative(cumulative: Sequence[Tuple[float, int]],
                               total: int, p: float,
                               lo: float = math.inf,
                               hi: float = -math.inf) -> float:
    """Shared percentile reconstruction — also used by benchmarks/report.py
    on a metrics *JSONL dump*, where only the cumulative counts survive."""
    if total <= 0:
        return float("nan")
    rank = (p / 100.0) * total
    prev_le, prev_cum = 0.0, 0
    for le, cum in cumulative:
        if cum >= rank:
            in_bucket = cum - prev_cum
            frac = 1.0 if in_bucket == 0 else (rank - prev_cum) / in_bucket
            upper = hi if le == math.inf and hi > -math.inf else le
            val = prev_le + frac * (upper - prev_le)
            if lo != math.inf:
                val = max(val, lo)
            if hi != -math.inf:
                val = min(val, hi)
            return val
        prev_le, prev_cum = le, cum
    return hi if hi > -math.inf else float("nan")


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class MetricsRegistry:
    """Create-on-first-use registry of labelled counters/gauges/histograms."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple], object] = {}
        self._types: Dict[str, str] = {}    # name -> counter|gauge|histogram

    def _get(self, kind: str, name: str, labels: Dict[str, str], factory):
        prev = self._types.setdefault(name, kind)
        if prev != kind:
            raise ValueError(f"metric {name!r} already registered as {prev}")
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = factory()
            self._metrics[key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(buckets or MS_BUCKETS))

    def items(self) -> Iterable[Tuple[str, Dict[str, str], object]]:
        for (name, labels), m in sorted(self._metrics.items(),
                                        key=lambda kv: kv[0]):
            yield name, dict(labels), m

    # -- export ------------------------------------------------------------

    def prometheus_text(self) -> str:
        """Prometheus exposition format (one # TYPE line per family)."""
        lines: List[str] = []
        seen_type = set()
        for name, labels, m in self.items():
            kind = self._types[name]
            if name not in seen_type:
                lines.append(f"# TYPE {name} {kind}")
                seen_type.add(name)
            ls = _label_str(_label_key(labels))
            if isinstance(m, (Counter, Gauge)):
                lines.append(f"{name}{ls} {m.value:g}")
            else:
                for le, cum in m.cumulative():
                    le_s = "+Inf" if le == math.inf else f"{le:g}"
                    il = _label_key({**labels, "le": le_s})
                    lines.append(f"{name}_bucket{_label_str(il)} {cum}")
                lines.append(f"{name}_sum{ls} {m.sum:g}")
                lines.append(f"{name}_count{ls} {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def jsonl_records(self) -> List[Dict]:
        """One JSON-serializable dict per metric series (the JSONL dump
        schema of docs/observability.md §JSONL export)."""
        out = []
        for name, labels, m in self.items():
            rec: Dict = {"metric": name, "type": self._types[name],
                         "labels": labels}
            if isinstance(m, (Counter, Gauge)):
                rec["value"] = m.value
            else:
                rec["buckets"] = [["+Inf" if le == math.inf else le, cum]
                                  for le, cum in m.cumulative()]
                rec["sum"] = m.sum
                rec["count"] = m.count
                if m.count:
                    rec["min"] = m.min
                    rec["max"] = m.max
                    rec["p50"] = m.percentile(50)
                    rec["p90"] = m.percentile(90)
                    rec["p99"] = m.percentile(99)
            out.append(rec)
        return out

    def jsonl_text(self) -> str:
        return "".join(json.dumps(r, sort_keys=True) + "\n"
                       for r in self.jsonl_records())
