"""Ring-buffer span tracer with Chrome-trace-event / Perfetto JSON export.

Contract (docs/observability.md §Overhead contract):

* **Monotonic clock** — timestamps come from ``time.perf_counter_ns``
  (never wall-clock), taken once on span entry and once on exit. All
  exported timestamps are microseconds relative to the tracer's birth.
* **Bounded memory** — events land in a fixed-capacity ring buffer; once
  full, the oldest event is overwritten and ``dropped`` counts how many
  were lost (the export records the drop count, so a truncated trace can
  never silently masquerade as a complete one).
* **Thread-safe** — the ring push takes a lock; spans themselves carry no
  shared state, so concurrently open spans from different threads are
  fine. The exported events carry the OS thread id, so Perfetto renders
  one track per thread.
* **Disabled = no-op** — a disabled tracer's ``span()`` returns a single
  module-level ``_NULL_SPAN`` object (no allocation, no clock read, no
  lock) and ``instant()`` returns immediately. The decode hot path can
  therefore keep its instrumentation calls unconditionally; with
  telemetry off they cost one attribute load and one branch
  (negative-tested in tests/test_telemetry.py).

Export is the Chrome trace-event JSON array format (``{"traceEvents":
[...]}``) that both ``chrome://tracing`` and https://ui.perfetto.dev load
directly: ``"X"`` (complete) events for spans, ``"i"`` (instant) events
for point markers, ``"M"`` metadata records for track names.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Callable, Dict, List, Optional

HOST_PID = 0            # pid of the host-side scheduler/engine/trainer track


class _NullSpan:
    """The disabled-tracer span: a process-wide singleton whose context
    protocol does nothing. `annotate` swallows late args the same way."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def annotate(self, **args):
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """An open span: records [enter, exit) as one complete event."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = self._tracer._now_us()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer._now_us()
        self._tracer._push(("X", self._name, self._cat, self._t0,
                            t1 - self._t0, threading.get_ident(),
                            self._args or None))
        return False

    def annotate(self, **args):
        """Attach (or override) args after entry — e.g. a row count only
        known once the work inside the span finished."""
        if self._args is None:
            self._args = {}
        self._args.update(args)
        return self


class Tracer:
    """Low-overhead span/instant recorder. See the module docstring for
    the clock/memory/threading/disabled contract."""

    def __init__(self, enabled: bool = True, capacity: int = 1 << 16,
                 clock: Callable[[], int] = time.perf_counter_ns):
        self.enabled = enabled
        self.capacity = int(capacity)
        self.dropped = 0
        self._clock = clock
        self._lock = threading.Lock()
        self._events: List[tuple] = []
        self._next = 0                      # overwrite cursor once full
        self._t0_ns = clock() if enabled else 0

    # -- recording ---------------------------------------------------------

    def _now_us(self) -> float:
        return (self._clock() - self._t0_ns) / 1e3

    def _push(self, ev: tuple) -> None:
        with self._lock:
            if len(self._events) < self.capacity:
                self._events.append(ev)
            else:
                self._events[self._next] = ev
                self._next = (self._next + 1) % self.capacity
                self.dropped += 1

    def span(self, name: str, cat: str = "span", **args):
        """Context manager timing a host-side region. Disabled tracers
        return the no-op singleton — zero allocation on the hot path."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args or None)

    def instant(self, name: str, cat: str = "event", **args) -> None:
        """A point-in-time marker (rendered as an arrow/flag in Perfetto)."""
        if not self.enabled:
            return
        self._push(("i", name, cat, self._now_us(), 0,
                    threading.get_ident(), args or None))

    # -- export ------------------------------------------------------------

    def events(self) -> List[tuple]:
        """Recorded events, oldest first (unwrapping the ring)."""
        with self._lock:
            if len(self._events) < self.capacity:
                return list(self._events)
            return self._events[self._next:] + self._events[:self._next]

    def chrome_events(self) -> List[Dict]:
        """Events as Chrome trace-event dicts (host pid, per-thread tids)."""
        out = []
        for ph, name, cat, ts, dur, tid, args in self.events():
            ev = {"ph": ph, "name": name, "cat": cat, "ts": round(ts, 3),
                  "pid": HOST_PID, "tid": tid}
            if ph == "X":
                ev["dur"] = round(dur, 3)
            if ph == "i":
                ev["s"] = "t"               # thread-scoped instant
            if args:
                ev["args"] = args
            out.append(ev)
        return out


def write_chrome_trace(path: str, events: List[Dict],
                       metadata: Optional[Dict] = None) -> str:
    """Write a Chrome-trace/Perfetto JSON object file. `events` are
    trace-event dicts (from `Tracer.chrome_events` plus any synthesized
    track events); `metadata` lands under the top-level "metadata" key."""
    payload = {
        "traceEvents": sorted(events, key=lambda e: e.get("ts", 0.0)),
        "displayTimeUnit": "ms",
    }
    if metadata:
        payload["metadata"] = metadata
    with open(path, "w") as f:
        json.dump(payload, f)
        f.write("\n")
    return path
