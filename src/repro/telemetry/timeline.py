"""Per-request serving lifecycle timelines.

Every request admitted to the continuous-batching scheduler moves through
a small state machine (docs/serving.md):

    queued -> admitted -> prefilling (per chunk) -> decoding (per chunk)
           -> preempted/snapshotted -> requeued -> ... -> retired
           |  shed (queue_full | deadline_infeasible | retries_exhausted)
           |  quarantined (fault)

`ServingTimelines.stamp()` records each transition **at the existing
per-chunk host sync** — the scheduler already returns to Python between
decode chunks, so stamping there adds zero device syncs (negative-tested
in tests/test_telemetry.py by comparing chunk counts with telemetry on
and off).

From the raw stamps, `finalize()` derives the serving SLO histograms —
queue wait, TTFT (time to first token), TPOT (time per output token),
deadline slack — each labelled by priority class, plus
deadline-miss-attribution counters, and writes them into a
`MetricsRegistry`.

`trace_events()` synthesizes one Perfetto track *per request* (a distinct
tid under a per-run pid), with phase bars (queued / prefilling /
decoding / requeued) and instant markers for point events (snapshot,
shed, deadline_miss, ...), so a request's whole life is one horizontal
lane in the UI.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .metrics import MetricsRegistry, TICK_BUCKETS, MS_BUCKETS

# Events that OPEN a phase bar (value = bar name), and events that CLOSE
# whatever bar is open. Everything stamped also gets an instant marker.
_PHASE_STARTS = {
    "queued": "queued",
    "admitted": "prefilling",
    "restored": "decoding",
    "first_token": "decoding",
    "preempted": "requeued",
}
_PHASE_ENDS = frozenset({"retired", "shed", "quarantined"})


class NullTimelines:
    """Disabled-telemetry stand-in: `stamp` is a no-op, `finalize` too.
    Shares the scheduler-facing surface so call sites stay unconditional."""

    __slots__ = ()
    enabled = False

    def stamp(self, rid, event, tick, **fields):
        pass

    def finalize(self, registry=None):
        pass


NULL_TIMELINES = NullTimelines()


class ServingTimelines:
    """Raw per-request stamp log + derived SLO metrics + Perfetto tracks.

    One instance covers one scheduler run; the `Telemetry` facade hands a
    fresh one to each `Scheduler` (warm benchmark reruns reuse request
    ids, so runs must not share a timeline namespace).
    """

    enabled = True

    def __init__(self, tracer=None):
        self._tracer = tracer
        # rid -> [(event, tick, t_us, fields)]
        self._stamps: Dict[int, List[Tuple[str, int, Optional[float], Dict]]] = {}

    # -- recording ---------------------------------------------------------

    def stamp(self, rid: int, event: str, tick: int, **fields) -> None:
        t_us = None
        if self._tracer is not None and self._tracer.enabled:
            t_us = self._tracer._now_us()
            self._tracer.instant(f"request_{event}", cat="request",
                                 rid=rid, tick=tick, **fields)
        self._stamps.setdefault(rid, []).append((event, tick, t_us, fields))

    def stamps(self, rid: int) -> List[Tuple[str, int, Optional[float], Dict]]:
        return list(self._stamps.get(rid, ()))

    def rids(self) -> List[int]:
        return sorted(self._stamps)

    # -- derived metrics ---------------------------------------------------

    def _first(self, rid: int, event: str):
        for s in self._stamps.get(rid, ()):
            if s[0] == event:
                return s
        return None

    def _last(self, rid: int, event: str):
        hit = None
        for s in self._stamps.get(rid, ()):
            if s[0] == event:
                hit = s
        return hit

    def finalize(self, registry: MetricsRegistry) -> None:
        """Fold raw stamps into per-priority SLO histograms and counters."""
        for rid in self.rids():
            queued = self._first(rid, "queued")
            if queued is None:
                continue
            pri = str(queued[3].get("priority", 0))
            deadline = queued[3].get("deadline")

            admitted = self._first(rid, "admitted")
            if admitted is not None:
                registry.histogram("serving_queue_wait_ticks",
                                   buckets=TICK_BUCKETS, priority=pri) \
                        .observe(admitted[1] - queued[1])

            first_tok = self._first(rid, "first_token")
            if first_tok is not None:
                registry.histogram("serving_ttft_ticks",
                                   buckets=TICK_BUCKETS, priority=pri) \
                        .observe(first_tok[1] - queued[1])
                if first_tok[2] is not None and queued[2] is not None:
                    registry.histogram("serving_ttft_ms",
                                       buckets=MS_BUCKETS, priority=pri) \
                            .observe((first_tok[2] - queued[2]) / 1e3)

            retired = self._last(rid, "retired")
            if retired is not None:
                n_tok = int(retired[3].get("n_tokens", 0))
                if (first_tok is not None and n_tok > 1
                        and retired[2] is not None
                        and first_tok[2] is not None):
                    tpot = (retired[2] - first_tok[2]) / 1e3 / (n_tok - 1)
                    registry.histogram("serving_tpot_ms",
                                       buckets=MS_BUCKETS, priority=pri) \
                            .observe(tpot)
                if deadline is not None:
                    slack = deadline - retired[1]
                    registry.histogram("serving_deadline_slack_ticks",
                                       buckets=TICK_BUCKETS, priority=pri) \
                            .observe(max(slack, 0))
                    if slack < 0:
                        registry.counter("serving_deadline_miss_total",
                                         priority=pri).inc()

            for ev, _tick, _t, fields in self._stamps[rid]:
                if ev == "shed":
                    registry.counter("serving_shed_events_total",
                                     reason=str(fields.get("reason", "?")),
                                     priority=pri).inc()
                elif ev == "preempted":
                    registry.counter("serving_preempted_events_total",
                                     priority=pri).inc()
                elif ev == "quarantined":
                    registry.counter("serving_quarantined_events_total",
                                     priority=pri).inc()

    # -- Perfetto tracks ---------------------------------------------------

    def trace_events(self, pid: int = 100, run_label: str = "serving") -> List[Dict]:
        """One lane per request: phase bars + instant markers. Requires the
        tracer to have been enabled during the run (stamps carry t_us)."""
        out: List[Dict] = []
        out.append({"ph": "M", "name": "process_name", "pid": pid,
                    "args": {"name": f"{run_label} requests"}})
        for rid in self.rids():
            stamps = [s for s in self._stamps[rid] if s[2] is not None]
            if not stamps:
                continue
            queued = self._first(rid, "queued")
            pri = queued[3].get("priority", 0) if queued else 0
            tid = rid
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid,
                        "args": {"name": f"req {rid} (pri {pri})"}})
            open_phase: Optional[Tuple[str, float]] = None
            for ev, tick, t_us, fields in stamps:
                start = _PHASE_STARTS.get(ev)
                if start is not None or ev in _PHASE_ENDS:
                    if open_phase is not None:
                        name, t0 = open_phase
                        out.append({"ph": "X", "name": name, "cat": "request",
                                    "ts": round(t0, 3),
                                    "dur": round(max(t_us - t0, 0.0), 3),
                                    "pid": pid, "tid": tid})
                        open_phase = None
                    if start is not None:
                        open_phase = (start, t_us)
                args = {"rid": rid, "tick": tick}
                args.update(fields)
                out.append({"ph": "i", "name": ev, "cat": "request",
                            "ts": round(t_us, 3), "pid": pid, "tid": tid,
                            "s": "t", "args": args})
            if open_phase is not None:
                name, t0 = open_phase
                last_t = stamps[-1][2]
                out.append({"ph": "X", "name": name, "cat": "request",
                            "ts": round(t0, 3),
                            "dur": round(max(last_t - t0, 0.0), 3),
                            "pid": pid, "tid": tid})
        return out
