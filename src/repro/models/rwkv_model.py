"""RWKV6 full model (attention-free SSM family)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import rwkv6 as r6
from repro.models.transformer import _dtype, logits_from_hidden, remat_wrap
from repro.parallel.sharding import ParallelCtx, shard_activation


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 4)
    params: Dict = {
        "embed": {"tok": L.init_embedding(ks[0], cfg.padded_vocab_size, cfg.d_model,
                                          dt)},
    }

    def layer(r):
        return {"ln1": L.init_rmsnorm(cfg.d_model, dt),
                "ln2": L.init_rmsnorm(cfg.d_model, dt),
                "rwkv": r6.init_rwkv6(r, cfg.d_model, cfg.mlp.d_ff, cfg.rwkv,
                                      dt)}

    params["layers"] = jax.vmap(layer)(jax.random.split(ks[1], cfg.num_layers))
    params["final_norm"] = L.init_rmsnorm(cfg.d_model, dt)
    params["lm_head"] = L.dense_init(ks[2], (cfg.d_model, cfg.padded_vocab_size), dt)
    return params


def forward(
    params: Dict, cfg: ModelConfig, batch: Dict, *,
    ctx: Optional[ParallelCtx] = None,
    return_cache: bool = False,
    cache_max_seq: Optional[int] = None,
    cache_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    x = L.embed_tokens(params["embed"]["tok"], batch["tokens"])
    x = shard_activation(x, ctx)
    B, S, _ = x.shape
    P_ = cfg.rwkv.head_dim
    H = cfg.d_model // P_
    zero_shift = jnp.zeros((B, cfg.d_model), x.dtype)
    zero_wkv = jnp.zeros((B, H, P_, P_), jnp.float32)

    def body(carry, lp):
        h = carry
        tm, tm_shift, wkv = r6.time_mix(lp["rwkv"],
                                        L.rms_norm(lp["ln1"], h), cfg.rwkv,
                                        zero_shift, zero_wkv)
        h = h + tm
        cm, cm_shift = r6.channel_mix(lp["rwkv"], L.rms_norm(lp["ln2"], h),
                                      zero_shift)
        h = shard_activation(h + cm, ctx)
        return h, (tm_shift, cm_shift, wkv)

    body = remat_wrap(body, cfg.remat)
    x, states = jax.lax.scan(body, x, params["layers"])
    logits = logits_from_hidden(params, cfg, x, ctx)

    cache = None
    if return_cache:
        tm_shift, cm_shift, wkv = states
        cache = {"wkv": wkv, "tm_shift": tm_shift, "cm_shift": cm_shift,
                 "length": jnp.asarray(S, jnp.int32)}
    return logits, jnp.zeros((), jnp.float32), cache


def init_cache(cfg: ModelConfig, *, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict:
    P_ = cfg.rwkv.head_dim
    H = cfg.d_model // P_
    return {
        "wkv": jnp.zeros((cfg.num_layers, batch, H, P_, P_), jnp.float32),
        "tm_shift": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dtype),
        "cm_shift": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def decode_step(
    params: Dict, cfg: ModelConfig, batch_t: Dict, cache: Dict, *,
    ctx: Optional[ParallelCtx] = None,
) -> Tuple[jax.Array, Dict]:
    x = L.embed_tokens(params["embed"]["tok"], batch_t["tokens"])

    def body(h, inp):
        lp, wkv, tms, cms = inp
        tm_out, st = r6.step_time_mix(
            lp["rwkv"], L.rms_norm(lp["ln1"], h), cfg.rwkv,
            {"wkv": wkv, "tm_shift": tms})
        h = h + tm_out
        normed = L.rms_norm(lp["ln2"], h)
        cm_out, new_cms = r6.channel_mix(lp["rwkv"], normed,
                                         cms)
        h = h + cm_out
        return h, (st["wkv"], st["tm_shift"], new_cms)

    x, (wkv, tms, cms) = jax.lax.scan(
        body, x, (params["layers"], cache["wkv"], cache["tm_shift"],
                  cache["cm_shift"]))
    logits = logits_from_hidden(params, cfg, x, ctx)
    return logits, {"wkv": wkv, "tm_shift": tms, "cm_shift": cms,
                    "length": cache["length"] + 1}
