"""Mamba2 (SSD — state-space duality) block, for the zamba2 hybrid trunk.

Per head h with head dim P and state dim N, the recurrence is

    h_t = a_t · h_{t-1} + dt_t · (B_t ⊗ x_t)        h ∈ R^{N×P}
    y_t = C_t · h_t + D_skip · x_t

with scalar per-head decay a_t = exp(-exp(A_log) · dt_t), dt_t = softplus(·).

Training uses the chunked (block-parallel) SSD algorithm: exact intra-chunk
attention-like computation + a lax.scan over chunk states. All decay factors
are computed as exp of *differences* of cumulative logs (always ≤ 0), so the
chunked form is numerically safe in fp32. A step function serves decode and
the reference scan (tests assert chunked == scan).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models import layers as L


def dims(d_model: int, cfg: SSMConfig) -> Tuple[int, int, int]:
    d_inner = cfg.expand * d_model
    P_ = cfg.head_dim
    H = cfg.num_heads or d_inner // P_
    assert H * P_ == d_inner
    return d_inner, H, P_


def init_mamba2(rng: jax.Array, d_model: int, cfg: SSMConfig, dtype) -> Dict:
    d_inner, H, P_ = dims(d_model, cfg)
    N = cfg.state_dim
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(rng, 5)
    # in_proj -> [z (d_inner) | x (d_inner) | B (N) | C (N) | dt (H)]
    return {
        "w_in": L.dense_init(ks[0], (d_model, 2 * d_inner + 2 * N + H), dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),           # a = exp(-exp(A_log)·dt)
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -1.0, jnp.float32),
        "norm": L.init_rmsnorm(d_inner, dtype),
        "w_out": L.dense_init(ks[2], (d_inner, d_model), dtype),
    }


def _split_proj(params, x, cfg: SSMConfig, d_model: int):
    d_inner, H, P_ = dims(d_model, cfg)
    N = cfg.state_dim
    zxbcdt = x @ params["w_in"]
    z = zxbcdt[..., :d_inner]
    xr = zxbcdt[..., d_inner:2 * d_inner]
    Bm = zxbcdt[..., 2 * d_inner:2 * d_inner + N]
    Cm = zxbcdt[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, xr, Bm, Cm, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv along S. xBC: (B,S,C); w: (W,C). If `state`
    (B, W-1, C) is given, it supplies the left context (decode)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(xBC[:, :W - 1])
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _discretize(params, dt):
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    log_a = -jnp.exp(params["A_log"]) * dt                            # ≤ 0
    return dt, log_a


def apply_mamba2(params: Dict, x: jax.Array, cfg: SSMConfig,
                 return_state: bool = False):
    """Training/prefill forward, chunked SSD. x: (B,S,D) -> (B,S,D).

    With return_state=True also returns the recurrent state after the last
    token ({ssm, conv}) — FREE from the chunk scan (no sequential replay);
    this is how prefill materializes the decode state in O(S/chunk) steps.
    """
    Bsz, S, D = x.shape
    d_inner, H, P_ = dims(D, cfg)
    N = cfg.state_dim
    Lc = cfg.chunk_size if (S % cfg.chunk_size == 0 and S >= cfg.chunk_size) \
        else S
    nc = S // Lc

    z, xr, Bm, Cm, dt = _split_proj(params, x, cfg, D)
    xBC_raw = jnp.concatenate([xr, Bm, Cm], -1)
    xBC = _causal_conv(xBC_raw, params["conv_w"], params["conv_b"])
    xr, Bm, Cm = xBC[..., :d_inner], xBC[..., d_inner:d_inner + N], \
        xBC[..., d_inner + N:]
    dt, log_a = _discretize(params, dt)                   # (B,S,H) fp32

    xh = xr.reshape(Bsz, nc, Lc, H, P_).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Lc, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Lc, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Lc, H)
    la = log_a.reshape(Bsz, nc, Lc, H)
    cum = jnp.cumsum(la, axis=2)                          # (B,nc,Lc,H) inclusive

    # intra-chunk: y[t] += sum_{s<=t} C_t·B_s · exp(cum[t]-cum[s]) · dt_s · x_s
    G = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)             # (B,nc,t,s)
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,t,s,H)
    mask = jnp.tril(jnp.ones((Lc, Lc), bool))
    dec = jnp.where(mask[None, None, :, :, None], dec, -jnp.inf)
    W = G[..., None] * jnp.exp(dec) * dtc[:, :, None, :, :]  # (B,nc,t,s,H)
    y = jnp.einsum("bctsh,bcshp->bcthp", W, xh)

    # chunk states: S_c = sum_s exp(cum[end]-cum[s]) dt_s B_s ⊗ x_s
    dec_end = cum[:, :, -1:, :] - cum                      # (B,nc,Lc,H) ≤ 0
    contrib = jnp.exp(dec_end) * dtc                       # (B,nc,Lc,H)
    S_c = jnp.einsum("bcsh,bcsn,bcshp->bchnp", contrib, Bc, xh)  # (B,nc,H,N,P)
    a_chunk = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H)

    def scan_fn(h, inp):
        s_c, a_c = inp                                     # (B,H,N,P),(B,H)
        h_new = h * a_c[..., None, None] + s_c
        return h_new, h                                    # emit state BEFORE chunk

    h0 = jnp.zeros((Bsz, H, N, P_), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(a_chunk, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                    # (B,nc,H,N,P)

    # inter-chunk: y[t] += exp(cum[t]) · C_t · h_prev
    y = y + jnp.einsum("bcth,bctn,bchnp->bcthp", jnp.exp(cum), Cc, h_prev)

    y = y + params["D_skip"][None, None, None, :, None] * xh
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = L.rms_norm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["w_out"]
    if return_state:
        W = params["conv_w"].shape[0]
        tail = xBC_raw[:, max(S - (W - 1), 0):, :]
        if tail.shape[1] < W - 1:                    # S < conv context
            tail = jnp.pad(tail, ((0, 0), (W - 1 - tail.shape[1], 0), (0, 0)))
        state = {"ssm": h_last, "conv": tail}
        return out, state
    return out


# ---------------------------------------------------------------------------
# Recurrent reference / decode
# ---------------------------------------------------------------------------


def init_mamba2_state(batch: int, d_model: int, cfg: SSMConfig,
                      dtype=jnp.float32) -> Dict:
    d_inner, H, P_ = dims(d_model, cfg)
    N = cfg.state_dim
    conv_ch = d_inner + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, N, P_), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def step_mamba2(params: Dict, x_t: jax.Array, state: Dict,
                cfg: SSMConfig) -> Tuple[jax.Array, Dict]:
    """One-token step. x_t: (B,1,D)."""
    Bsz, _, D = x_t.shape
    d_inner, H, P_ = dims(D, cfg)
    N = cfg.state_dim
    z, xr, Bm, Cm, dt = _split_proj(params, x_t, cfg, D)
    xBC = jnp.concatenate([xr, Bm, Cm], -1)                # (B,1,C)
    conv_in = jnp.concatenate([state["conv"], xBC], axis=1)
    out = sum(conv_in[:, i:i + 1] * params["conv_w"][i]
              for i in range(cfg.conv_width))
    xBC_c = jax.nn.silu(out + params["conv_b"])            # (B,1,C)
    new_conv = conv_in[:, 1:]
    xr = xBC_c[..., :d_inner]
    Bm = xBC_c[..., d_inner:d_inner + N]
    Cm = xBC_c[..., d_inner + N:]
    dt, log_a = _discretize(params, dt)                    # (B,1,H)

    xh = xr.reshape(Bsz, H, P_).astype(jnp.float32)
    Bv = Bm.reshape(Bsz, N).astype(jnp.float32)
    Cv = Cm.reshape(Bsz, N).astype(jnp.float32)
    a = jnp.exp(log_a)[:, 0, :]                            # (B,H)
    dtv = dt[:, 0, :]                                      # (B,H)
    h = state["ssm"] * a[..., None, None] + \
        jnp.einsum("bh,bn,bhp->bhnp", dtv, Bv, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cv, h) + \
        params["D_skip"][None, :, None] * xh
    y = y.reshape(Bsz, 1, d_inner).astype(x_t.dtype)
    y = L.rms_norm(params["norm"], y * jax.nn.silu(z))
    return y @ params["w_out"], {"ssm": h, "conv": new_conv}


def apply_mamba2_scan(params: Dict, x: jax.Array, cfg: SSMConfig) -> jax.Array:
    """Step-by-step reference (oracle for chunked-vs-scan tests)."""
    Bsz, S, D = x.shape
    state = init_mamba2_state(Bsz, D, cfg, x.dtype)

    def body(st, xt):
        y, st = step_mamba2(params, xt[:, None], st, cfg)
        return st, y[:, 0]

    _, ys = jax.lax.scan(body, state, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(ys, 0, 1)
