"""Unified model API: dispatches on `ModelConfig.family` and provides the
loss used by the trainer (causal LM or MLM), plus cache helpers for serving.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rwkv_model, transformer, zamba
from repro.parallel.sharding import ParallelCtx

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm", "audio")


def _impl(cfg: ModelConfig):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer
    if cfg.family == "hybrid":
        return zamba
    if cfg.family == "ssm":
        return rwkv_model
    raise ValueError(f"unknown family {cfg.family!r}")


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    return _impl(cfg).init_params(rng, cfg)


def forward(params, cfg: ModelConfig, batch: Dict, *,
            ctx: Optional[ParallelCtx] = None, **kw):
    return _impl(cfg).forward(params, cfg, batch, ctx=ctx, **kw)


def init_cache(cfg: ModelConfig, *, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict:
    return _impl(cfg).init_cache(cfg, batch=batch, max_seq=max_seq,
                                 dtype=dtype)


def decode_step(params, cfg: ModelConfig, batch_t: Dict, cache: Dict, *,
                ctx: Optional[ParallelCtx] = None):
    return _impl(cfg).decode_step(params, cfg, batch_t, cache, ctx=ctx)


def prefill_chunk(params, cfg: ModelConfig, batch_c: Dict, cache: Dict,
                  n_valid, *, ctx: Optional[ParallelCtx] = None):
    """Prefill-at-offset forward of one fixed-size chunk per row (serving's
    chunked-admission path). Transformer families only: ssm/hybrid caches
    have no per-row positions to chunk against."""
    impl = _impl(cfg)
    if not hasattr(impl, "prefill_chunk"):
        raise ValueError(
            f"family {cfg.family!r} has no chunked-prefill path")
    return impl.prefill_chunk(params, cfg, batch_c, cache, n_valid, ctx=ctx)


def decode_scan(
    params,
    cfg: ModelConfig,
    cur: jax.Array,        # (B,) int32 — first un-emitted sampled token
    finished: jax.Array,   # (B,) bool — rows whose output is frozen to eos
    cache: Dict,
    rng: jax.Array,
    *,
    n_steps: int,
    eos_id: int,
    temperature: float = 0.0,
    ctx: Optional[ParallelCtx] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, Dict, jax.Array]:
    """Device-resident multi-token decode: a lax.scan over `n_steps` steps
    with on-device sampling (argmax / categorical) and on-device EOS
    masking. No host round-trips inside — the caller syncs ONCE per chunk
    on the returned tokens (the serving engine's chunked decode contract).

    Each step emits `cur` (frozen to eos_id for finished rows), feeds the
    emitted token back through `decode_step`, and samples the next token.
    Finished rows also freeze their per-row position counter
    (cache["lengths"]), so an idle slot of a continuous-batching pool never
    advances past the cache capacity no matter how long it sits empty.

    The carry also accumulates a per-row `bad` flag: any step whose logits
    for a still-live row go non-finite latches the flag. It rides the
    chunk's single host sync, so NaN/Inf detection costs nothing extra —
    the serving scheduler quarantines flagged rows instead of streaming
    garbage tokens.
    Returns (tokens (B, n_steps), next cur, finished, bad, cache, rng).
    """

    def sample(logits, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / temperature, axis=-1)

    def step(carry, _):
        cur, finished, bad, cache, rng = carry
        tok = jnp.where(finished, eos_id, cur)
        finished = finished | (tok == eos_id)
        rng, sub = jax.random.split(rng)
        prev_lengths = cache.get("lengths")
        logits, cache = decode_step(
            params, cfg, {"tokens": tok[:, None].astype(jnp.int32)}, cache,
            ctx=ctx)
        if prev_lengths is not None:    # ssm/hybrid caches keep a scalar
            cache["lengths"] = jnp.where(finished, prev_lengths,
                                         cache["lengths"])
        bad = bad | (~jnp.isfinite(logits[:, 0]).all(axis=-1) & ~finished)
        nxt = sample(logits[:, 0], sub)
        return (nxt, finished, bad, cache, rng), tok

    bad0 = jnp.zeros(cur.shape, bool)
    (cur, finished, bad, cache, rng), toks = jax.lax.scan(
        step, (cur, finished, bad0, cache, rng), None, length=n_steps)
    return jnp.moveaxis(toks, 0, 1), cur, finished, bad, cache, rng


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Stable CE in fp32. labels: (B,S) int; mask: (B,S) {0,1} loss weights.
    Returns (sum_loss, sum_weight)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum(), mask.sum()


def chunked_head_ce(params, cfg: ModelConfig, hidden: jax.Array,
                    labels: jax.Array, mask: jax.Array, *,
                    ctx: Optional[ParallelCtx],
                    chunk: int) -> Tuple[jax.Array, jax.Array]:
    """LM-head matmul + CE over sequence chunks: the (B, S, V) logits tensor
    is never materialized; backward recomputes each chunk (checkpoint).
    §Perf iteration qwen1.5-110b/train_4k."""
    from repro.models.transformer import logits_from_hidden
    B, S, D = hidden.shape
    if S % chunk != 0:
        chunk = S
    n = S // chunk
    hs = jnp.moveaxis(hidden.reshape(B, n, chunk, D), 1, 0)
    ys = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        h_c, y_c, m_c = inp
        logits = logits_from_hidden(params, cfg, h_c, ctx)
        nll, den = cross_entropy(logits, y_c, m_c)
        return (carry[0] + nll, carry[1] + den), None

    (nll, den), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ys, ms))
    return nll, den


def loss_fn(params, cfg: ModelConfig, batch: Dict, *,
            ctx: Optional[ParallelCtx] = None
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: tokens/embeds (+frontend_embeds), labels (B,S), loss_mask (B,S).

    Causal LM: labels are inputs shifted by one (built by the data pipeline).
    MLM: labels hold original ids at masked positions, loss_mask marks them.
    """
    labels = batch["labels"]
    mask = batch["loss_mask"].astype(jnp.float32)
    use_chunked = (cfg.chunked_ce > 0
                   and cfg.family in _TRANSFORMER_FAMILIES)
    if use_chunked:
        hidden, aux, _ = forward(params, cfg, batch, ctx=ctx,
                                 return_hidden=True)
        if cfg.frontend_embed_len > 0:
            hidden = hidden[:, cfg.frontend_embed_len:]
        nll_sum, denom = chunked_head_ce(params, cfg, hidden, labels, mask,
                                         ctx=ctx, chunk=cfg.chunked_ce)
    else:
        logits, aux, _ = forward(params, cfg, batch, ctx=ctx)
        if cfg.frontend_embed_len > 0:
            # logits cover [frontend | text]; loss only on the text positions
            logits = logits[:, cfg.frontend_embed_len:]
        nll_sum, denom = cross_entropy(logits, labels, mask)
    loss = nll_sum / jnp.maximum(denom, 1.0)
    total = loss
    if cfg.moe.num_experts > 0:
        total = total + cfg.moe.aux_loss_weight * aux
    metrics = {"loss": loss, "aux_loss": aux, "tokens": denom,
               "perplexity": jnp.exp(jnp.minimum(loss, 20.0))}
    return total, metrics


def make_train_batch_shapes(cfg: ModelConfig, *, batch: int, seq: int
                            ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one training batch of this architecture —
    the single source of truth used by input_specs() in the dry-run."""
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    shapes: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.embedding_inputs:
        shapes["embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), f)
        shapes["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
        shapes["loss_mask"] = jax.ShapeDtypeStruct((batch, seq), i32)
        return shapes
    text = seq - cfg.frontend_embed_len
    shapes["tokens"] = jax.ShapeDtypeStruct((batch, text), i32)
    if cfg.frontend_embed_len > 0:
        shapes["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_embed_len, cfg.d_model), f)
    shapes["labels"] = jax.ShapeDtypeStruct((batch, text), i32)
    shapes["loss_mask"] = jax.ShapeDtypeStruct((batch, text), i32)
    return shapes
