"""Shared neural-net layers (pure JAX, no flax): norms, rotary embeddings,
MLP variants, embedding tables, init helpers.

Convention: every module is a pair of pure functions
  ``init_*(rng, ...) -> params``  /  ``apply(params, x, ...) -> y``
with params as nested dicts of arrays.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLPConfig


def dense_init(rng: jax.Array, shape, dtype, scale: Optional[float] = None):
    """Fan-in scaled normal init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(rng, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rms_norm(params: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (NeoX half-rotation style)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    B, S, H, Dh = x.shape
    freqs = rope_frequencies(Dh, theta)                  # (Dh/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, Dh/2)
        ang = ang[None, :, None, :]                      # (1,S,1,Dh/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs          # (B,S,Dh/2)
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense feed-forward) variants
# ---------------------------------------------------------------------------


def init_mlp(rng: jax.Array, d_model: int, cfg: MLPConfig, dtype) -> Dict:
    ks = jax.random.split(rng, 3)
    p = {
        "w_in": dense_init(ks[0], (d_model, cfg.d_ff), dtype),
        "w_out": dense_init(ks[1], (cfg.d_ff, d_model), dtype),
    }
    if cfg.activation == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d_model, cfg.d_ff), dtype)
    return p


def apply_mlp(params: Dict, x: jax.Array, cfg: MLPConfig) -> jax.Array:
    h = x @ params["w_in"]
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * h
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown activation {cfg.activation!r}")
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(rng: jax.Array, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, d_model)) * 0.02).astype(dtype)


def embed_tokens(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def init_learned_positions(rng: jax.Array, max_seq: int, d_model: int,
                           dtype) -> jax.Array:
    return (jax.random.normal(rng, (max_seq, d_model)) * 0.02).astype(dtype)
