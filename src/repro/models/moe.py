"""Mixture-of-Experts feed-forward with capacity-based dispatch.

Expert parallelism: experts are sharded over the "model" mesh axis. Because
activations between blocks are replicated across the model axis (TP layout),
each model-column device routes its local batch against only its *local*
experts and a single psum over "model" combines expert outputs — no explicit
all-to-all is needed; communication is one (tokens × d_model) all-reduce,
identical in shape to a TP FFN reduction.

The same `_moe_local` math runs unsharded (all experts local) for smoke tests
and single-device runs.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MLPConfig, MoEConfig
from repro.models import layers as L
from repro.parallel.sharding import ParallelCtx, shard_map as _shard_map



def init_moe(rng: jax.Array, d_model: int, cfg: MoEConfig, mlp: MLPConfig,
             dtype) -> Dict:
    ks = jax.random.split(rng, 4)
    E, ff = cfg.num_experts, cfg.expert_d_ff
    p = {
        "router": L.dense_init(ks[0], (d_model, E), jnp.float32),
        "w_in": L.dense_init(ks[1], (E, d_model, ff), dtype),
        "w_out": L.dense_init(ks[2], (E, ff, d_model), dtype),
    }
    if mlp.activation == "swiglu":
        p["w_gate"] = L.dense_init(ks[3], (E, d_model, ff), dtype)
    return p


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    floor = 1 if cfg.capacity_floor_one else cfg.top_k
    return max(floor, c)


def _expert_ffn(w_in, w_gate, w_out, x, activation: str):
    """x: (E_loc, C, D) -> (E_loc, C, D)."""
    h = jnp.einsum("ecd,edf->ecf", x, w_in)
    if activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w_gate)) * h
    elif activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _moe_local(
    router: jax.Array,       # (D, E_total) fp32
    w_in: jax.Array,         # (E_loc, D, ff)
    w_gate: Optional[jax.Array],
    w_out: jax.Array,        # (E_loc, ff, D)
    x: jax.Array,            # (T, D) local tokens
    *,
    cfg: MoEConfig,
    activation: str,
    e_offset: int,           # global index of first local expert
) -> Tuple[jax.Array, jax.Array]:
    """Route local tokens to local experts. Returns (out (T,D), aux-loss)."""
    T, D = x.shape
    E_total = router.shape[1]
    E_loc = w_in.shape[0]
    C = _capacity(T, cfg)

    logits = (x.astype(jnp.float32) @ router)             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.top_k)        # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e — computed over the
    # full expert set from local tokens; psum-averaging happens via grad sync.
    me = probs.mean(0)                                     # (E,)
    ce = jnp.zeros((E_total,), jnp.float32).at[top_i.reshape(-1)].add(
        jnp.ones((T * cfg.top_k,), jnp.float32)) / (T * cfg.top_k)
    aux = E_total * jnp.sum(me * ce)

    def one_expert(e_local):
        e = e_local + e_offset
        match = (top_i == e)                               # (T, K)
        w_tok = (top_w * match).sum(-1)                    # (T,)
        m_tok = match.any(-1)
        pos = jnp.cumsum(m_tok) - 1                        # position in expert
        keep = m_tok & (pos < C)
        posc = jnp.where(keep, pos, C)                     # C = overflow slot
        buf = jnp.zeros((C + 1, D), x.dtype).at[posc].add(
            jnp.where(keep[:, None], x, 0))
        return buf[:C], (posc, keep, w_tok)

    buf, (posc, keep, w_tok) = jax.vmap(one_expert)(jnp.arange(E_loc))
    y = _expert_ffn(w_in, w_gate, w_out, buf, activation)  # (E_loc, C, D)

    def gather_back(y_e, posc_e, keep_e, w_e):
        y_pad = jnp.concatenate([y_e, jnp.zeros((1, D), y_e.dtype)], 0)
        return y_pad[posc_e] * (w_e * keep_e)[:, None].astype(y_e.dtype)

    out = jax.vmap(gather_back)(y, posc, keep, w_tok).sum(0)  # (T, D)
    return out, aux


def _moe_weight_stationary(
    params: Dict, xt: jax.Array, cfg: MoEConfig, act: str,
    ctx: ParallelCtx,
) -> Tuple[jax.Array, jax.Array]:
    """Decode-time EP where TOKENS move and WEIGHTS stay put.

    Expert weights remain sharded (E over model, D over fsdp axes) — no
    per-step all-gather of the (potentially trillion-param) expert stack.
    Tokens (tiny at decode) are replicated; per-layer collectives are two
    (E_loc, C, ff) psums over the fsdp axes, one (T, D_loc) psum over model
    and a (T, D) token all-gather — bytes independent of parameter count.
    """
    mesh = ctx.mesh
    maxis = ctx.model_axis
    fsdp = ctx.fsdp_axes            # axes the weight D dim is sharded over
    T, D = xt.shape
    E_loc = cfg.num_experts // ctx.model_shards
    C = _capacity(T, cfg)
    w_gate = params.get("w_gate")

    def body(router, w_in, w_gate_, w_out, x_full):
        mi = jax.lax.axis_index(maxis)
        logits = x_full.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, -1)
        top_w, top_i = jax.lax.top_k(probs, cfg.top_k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        D_loc = w_in.shape[1]
        if fsdp:
            di = jax.lax.axis_index(fsdp)
            x_slice = jax.lax.dynamic_slice_in_dim(x_full, di * D_loc, D_loc,
                                                   axis=1)
        else:
            x_slice = x_full

        def one_expert(e_local):
            e = e_local + mi * E_loc
            match = (top_i == e)
            w_tok = (top_w * match).sum(-1)
            m_tok = match.any(-1)
            pos = jnp.cumsum(m_tok) - 1
            keep = m_tok & (pos < C)
            posc = jnp.where(keep, pos, C)
            buf = jnp.zeros((C + 1, D_loc), x_slice.dtype).at[posc].add(
                jnp.where(keep[:, None], x_slice, 0))
            return buf[:C], (posc, keep, w_tok)

        buf, (posc, keep, w_tok) = jax.vmap(one_expert)(jnp.arange(E_loc))
        h = jnp.einsum("ecd,edf->ecf", buf, w_in)        # partial over D_loc
        if act == "swiglu":
            g = jnp.einsum("ecd,edf->ecf", buf, w_gate_)
            if fsdp:
                h = jax.lax.psum(h, fsdp)
                g = jax.lax.psum(g, fsdp)
            h = jax.nn.silu(g) * h
        else:
            if fsdp:
                h = jax.lax.psum(h, fsdp)
            h = jnp.square(jax.nn.relu(h)) if act == "squared_relu" \
                else jax.nn.gelu(h)
        y = jnp.einsum("ecf,efd->ecd", h, w_out)         # (E_loc, C, D_loc)

        def gather_back(y_e, posc_e, keep_e, w_e):
            y_pad = jnp.concatenate([y_e, jnp.zeros((1, D_loc), y_e.dtype)],
                                    0)
            return y_pad[posc_e] * (w_e * keep_e)[:, None].astype(y_e.dtype)

        out = jax.vmap(gather_back)(y, posc, keep, w_tok).sum(0)  # (T, D_loc)
        out = jax.lax.psum(out, maxis)                   # sum expert groups
        if fsdp:
            out = jax.lax.all_gather(out, fsdp, axis=1, tiled=True)
        # aux loss (same formula as _moe_local, computed on full T)
        me = probs.mean(0)
        ce = jnp.zeros((cfg.num_experts,), jnp.float32).at[
            top_i.reshape(-1)].add(1.0) / (T * cfg.top_k)
        aux = cfg.num_experts * jnp.sum(me * ce)
        return out, aux

    fs = fsdp if fsdp else None
    out, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P(maxis, fs, None),
                  P(maxis, fs, None) if w_gate is not None else P(),
                  P(maxis, None, fs), P(None, None)),
        out_specs=(P(None, None), P()),
        check_vma=False,
    )(params["router"], params["w_in"],
      w_gate if w_gate is not None else jnp.zeros((), xt.dtype),
      params["w_out"], xt)
    return out, aux


def apply_moe(
    params: Dict,
    x: jax.Array,            # (B, S, D)
    cfg: MoEConfig,
    mlp: MLPConfig,
    ctx: Optional[ParallelCtx] = None,
) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN. Returns (out (B,S,D), aux_loss scalar)."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    w_gate = params.get("w_gate")
    act = mlp.activation

    if ctx is None or ctx.mesh is None or ctx.model_shards == 1:
        out, aux = _moe_local(params["router"], params["w_in"], w_gate,
                              params["w_out"], xt, cfg=cfg, activation=act,
                              e_offset=0)
        return out.reshape(B, S, D), aux

    if cfg.weight_stationary_decode and S == 1:
        out, aux = _moe_weight_stationary(params, xt, cfg, act, ctx)
        return out.reshape(B, S, D), aux

    mesh = ctx.mesh
    maxis = ctx.model_axis
    daxes = ctx.data_axes
    # decode at tiny batch: tokens can't shard over the data axes — keep them
    # replicated inside the shard_map instead (EP still splits the experts).
    dp_size = 1
    for a in daxes:
        dp_size *= mesh.shape[a]
    if (B * S) % dp_size != 0:
        daxes = ()
    E_loc = cfg.num_experts // ctx.model_shards
    fs = ctx.fsdp_axes or None

    def sharded(router, w_in, w_gate_, w_out, xt_):
        mi = jax.lax.axis_index(maxis)
        out, aux = _moe_local(router, w_in, w_gate_, w_out, xt_, cfg=cfg,
                              activation=act, e_offset=mi * E_loc)
        # combine expert contributions across the EP axis; average the aux
        # loss over every mesh axis so it is truly replicated.
        out = jax.lax.psum(out, maxis)
        aux = jax.lax.pmean(aux, mesh.axis_names)
        return out, aux

    # Expert weights enter replicated along data axes (in_specs trigger the
    # FSDP all-gather here when params are stored fsdp-sharded).
    gate_spec = P(maxis, None, None) if w_gate is not None else P()
    out, aux = _shard_map(
        sharded, mesh=mesh,
        in_specs=(P(None, None), P(maxis, None, None), gate_spec,
                  P(maxis, None, None), P(daxes, None)),
        out_specs=(P(daxes, None), P()),
        check_vma=False,
    )(params["router"], params["w_in"],
      w_gate if w_gate is not None else jnp.zeros((), x.dtype),
      params["w_out"], xt)
    return out.reshape(B, S, D), aux
