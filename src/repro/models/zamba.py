"""Zamba2-style hybrid: a trunk of Mamba2 blocks with ONE weight-shared
attention+MLP block invoked every `hybrid_attn_every` trunk layers.

The shared block (where Linformer applies) is stored once in
params["shared_block"]; each invocation keeps its own decode cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import linformer as lin_lib
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import mamba2 as m2
from repro.models.transformer import _dtype, remat_wrap
from repro.parallel.sharding import ParallelCtx, shard_activation


def n_attn_invocations(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.hybrid_attn_every


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 8)
    params: Dict = {
        "embed": {"tok": L.init_embedding(ks[0], cfg.padded_vocab_size, cfg.d_model,
                                          dt)},
    }

    def trunk_layer(r):
        return {"ln": L.init_rmsnorm(cfg.d_model, dt),
                "ssm": m2.init_mamba2(r, cfg.d_model, cfg.ssm, dt)}

    params["trunk"] = jax.vmap(trunk_layer)(
        jax.random.split(ks[1], cfg.num_layers))

    params["shared_block"] = {
        "ln1": L.init_rmsnorm(cfg.d_model, dt),
        "ln2": L.init_rmsnorm(cfg.d_model, dt),
        "attn": attn_lib.init_attention(ks[2], cfg.d_model, cfg.attention,
                                        max_seq=cfg.max_seq_len, dtype=dt),
        "mlp": L.init_mlp(ks[3], cfg.d_model, cfg.mlp, dt),
    }
    if cfg.attention.kind in ("linformer", "linformer_causal") \
            and cfg.attention.linformer.sharing == "layerwise":
        params["shared"] = {"lin": lin_lib.init_linformer_params(
            ks[4], cfg.attention, num_layers=1, max_seq=cfg.max_seq_len,
            dtype=dt)["shared"]}
    params["final_norm"] = L.init_rmsnorm(cfg.d_model, dt)
    params["lm_head"] = L.dense_init(ks[5], (cfg.d_model, cfg.padded_vocab_size), dt)
    return params


def _shared_block(params, cfg, x, *, shared_lin, ctx, chunked):
    sb = params["shared_block"]
    h = attn_lib.apply_attention(sb["attn"], L.rms_norm(sb["ln1"], x),
                                 cfg.attention, shared_lin=shared_lin,
                                 chunked=chunked)
    x = x + h
    x = x + L.apply_mlp(sb["mlp"], L.rms_norm(sb["ln2"], x), cfg.mlp)
    return shard_activation(x, ctx)


def _trunk_slice(params, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], params["trunk"])


def forward(
    params: Dict, cfg: ModelConfig, batch: Dict, *,
    ctx: Optional[ParallelCtx] = None,
    return_cache: bool = False,
    cache_max_seq: Optional[int] = None,
    cache_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    x = L.embed_tokens(params["embed"]["tok"], batch["tokens"])
    x = shard_activation(x, ctx)
    B, S, _ = x.shape
    chunked = S >= 8192
    shared_lin = params.get("shared", {}).get("lin")
    every = cfg.hybrid_attn_every
    n_inv = n_attn_invocations(cfg)

    from repro.models.transformer import _act_spec
    spec = _act_spec(ctx, cfg)

    def mamba_body(h, lp):
        y = m2.apply_mamba2(lp["ssm"], L.rms_norm(lp["ln"], h), cfg.ssm,
                            return_state=return_cache)
        if return_cache:
            y, st = y
            h = h + y
            return shard_activation(h, ctx, spec), (
                st["ssm"], st["conv"].astype(cache_dtype))
        return shard_activation(h + y, ctx, spec), None

    mamba_body = remat_wrap(mamba_body, cfg.remat)

    attn_entries = []
    mamba_states = []
    for g in range(n_inv):
        x, st = jax.lax.scan(mamba_body, x,
                             _trunk_slice(params, g * every, (g + 1) * every))
        mamba_states.append(st)
        if return_cache:
            sb = params["shared_block"]
            attn_entries.append(attn_lib.prefill_cache_entries(
                sb["attn"], L.rms_norm(sb["ln1"], x), cfg.attention,
                shared_lin=shared_lin, max_seq=cache_max_seq or cfg.max_seq_len,
                dtype=cache_dtype))
        x = _shared_block(params, cfg, x, shared_lin=shared_lin, ctx=ctx,
                          chunked=chunked)
    if n_inv * every < cfg.num_layers:
        x, st = jax.lax.scan(mamba_body, x,
                             _trunk_slice(params, n_inv * every,
                                          cfg.num_layers))
        mamba_states.append(st)

    from repro.models.transformer import logits_from_hidden
    logits = logits_from_hidden(params, cfg, x, ctx)

    cache = None
    if return_cache:
        # states come stacked per trunk group from the scans — concatenate
        ssm = jnp.concatenate([s[0] for s in mamba_states], axis=0)
        conv = jnp.concatenate([s[1] for s in mamba_states], axis=0)
        cache = {
            "mamba_ssm": ssm,
            "mamba_conv": conv,
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *attn_entries),
            "length": jnp.asarray(S, jnp.int32),
        }
    return logits, jnp.zeros((), jnp.float32), cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, *, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict:
    d_inner, H, P_ = m2.dims(cfg.d_model, cfg.ssm)
    N = cfg.ssm.state_dim
    conv_ch = d_inner + 2 * N
    n_inv = n_attn_invocations(cfg)
    attn_spec = attn_lib.decode_cache_spec(
        cfg.attention, num_layers=n_inv, batch=batch, max_seq=max_seq,
        dtype=dtype)
    return {
        "mamba_ssm": jnp.zeros((cfg.num_layers, batch, H, N, P_), jnp.float32),
        "mamba_conv": jnp.zeros((cfg.num_layers, batch,
                                 cfg.ssm.conv_width - 1, conv_ch), dtype),
        "attn": {k: jnp.zeros(v.shape, v.dtype) for k, v in attn_spec.items()
                 if k != "lengths"},
        "length": jnp.zeros((), jnp.int32),
    }


def decode_step(
    params: Dict, cfg: ModelConfig, batch_t: Dict, cache: Dict, *,
    ctx: Optional[ParallelCtx] = None,
) -> Tuple[jax.Array, Dict]:
    t = cache["length"]
    x = L.embed_tokens(params["embed"]["tok"], batch_t["tokens"])
    shared_lin = params.get("shared", {}).get("lin")
    every = cfg.hybrid_attn_every
    n_inv = n_attn_invocations(cfg)
    new_ssm, new_conv, new_attn = [], [], []

    def trunk_step(x, i):
        lp = jax.tree.map(lambda a: a[i], params["trunk"])
        st = {"ssm": cache["mamba_ssm"][i], "conv": cache["mamba_conv"][i]}
        y, st2 = m2.step_mamba2(lp["ssm"], L.rms_norm(lp["ln"], x), st,
                                cfg.ssm)
        new_ssm.append(st2["ssm"])
        new_conv.append(st2["conv"])
        return x + y

    sb = params["shared_block"]
    for g in range(n_inv):
        for i in range(g * every, (g + 1) * every):
            x = trunk_step(x, i)
        lc = jax.tree.map(lambda a: a[g], cache["attn"])
        h, nlc = attn_lib.apply_attention_decode(
            sb["attn"], L.rms_norm(sb["ln1"], x), lc, t, cfg.attention,
            shared_lin=shared_lin)
        new_attn.append(nlc)
        x = x + h
        x = x + L.apply_mlp(sb["mlp"], L.rms_norm(sb["ln2"], x), cfg.mlp)
    for i in range(n_inv * every, cfg.num_layers):
        x = trunk_step(x, i)

    from repro.models.transformer import logits_from_hidden
    logits = logits_from_hidden(params, cfg, x, ctx)
    return logits, {
        "mamba_ssm": jnp.stack(new_ssm),
        "mamba_conv": jnp.stack(new_conv),
        "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *new_attn),
        "length": t + 1,
    }
