"""Attention block: QKV/output projections + dispatch between the standard
softmax baseline and the paper's Linformer forms.

`init_attention` creates the per-layer parameters (E/F included here when the
sharing mode is per-layer; the layerwise-shared E lives in the model's
"shared" collection and is passed through `shared_lin`).

Compute dispatch: every Linformer form executes through an
:class:`repro.parallel.plan.AttentionPlan` — resolved once per (config,
mesh) and threaded in by the caller (models/transformer.py passes the plan
for its ParallelCtx; a missing plan resolves the config single-device).
The plan owns backend selection (`cfg.backend` "auto" | "reference" |
"fused") AND, under a mesh, the shard_map specs that run the fused Pallas
kernels per shard — this module never branches on backend strings or mesh
presence.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.core import cache as cache_lib
from repro.core import causal as causal_lib
from repro.core import linformer as lin_lib
from repro.models import layers as L
from repro.parallel import plan as plan_lib

NEG_INF = causal_lib.NEG_INF


def init_attention(
    rng: jax.Array, d_model: int, cfg: AttentionConfig, *, max_seq: int,
    dtype,
) -> Dict:
    ks = jax.random.split(rng, 6)
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": L.dense_init(ks[0], (d_model, H * Dh), dtype),
        "wk": L.dense_init(ks[1], (d_model, Hkv * Dh), dtype),
        "wv": L.dense_init(ks[2], (d_model, Hkv * Dh), dtype),
        "wo": L.dense_init(ks[3], (H * Dh, d_model), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((Hkv * Dh,), dtype)
        p["bv"] = jnp.zeros((Hkv * Dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(Dh, dtype)
        p["k_norm"] = L.init_rmsnorm(Dh, dtype)
    if cfg.kind in ("linformer", "linformer_causal") \
            and cfg.linformer.sharing != "layerwise":
        # per-layer E/F (num_layers=1: the layer axis is added by the stacker)
        lp = lin_lib.init_linformer_params(ks[4], cfg, num_layers=1,
                                           max_seq=max_seq, dtype=dtype)
        p["lin"] = jax.tree.map(lambda a: a[0], lp["per_layer"])
    return p


def _qkv(params: Dict, x: jax.Array, cfg: AttentionConfig,
         positions: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    if cfg.qk_norm:
        q = L.rms_norm(params["q_norm"], q)
        k = L.rms_norm(params["k_norm"], k)
    if cfg.use_rope:
        pos = positions if positions is not None else jnp.arange(S)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def _resolve_ef(params: Dict, shared_lin: Optional[Dict],
                cfg: AttentionConfig) -> Tuple[jax.Array, jax.Array]:
    if cfg.linformer.sharing == "layerwise":
        assert shared_lin is not None, "layerwise sharing needs shared params"
        E = shared_lin["E"]
        return E, E
    lp = params["lin"]
    return lp["E"], lp.get("F", lp["E"])


def standard_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
    scale: Optional[float] = None,
) -> jax.Array:
    """Full softmax attention (the paper's baseline), GQA-grouped."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale_ = scale if scale is not None else Dh ** -0.5
    qg = q.reshape(B, S, Hkv, G, Dh)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32) * scale_
    if causal:
        s = jnp.where(jnp.tril(jnp.ones((S, S), bool))[None, None, None],
                      s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgst,bthd->bshgd", p, v).reshape(B, S, H, Dh)


def apply_attention(
    params: Dict,
    x: jax.Array,
    cfg: AttentionConfig,
    *,
    shared_lin: Optional[Dict] = None,
    positions: Optional[jax.Array] = None,
    chunked: bool = False,
    cache_entry_spec: Optional[Dict] = None,
    plan: Optional[plan_lib.AttentionPlan] = None,
):
    """Full-sequence attention (training / prefill). x: (B, S, D).

    With `cache_entry_spec` = {"max_seq": int, "dtype": ...}, also returns
    this layer's decode-cache entry built from the SAME k/v (single-pass
    prefill — no second forward). `plan` carries the resolved execution
    plan; None resolves the config single-device."""
    B, S, _ = x.shape
    if plan is None:
        plan = plan_lib.resolve_attention_plan(cfg)
    q, k, v = _qkv(params, x, cfg, positions)
    if cfg.kind == "standard":
        out = standard_attention(q, k, v, causal=cfg.causal)
    elif cfg.kind == "linformer":
        E, F = _resolve_ef(params, shared_lin, cfg)
        out = plan.exact_attention(q, k, v, E, F,
                                   projection=cfg.linformer.projection,
                                   scale=cfg.head_dim ** -0.5)
    elif cfg.kind == "linformer_causal":
        E, F = _resolve_ef(params, shared_lin, cfg)
        out = plan.causal_attention(q, k, v, E, F,
                                    block_size=cfg.linformer.block_size,
                                    block_slots=cfg.linformer.block_slots,
                                    scale=cfg.head_dim ** -0.5,
                                    chunked=chunked)
    else:
        raise ValueError(f"unknown attention kind {cfg.kind!r}")
    out = out.reshape(B, S, -1) @ params["wo"]
    if cache_entry_spec is not None:
        entry = _entry_from_kv(k, v, cfg,
                               _resolve_ef(params, shared_lin, cfg)
                               if cfg.kind == "linformer_causal" else None,
                               max_seq=cache_entry_spec["max_seq"],
                               dtype=cache_entry_spec["dtype"])
        return out, entry
    return out


def _entry_from_kv(k, v, cfg: AttentionConfig, ef, *, max_seq, dtype):
    """Decode-cache entry from already-computed k/v (rope applied)."""
    B, S, Hkv, Dh = k.shape
    if cfg.kind == "linformer_causal":
        E, F = ef
        c = cfg.linformer.block_size
        r = cfg.linformer.block_slots
        if S % c != 0:
            raise ValueError(f"prefill length {S} not a multiple of block {c}")
        nb = S // c
        M = (max_seq // c) * r
        comp_k = causal_lib.compress_blocks(
            k.reshape(B, nb, c, Hkv, Dh), E).reshape(B, nb * r, Hkv, Dh)
        comp_v = causal_lib.compress_blocks(
            v.reshape(B, nb, c, Hkv, Dh), F).reshape(B, nb * r, Hkv, Dh)
        pad = ((0, 0), (0, M - nb * r), (0, 0), (0, 0))
        return {
            "raw_k": jnp.zeros((B, c, Hkv, Dh), dtype),
            "raw_v": jnp.zeros((B, c, Hkv, Dh), dtype),
            "comp_k": jnp.pad(comp_k.astype(dtype), pad),
            "comp_v": jnp.pad(comp_v.astype(dtype), pad),
        }
    if cfg.kind == "standard":
        pad = ((0, 0), (0, max_seq - S), (0, 0), (0, 0))
        return {"k": jnp.pad(k.astype(dtype), pad),
                "v": jnp.pad(v.astype(dtype), pad)}
    raise ValueError(f"no decode cache for attention kind {cfg.kind!r}")


def apply_attention_decode(
    params: Dict,
    x_t: jax.Array,                 # (B, 1, D)
    layer_cache: Dict[str, jax.Array],
    t: jax.Array,                   # () or (B,) int32 current position(s)
    cfg: AttentionConfig,
    *,
    shared_lin: Optional[Dict] = None,
    plan: Optional[plan_lib.AttentionPlan] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode step against the layer's cache. A (B,) t gives each
    row its own position (rope + cache write + mask all per row)."""
    if plan is None:
        plan = plan_lib.resolve_attention_plan(cfg)
    positions = t[None] if t.ndim == 0 else t[:, None]      # (1,) or (B, 1)
    q, k, v = _qkv(params, x_t, cfg, positions=positions)
    if cfg.kind == "linformer_causal":
        E, F = _resolve_ef(params, shared_lin, cfg)
        # paged, quantized cache routes on its page_table leaf — same
        # attention math, different storage (core/cache.py paged family)
        decode_fn = (cache_lib.paged_decode_attention
                     if "page_table" in layer_cache
                     else cache_lib.compressed_decode_attention)
        out, new_cache = decode_fn(q, k, v, layer_cache, E, F, t, plan=plan)
    elif cfg.kind == "standard":
        out, new_cache = cache_lib.full_decode_attention(
            q, k, v, layer_cache, t)
    else:
        raise ValueError(
            f"attention kind {cfg.kind!r} has no decode path "
            "(exact linformer is bidirectional/encoder-only)")
    B = x_t.shape[0]
    return out.reshape(B, 1, -1) @ params["wo"], new_cache


def apply_attention_prefill_chunk(
    params: Dict,
    x: jax.Array,                   # (B, P, D) — one prefill chunk
    layer_cache: Dict[str, jax.Array],
    t0: jax.Array,                  # (B,) int32 — row's committed length
    cfg: AttentionConfig,
    *,
    shared_lin: Optional[Dict] = None,
    positions: Optional[jax.Array] = None,   # (B, P) absolute positions
    plan: Optional[plan_lib.AttentionPlan] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked-prefill attention at a per-row offset, against the layer's
    slot-resident cache: row b's chunk covers absolute positions
    [t0[b], t0[b] + P). For linformer_causal t0 and P must be multiples of
    the block size (chunk boundaries are block-fold boundaries); standard
    attention takes any offset. Returns (out (B, P, D'), updated cache)."""
    if plan is None:
        plan = plan_lib.resolve_attention_plan(cfg)
    if positions is None:
        positions = t0[:, None] + jnp.arange(x.shape[1])[None, :]
    q, k, v = _qkv(params, x, cfg, positions=positions)
    if cfg.kind == "linformer_causal":
        E, F = _resolve_ef(params, shared_lin, cfg)
        prefill_fn = (cache_lib.paged_prefill_chunk
                      if "page_table" in layer_cache
                      else cache_lib.compressed_prefill_chunk)
        out, new_cache = prefill_fn(q, k, v, layer_cache, E, F, t0, plan=plan)
    elif cfg.kind == "standard":
        out, new_cache = cache_lib.full_prefill_chunk(
            q, k, v, layer_cache, t0)
    else:
        raise ValueError(
            f"attention kind {cfg.kind!r} has no chunked-prefill path "
            "(exact linformer is bidirectional/encoder-only)")
    B, P = x.shape[:2]
    return out.reshape(B, P, -1) @ params["wo"], new_cache


def prefill_cache_entries(
    params: Dict,
    x: jax.Array,                   # (B, S, D) — normed block input
    cfg: AttentionConfig,
    *,
    shared_lin: Optional[Dict],
    max_seq: int,
    dtype=jnp.bfloat16,
) -> Dict[str, jax.Array]:
    """Build this layer's decode-cache entry from a prefilled sequence.

    For the compressed cache, S must be a multiple of block_size (the serving
    engine decodes any remainder tokens individually); the raw ring buffer
    starts empty at t = S.
    """
    q, k, v = _qkv(params, x, cfg, positions=None)
    ef = (_resolve_ef(params, shared_lin, cfg)
          if cfg.kind == "linformer_causal" else None)
    return _entry_from_kv(k, v, cfg, ef, max_seq=max_seq, dtype=dtype)


def decode_cache_spec(cfg: AttentionConfig, *, num_layers: int, batch: int,
                      max_seq: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct spec of this attention kind's decode cache."""
    if cfg.kind == "linformer_causal":
        return cache_lib.compressed_cache_spec(
            num_layers=num_layers, batch=batch, max_seq=max_seq,
            block_size=cfg.linformer.block_size,
            block_slots=cfg.linformer.block_slots,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim, dtype=dtype)
    return cache_lib.full_cache_spec(
        num_layers=num_layers, batch=batch, max_seq=max_seq,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim, dtype=dtype)


def paged_decode_cache_spec(cfg: AttentionConfig, *, num_layers: int,
                            batch: int, max_seq: int,
                            arena_pages: Optional[int] = None,
                            page_dtype: str = "int8"):
    """ShapeDtypeStruct spec of the paged, quantized decode cache (the
    linformer_causal serving pool in int8/fp8 page storage)."""
    if cfg.kind != "linformer_causal":
        raise ValueError(
            f"paged cache requires kind='linformer_causal', got {cfg.kind!r}")
    return cache_lib.paged_cache_spec(
        num_layers=num_layers, batch=batch, max_seq=max_seq,
        block_size=cfg.linformer.block_size,
        block_slots=cfg.linformer.block_slots,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        arena_pages=arena_pages, page_dtype=page_dtype)
