"""Transformer model families: dense decoder LMs (qwen3/nemotron/qwen1.5),
MoE decoders (kimi-k2, qwen3-moe), VLM/audio backbones (internvl2, musicgen)
and the paper's bidirectional encoder (linformer-paper MLM track).

Layers are scanned (stacked params + lax.scan) so HLO size and compile time
are depth-independent; `cfg.scan_layers=False` falls back to an unrolled loop
(needed for non-uniform Linformer k, where per-layer shapes differ).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import linformer as lin_lib
from repro.core.causal import chunked_attention_min_seq
from repro.core.projections import effective_k
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.parallel import plan as plan_lib
from repro.parallel.sharding import ParallelCtx, shard_activation

import dataclasses


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# One transformer block
# ---------------------------------------------------------------------------


def init_block(rng: jax.Array, cfg: ModelConfig, *, lin_k: Optional[int] = None
               ) -> Dict:
    """One decoder/encoder block. `lin_k` overrides the Linformer k (used for
    non-uniform projected dimension in the unrolled encoder)."""
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 3)
    acfg = cfg.attention
    if lin_k is not None:
        acfg = dataclasses.replace(
            acfg, linformer=dataclasses.replace(acfg.linformer, k=lin_k))
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model, dt),
        "ln2": L.init_rmsnorm(cfg.d_model, dt),
        "attn": attn_lib.init_attention(ks[0], cfg.d_model, acfg,
                                        max_seq=cfg.max_seq_len, dtype=dt),
    }
    if cfg.moe.num_experts > 0:
        p["moe"] = moe_lib.init_moe(ks[1], cfg.d_model, cfg.moe, cfg.mlp, dt)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.mlp, dt)
    return p


def _act_spec(ctx: Optional[ParallelCtx], cfg: ModelConfig):
    """Residual-stream sharding between blocks: batch over data axes, and —
    with cfg.seq_shard_activations — the sequence over "model" (sequence
    parallelism for the carry; GSPMD inserts the gather where attention
    needs the full sequence)."""
    if ctx is None or ctx.mesh is None:
        return None
    from jax.sharding import PartitionSpec as P
    if cfg.seq_shard_activations:
        return P(ctx.data_axes, ctx.model_axis, None)
    return P(ctx.data_axes, None, None)


def apply_block(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    shared_lin: Optional[Dict],
    ctx: Optional[ParallelCtx],
    chunked_attn: bool = False,
    cache_entry_spec: Optional[Dict] = None,
):
    """Returns (x, moe_aux_loss[, cache_entry])."""
    spec = _act_spec(ctx, cfg)
    plan = plan_lib.resolve_attention_plan(cfg.attention, ctx)
    res = attn_lib.apply_attention(params["attn"], L.rms_norm(params["ln1"], x),
                                   cfg.attention, shared_lin=shared_lin,
                                   chunked=chunked_attn,
                                   cache_entry_spec=cache_entry_spec,
                                   plan=plan)
    entry = None
    if cache_entry_spec is not None:
        h, entry = res
    else:
        h = res
    x = x + h
    x = shard_activation(x, ctx, spec)
    hin = L.rms_norm(params["ln2"], x)
    if cfg.moe.num_experts > 0:
        h, aux = moe_lib.apply_moe(params["moe"], hin, cfg.moe, cfg.mlp, ctx)
    else:
        h, aux = L.apply_mlp(params["mlp"], hin, cfg.mlp), jnp.zeros((), jnp.float32)
    x = shard_activation(x + h, ctx, spec)
    if cache_entry_spec is not None:
        return x, aux, entry
    return x, aux


def apply_block_decode(
    params: Dict,
    x_t: jax.Array,
    layer_cache: Dict,
    t: jax.Array,
    cfg: ModelConfig,
    *,
    shared_lin: Optional[Dict],
    ctx: Optional[ParallelCtx],
) -> Tuple[jax.Array, Dict, jax.Array]:
    h, new_cache = attn_lib.apply_attention_decode(
        params["attn"], L.rms_norm(params["ln1"], x_t), layer_cache, t,
        cfg.attention, shared_lin=shared_lin,
        plan=plan_lib.resolve_attention_plan(cfg.attention, ctx))
    x_t = x_t + h
    hin = L.rms_norm(params["ln2"], x_t)
    if cfg.moe.num_experts > 0:
        h, aux = moe_lib.apply_moe(params["moe"], hin, cfg.moe, cfg.mlp, ctx)
    else:
        h, aux = L.apply_mlp(params["mlp"], hin, cfg.mlp), jnp.zeros((), jnp.float32)
    return x_t + h, new_cache, aux


def apply_block_prefill_chunk(
    params: Dict,
    x: jax.Array,                   # (B, P, D) — one prefill chunk
    layer_cache: Dict,
    t0: jax.Array,                  # (B,) int32 committed per-row lengths
    cfg: ModelConfig,
    *,
    positions: jax.Array,           # (B, P) absolute positions
    shared_lin: Optional[Dict],
    ctx: Optional[ParallelCtx],
) -> Tuple[jax.Array, Dict]:
    """One transformer block over a prefill chunk at a per-row offset
    (decode-path twin of `apply_block`, cache-writing like
    `apply_block_decode` but P tokens at once)."""
    h, new_cache = attn_lib.apply_attention_prefill_chunk(
        params["attn"], L.rms_norm(params["ln1"], x), layer_cache, t0,
        cfg.attention, shared_lin=shared_lin, positions=positions,
        plan=plan_lib.resolve_attention_plan(cfg.attention, ctx))
    x = x + h
    hin = L.rms_norm(params["ln2"], x)
    if cfg.moe.num_experts > 0:
        h, _ = moe_lib.apply_moe(params["moe"], hin, cfg.moe, cfg.mlp, ctx)
    else:
        h = L.apply_mlp(params["mlp"], hin, cfg.mlp)
    return x + h, new_cache


def prefill_chunk(
    params: Dict,
    cfg: ModelConfig,
    batch_c: Dict,
    cache: Dict,
    n_valid: jax.Array,
    *,
    ctx: Optional[ParallelCtx] = None,
) -> Tuple[jax.Array, Dict]:
    """Prefill-at-offset forward for one fixed-size chunk of every row.

    batch_c: {"tokens": (B, P)} — row b's next prefill chunk, padded at the
    END to the fixed chunk width P; n_valid (B,) int32 counts the real
    tokens (for linformer_causal a multiple of the block size, so padding
    occupies whole blocks and needs no masking — see core/cache.py).

    Row b's chunk starts at its committed length cache["lengths"][b]: rope
    and learned positions are taken at the absolute offsets, the causal
    structure continues from the row's cache (compressed slots / full-cache
    prefix), and each layer's K/V state is written back at the row's offset.
    Returns (last-valid-token logits (B, V), cache advanced by n_valid) —
    the logits row is only meaningful for rows whose prompt ends inside
    this chunk (the serving scheduler samples the first generated token
    from it)."""
    if cfg.embedding_inputs or cfg.frontend_embed_len > 0:
        raise ValueError("chunked prefill supports token inputs only")
    t0 = cache["lengths"]                   # (B,) committed lengths
    tokens = batch_c["tokens"]
    B, P = tokens.shape
    n_valid = jnp.asarray(n_valid, jnp.int32)
    x = L.embed_tokens(params["embed"]["tok"], tokens)
    positions = t0[:, None] + jnp.arange(P)[None, :]         # (B, P)
    if "pos" in params.get("embed", {}):
        tab = params["embed"]["pos"]
        x = x + tab[jnp.clip(positions, 0, tab.shape[0] - 1)]
    x = shard_activation(x, ctx)
    shared_lin = params.get("shared", {}).get("lin")

    layer_caches = {k: v for k, v in cache.items() if k != "lengths"}

    def body(h, inp):
        lp, lc = inp
        h2, new_lc = apply_block_prefill_chunk(
            lp, h, lc, t0, cfg, positions=positions, shared_lin=shared_lin,
            ctx=ctx)
        return h2, new_lc

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches))
    else:
        outs = []
        for i, lp in enumerate(params["layers_list"]):
            lc = jax.tree.map(lambda a: a[i], layer_caches)
            x, nc = body(x, (lp, lc))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    # logits only at each row's last REAL token (padded rows' tail is junk)
    h_last = jnp.take_along_axis(
        x, (n_valid - 1)[:, None, None].astype(jnp.int32), axis=1)  # (B,1,D)
    logits = logits_from_hidden(params, cfg, h_last, ctx)
    new_caches["lengths"] = t0 + n_valid
    return logits[:, 0], new_caches


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 6)
    params: Dict = {"embed": {}}
    if not cfg.embedding_inputs:
        params["embed"]["tok"] = L.init_embedding(ks[0], cfg.padded_vocab_size,
                                                  cfg.d_model, dt)
    if not cfg.attention.use_rope:
        params["embed"]["pos"] = L.init_learned_positions(
            ks[1], cfg.max_seq_len, cfg.d_model, dt)

    lin = cfg.attention.linformer
    uses_linformer = cfg.attention.kind in ("linformer", "linformer_causal")
    if uses_linformer and lin.sharing == "layerwise":
        params["shared"] = {
            "lin": lin_lib.init_linformer_params(
                ks[2], cfg.attention, num_layers=cfg.num_layers,
                max_seq=cfg.max_seq_len, dtype=dt)["shared"]
        }

    if cfg.scan_layers:
        rngs = jax.random.split(ks[3], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda r: init_block(r, cfg))(rngs)
    else:
        blocks = []
        for i in range(cfg.num_layers):
            k_i = (effective_k(lin.k, lin.k_decay, i, cfg.num_layers)
                   if uses_linformer and cfg.attention.kind == "linformer"
                   else None)
            blocks.append(init_block(jax.random.fold_in(ks[3], i), cfg,
                                     lin_k=k_i))
        params["layers_list"] = blocks

    params["final_norm"] = L.init_rmsnorm(cfg.d_model, dt)
    if cfg.tie_embeddings and not cfg.embedding_inputs:
        pass  # reuse embed.tok
    else:
        params["lm_head"] = L.dense_init(ks[4], (cfg.d_model, cfg.padded_vocab_size),
                                         dt)
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def embed_inputs(params: Dict, cfg: ModelConfig, batch: Dict,
                 ctx: Optional[ParallelCtx]) -> jax.Array:
    """Assemble the (B, S, D) input stream from tokens and/or stub-frontend
    embeddings (VLM patches prepended; audio frames replace tokens)."""
    if cfg.embedding_inputs:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = L.embed_tokens(params["embed"]["tok"], batch["tokens"])
        if cfg.frontend_embed_len > 0:
            fe = batch["frontend_embeds"].astype(x.dtype)   # (B, P, D)
            x = jnp.concatenate([fe, x], axis=1)
    if "pos" in params.get("embed", {}):
        S = x.shape[1]
        x = x + params["embed"]["pos"][:S][None]
    return shard_activation(x, ctx)


def logits_from_hidden(params: Dict, cfg: ModelConfig, x: jax.Array,
                       ctx: Optional[ParallelCtx]) -> jax.Array:
    x = L.rms_norm(params["final_norm"], x)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"]["tok"].T
    logits = x @ head
    if ctx is not None and ctx.mesh is not None:
        from jax.sharding import PartitionSpec as P
        logits = shard_activation(logits, ctx,
                                  P(ctx.data_axes, None, "model"))
    return logits


def forward(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict,
    *,
    ctx: Optional[ParallelCtx] = None,
    return_cache: bool = False,
    cache_max_seq: Optional[int] = None,
    cache_dtype=jnp.bfloat16,
    return_hidden: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """Full-sequence forward. Returns (logits, moe_aux, cache|None).

    With return_cache=True the sequence length must be a multiple of the
    Linformer block size (standard attention: any length); the returned cache
    is positioned at t = S, ready for decode_step.
    """
    x = embed_inputs(params, cfg, batch, ctx)
    B, S, _ = x.shape
    chunked = S >= chunked_attention_min_seq()
    shared_lin = params.get("shared", {}).get("lin")
    single_pass = return_cache and cfg.single_pass_cache
    entry_spec = ({"max_seq": cache_max_seq or cfg.max_seq_len,
                   "dtype": cache_dtype} if single_pass else None)

    entries = None
    if cfg.scan_layers:
        def body(carry, lp):
            h, aux = carry
            out = apply_block(lp, h, cfg, shared_lin=shared_lin, ctx=ctx,
                              chunked_attn=chunked,
                              cache_entry_spec=entry_spec)
            if single_pass:
                h2, aux2, entry = out
                return (h2, aux + aux2), entry
            h2, aux2 = out
            return (h2, aux + aux2), None

        body = remat_wrap(body, cfg.remat)
        (x, aux), entries = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        outs = []
        for lp in params["layers_list"]:
            out = apply_block(lp, x, cfg, shared_lin=shared_lin, ctx=ctx,
                              chunked_attn=chunked,
                              cache_entry_spec=entry_spec)
            if single_pass:
                x, a, entry = out
                outs.append(entry)
            else:
                x, a = out
            aux = aux + a
        if single_pass:
            entries = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    logits = x if return_hidden else logits_from_hidden(params, cfg, x, ctx)

    cache = None
    if return_cache:
        if single_pass:
            cache = dict(entries)
            cache["lengths"] = jnp.full((B,), S, jnp.int32)
        else:
            cache = build_cache_from_sequence(
                params, cfg, batch, max_seq=cache_max_seq or cfg.max_seq_len,
                dtype=cache_dtype, ctx=ctx)
    return logits, aux, cache


def build_cache_from_sequence(params, cfg, batch, *, max_seq, dtype, ctx):
    """Recompute per-layer K/V once more to materialize a decode cache after
    prefill (sequence length must be a multiple of the block size for the
    compressed cache). Separate pass keeps the scan body cache-free."""
    x = embed_inputs(params, cfg, batch, ctx)
    B, S, _ = x.shape
    shared_lin = params.get("shared", {}).get("lin")
    acfg = cfg.attention
    chunked = S >= chunked_attention_min_seq()

    def body(carry, lp):
        h, _ = carry
        normed = L.rms_norm(lp["ln1"], h)
        entries = attn_lib.prefill_cache_entries(
            lp["attn"], normed, acfg, shared_lin=shared_lin,
            max_seq=max_seq, dtype=dtype)
        h2, aux2 = apply_block(lp, h, cfg, shared_lin=shared_lin, ctx=ctx,
                               chunked_attn=chunked)
        return (h2, aux2), entries

    body = remat_wrap(body, cfg.remat)
    if cfg.scan_layers:
        _, entries = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                  params["layers"])
    else:
        outs = []
        carry = (x, jnp.zeros((), jnp.float32))
        for lp in params["layers_list"]:
            carry, e = body(carry, lp)
            outs.append(e)
        entries = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    entries["lengths"] = jnp.full((B,), S, jnp.int32)
    return entries


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, *, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict:
    spec = attn_lib.decode_cache_spec(cfg.attention, num_layers=cfg.num_layers,
                                      batch=batch, max_seq=max_seq, dtype=dtype)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}


def cache_spec(cfg: ModelConfig, *, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict:
    return attn_lib.decode_cache_spec(cfg.attention, num_layers=cfg.num_layers,
                                      batch=batch, max_seq=max_seq, dtype=dtype)


def decode_step(
    params: Dict,
    cfg: ModelConfig,
    batch_t: Dict,
    cache: Dict,
    *,
    ctx: Optional[ParallelCtx] = None,
) -> Tuple[jax.Array, Dict]:
    """One decode step. batch_t: {"tokens": (B,1)} or {"embeds": (B,1,D)}.
    Returns (logits (B,1,V), updated cache). Positions are per row: row b
    decodes at cache["lengths"][b]."""
    t = cache["lengths"]                    # (B,) per-row positions
    if cfg.embedding_inputs:
        x = batch_t["embeds"].astype(_dtype(cfg))
    else:
        x = L.embed_tokens(params["embed"]["tok"], batch_t["tokens"])
    if "pos" in params.get("embed", {}):
        x = x + params["embed"]["pos"][t][:, None]      # (B, 1, D)
    x = shard_activation(x, ctx)
    shared_lin = params.get("shared", {}).get("lin")

    layer_caches = {k: v for k, v in cache.items() if k != "lengths"}

    def body(h, inp):
        lp, lc = inp
        h2, new_lc, _ = apply_block_decode(lp, h, lc, t, cfg,
                                           shared_lin=shared_lin, ctx=ctx)
        return h2, new_lc

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches))
    else:
        outs = []
        for i, lp in enumerate(params["layers_list"]):
            lc = jax.tree.map(lambda a: a[i], layer_caches)
            x, nc = body(x, (lp, lc))
            outs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    logits = logits_from_hidden(params, cfg, x, ctx)
    new_caches["lengths"] = t + 1
    return logits, new_caches
