"""RWKV6 "Finch" block (data-dependent decay linear attention) — attn-free.

Per head (head dim P), with per-channel data-dependent decay w_t ∈ (0,1):

    y_t = r_t · ( S_{t-1} + diag(u) · k_t ⊗ v_t )
    S_t = diag(w_t) · S_{t-1} + k_t ⊗ v_t              S ∈ R^{P×P}

Token-shift "ddlerp" mixing and the decay w_t follow the Finch low-rank
parameterization. Training uses a chunked parallel form; per-step log-decay is
clamped to [-2, -1e-6] (identically at train and decode time) so the chunked
factorization exp(±cum) stays in fp32 range — decays below e^-2/step zero out
state within a few tokens anyway, so the clamp is modelling-neutral.

Linformer is inapplicable here (no attention matrix) — see DESIGN.md §5.1.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RWKVConfig
from repro.models import layers as L

TM_DIM = 32          # ddlerp low-rank dim
TD_DIM = 64          # decay low-rank dim
LOG_W_MIN = -2.0
LOG_W_MAX = -1e-6
_MIX = ("w", "k", "v", "r", "g")


def init_rwkv6(rng: jax.Array, d_model: int, d_ff: int, cfg: RWKVConfig,
               dtype) -> Dict:
    D = d_model
    ks = jax.random.split(rng, 12)
    p = {
        # token-shift mixing
        "maa_x": jnp.zeros((D,), dtype),
        "maa": jnp.zeros((5, D), dtype),                   # per w,k,v,r,g
        "tm_w1": L.dense_init(ks[0], (D, 5 * TM_DIM), dtype, scale=1e-2),
        "tm_w2": L.dense_init(ks[1], (5, TM_DIM, D), dtype, scale=1e-2),
        # data-dependent decay
        "td_w1": L.dense_init(ks[2], (D, TD_DIM), dtype, scale=1e-2),
        "td_w2": L.dense_init(ks[3], (TD_DIM, D), dtype, scale=1e-2),
        "decay_base": jnp.zeros((D,), jnp.float32),
        "bonus_u": (jax.random.normal(ks[4], (D,)) * 0.1).astype(jnp.float32),
        # projections
        "w_r": L.dense_init(ks[5], (D, D), dtype),
        "w_k": L.dense_init(ks[6], (D, D), dtype),
        "w_v": L.dense_init(ks[7], (D, D), dtype),
        "w_g": L.dense_init(ks[8], (D, D), dtype),
        "w_o": L.dense_init(ks[9], (D, D), dtype),
        "ln_x": {"scale": jnp.ones((D,), dtype), "bias": jnp.zeros((D,), dtype)},
        # channel mix
        "cm_maa_k": jnp.zeros((D,), dtype),
        "cm_maa_r": jnp.zeros((D,), dtype),
        "cm_w_k": L.dense_init(ks[10], (D, d_ff), dtype),
        "cm_w_v": L.dense_init(ks[11], (d_ff, D), dtype),
        "cm_w_r": L.dense_init(jax.random.fold_in(rng, 99), (D, D), dtype),
    }
    return p


def _shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Token shift: x_{t-1}, with `prev` (B,D) as the t=0 left context."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(params, x, xx):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    B, S, D = x.shape
    dx = xx - x
    base = x + dx * params["maa_x"]
    k5 = jnp.tanh(base @ params["tm_w1"]).reshape(B, S, 5, TM_DIM)
    deltas = jnp.einsum("bsnt,ntd->nbsd", k5, params["tm_w2"])   # (5,B,S,D)
    outs = []
    for i in range(5):
        mi = params["maa"][i] + deltas[i]
        outs.append(x + dx * mi)
    return outs                                            # [xw,xk,xv,xr,xg]


def _log_decay(params, xw):
    ww = params["decay_base"] + \
        (jnp.tanh(xw @ params["td_w1"]) @ params["td_w2"]).astype(jnp.float32)
    return jnp.clip(-jnp.exp(ww), LOG_W_MIN, LOG_W_MAX)    # (B,S,D)


def _group_norm(p, y, H):
    """Per-head layer norm; y: (B,S,H,P) -> (B,S,D)."""
    B, S, _, P_ = y.shape
    y32 = y.astype(jnp.float32)
    mu = y32.mean(-1, keepdims=True)
    var = y32.var(-1, keepdims=True)
    yn = (y32 - mu) * jax.lax.rsqrt(var + 1e-5)
    yn = yn.reshape(B, S, H * P_)
    return (yn * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32))


def time_mix(params: Dict, x: jax.Array, cfg: RWKVConfig,
             shift_prev: jax.Array, wkv_state: jax.Array
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked parallel WKV. x: (B,S,D). Returns (out, new_shift, new_state).

    wkv_state: (B,H,P,P) initial state (zeros at sequence start).
    """
    B, S, D = x.shape
    P_ = cfg.head_dim
    H = D // P_
    Lc = cfg.chunk_size if (S % cfg.chunk_size == 0 and S >= cfg.chunk_size) \
        else S
    nc = S // Lc

    xx = _shift(x, shift_prev)
    xw, xk, xv, xr, xg = _ddlerp(params, x, xx)
    r = (xr @ params["w_r"]).reshape(B, S, H, P_).astype(jnp.float32)
    k = (xk @ params["w_k"]).reshape(B, S, H, P_).astype(jnp.float32)
    v = (xv @ params["w_v"]).reshape(B, S, H, P_).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["w_g"])
    lw = _log_decay(params, xw).reshape(B, S, H, P_)       # (B,S,H,P) ≤ 0
    u = params["bonus_u"].reshape(H, P_)

    rc = r.reshape(B, nc, Lc, H, P_)
    kc = k.reshape(B, nc, Lc, H, P_)
    vc = v.reshape(B, nc, Lc, H, P_)
    lwc = lw.reshape(B, nc, Lc, H, P_)
    cum = jnp.cumsum(lwc, axis=2)                          # inclusive, ≤ 0
    cum_prev = cum - lwc                                   # exclusive: decay up to t-1
    cum_end = cum[:, :, -1:]                               # (B,nc,1,H,P)

    # intra-chunk, strict lower triangle (bonus handles the diagonal):
    # score[t,s] = Σ_i r_t[i] k_s[i] exp(cum_prev[t,i] - cum[s,i]), s < t
    q_f = rc * jnp.exp(cum_prev)                           # bounded ≤ |r|
    k_f = kc * jnp.exp(-cum)                               # bounded by clamp
    sc = jnp.einsum("bcthi,bcshi->bchts", q_f, k_f)
    mask = jnp.tril(jnp.ones((Lc, Lc), bool), k=-1)
    sc = jnp.where(mask[None, None, None], sc, 0.0)
    y = jnp.einsum("bchts,bcshj->bcthj", sc, vc)
    # bonus (current token):
    y = y + jnp.einsum("bcthi,hi,bcthi,bcthj->bcthj", rc,
                       u.astype(jnp.float32), kc, vc)

    # chunk states + inter-chunk scan
    k_end = kc * jnp.exp(cum_end - cum)                    # bounded
    S_c = jnp.einsum("bcshi,bcshj->bchij", k_end, vc)      # (B,nc,H,P,P)
    a_c = jnp.exp(cum_end[:, :, 0])                        # (B,nc,H,P)

    def scan_fn(h, inp):
        s_c, a = inp                                       # (B,H,P,P),(B,H,P)
        h_new = h * a[..., None] + s_c                     # decay keys axis i
        return h_new, h

    h_last, h_prev = jax.lax.scan(
        scan_fn, wkv_state.astype(jnp.float32),
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(a_c, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                    # (B,nc,H,P,P)

    y = y + jnp.einsum("bcthi,bchij->bcthj", q_f, h_prev)
    y = y.reshape(B, S, H, P_)

    out = _group_norm(params["ln_x"], y, H).astype(x.dtype) * g
    out = out @ params["w_o"]
    return out, x[:, -1], h_last


def channel_mix(params: Dict, x: jax.Array, shift_prev: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    xx = _shift(x, shift_prev)
    dx = xx - x
    xk = x + dx * params["cm_maa_k"]
    xr = x + dx * params["cm_maa_r"]
    kk = jnp.square(jax.nn.relu(xk @ params["cm_w_k"]))
    out = jax.nn.sigmoid(xr @ params["cm_w_r"]) * (kk @ params["cm_w_v"])
    return out, x[:, -1]


# ---------------------------------------------------------------------------
# Recurrent step (decode + oracle)
# ---------------------------------------------------------------------------


def init_rwkv6_state(batch: int, d_model: int, cfg: RWKVConfig,
                     dtype=jnp.float32) -> Dict:
    P_ = cfg.head_dim
    H = d_model // P_
    return {
        "wkv": jnp.zeros((batch, H, P_, P_), jnp.float32),
        "tm_shift": jnp.zeros((batch, d_model), dtype),
        "cm_shift": jnp.zeros((batch, d_model), dtype),
    }


def step_time_mix(params: Dict, x_t: jax.Array, cfg: RWKVConfig,
                  state: Dict) -> Tuple[jax.Array, Dict]:
    """x_t: (B,1,D) -> (out (B,1,D), new state pieces)."""
    B, _, D = x_t.shape
    P_ = cfg.head_dim
    H = D // P_
    xx = state["tm_shift"][:, None].astype(x_t.dtype)
    xw, xk, xv, xr, xg = _ddlerp(params, x_t, xx)
    r = (xr @ params["w_r"]).reshape(B, H, P_).astype(jnp.float32)
    k = (xk @ params["w_k"]).reshape(B, H, P_).astype(jnp.float32)
    v = (xv @ params["w_v"]).reshape(B, H, P_).astype(jnp.float32)
    g = jax.nn.silu(xg @ params["w_g"])
    w = jnp.exp(_log_decay(params, xw).reshape(B, H, P_))
    u = params["bonus_u"].reshape(H, P_)

    S = state["wkv"]
    kv = jnp.einsum("bhi,bhj->bhij", k, v)
    y = jnp.einsum("bhi,bhij->bhj", r, S + u[None, :, :, None] * kv)
    S_new = S * w[..., None] + kv
    y = y.reshape(B, 1, H, P_)
    out = _group_norm(params["ln_x"], y, H).astype(x_t.dtype) * g
    return out @ params["w_o"], {"wkv": S_new, "tm_shift": x_t[:, 0]}
