"""Core of the reproduction: Linformer linear-complexity attention.

Public surface:
  * exact bidirectional form (paper Eq. 7): :mod:`repro.core.linformer`
  * blockwise-causal adaptation:            :mod:`repro.core.causal`
  * decode caches (compressed + full):      :mod:`repro.core.cache`
  * sequence projections (linear/conv/pool)::mod:`repro.core.projections`
  * spectrum / JL analysis (Thm 1–2, Fig 1)::mod:`repro.core.low_rank`
"""
from repro.core.linformer import (  # noqa: F401
    attend_compressed,
    exact_linformer_attention,
    init_linformer_params,
    num_projection_matrices,
    project_kv,
    resolve_ef,
)
from repro.core.causal import (  # noqa: F401
    blockwise_causal_attention,
    blockwise_causal_attention_chunked,
    compress_blocks,
)
from repro.core.cache import (  # noqa: F401
    compressed_decode_attention,
    full_decode_attention,
    init_compressed_cache,
    init_full_cache,
)
