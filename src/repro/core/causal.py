"""Blockwise-causal Linformer attention (DESIGN.md §4).

The paper's convolutional projection (kernel = stride = c) compresses each
c-token block into r slots: slots of block b are a linear function of keys in
block b ONLY. Causality therefore holds at block granularity:

  a query at position t (block b = t // c) attends
    * exactly + causally within its own block (positions b·c .. t), and
    * the r compressed slots of every block strictly before b.

Cost O(n·(c + r·n/c)) — vs O(n²) for full attention. With fixed (c, r) the
attended width at position t is c + r·⌊t/c⌋, i.e. a c/r-fold compression of
the prefix. Decode keeps a compressed cache of the same width (cache.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# Sequences at or above this length route reference/recompute attention
# through the memory-bounded chunked form (`blockwise_causal_attention_chunked`)
# instead of the plain form, whose (S × nb·r) global score tensor would be
# materialized whole. Single source of truth for models/transformer.py's
# forward rule and kernels/ops.py's reference-recompute backward — previously
# duplicated as bare literals that could drift.
CHUNKED_ATTENTION_MIN_SEQ = 8192


def chunked_attention_min_seq() -> int:
    """The chunked-vs-plain routing threshold, after tuning.

    Consults the tuning table's platform-wide ``chunked_min_seq`` scalar
    (repro/tune/table.py, committed TUNING.json) and falls back to
    CHUNKED_ATTENTION_MIN_SEQ on any miss. Called at trace/construction
    time only — the result is a static Python int."""
    from repro.tune import table as tuning
    return tuning.scalar("chunked_min_seq", CHUNKED_ATTENTION_MIN_SEQ)


def _split_heads_gqa(q: jax.Array, num_kv: int) -> jax.Array:
    """(B,S,H,Dh) -> (B,S,Hkv,G,Dh)"""
    B, S, H, Dh = q.shape
    assert H % num_kv == 0
    return q.reshape(B, S, num_kv, H // num_kv, Dh)


def compress_blocks(x: jax.Array, W: jax.Array) -> jax.Array:
    """(B, nb, c, Hkv, Dh) × (c, r)|(Hkv, c, r) -> (B, nb, r, Hkv, Dh)."""
    if W.ndim == 2:
        return jnp.einsum("bnchd,cr->bnrhd", x, W.astype(x.dtype))
    return jnp.einsum("bnchd,hcr->bnrhd", x, W.astype(x.dtype))


def blockwise_causal_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    E: jax.Array,
    F: jax.Array,
    *,
    block_size: int,
    scale: Optional[float] = None,
    return_residuals: bool = False,
):
    """Training-parallel form.

    q: (B,S,H,Dh); k,v: (B,S,Hkv,Dh); E,F: (c,r) or (Hkv,c,r); S % c == 0.
    Returns (B,S,H,Dh) — or, with ``return_residuals=True``, the tuple
    ``(out, m, denom)`` where m/denom are the joint softmax's per-row max and
    denominator, each (B, H, S) fp32: the parity oracle for the residuals the
    fused forward saves for its Pallas backward (kernels/ops.py).
    """
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    c = block_size
    if S % c != 0:
        raise ValueError(f"S={S} must be a multiple of block_size={c}")
    nb = S // c
    r = E.shape[-1]
    scale = scale if scale is not None else Dh ** -0.5

    kb = k.reshape(B, nb, c, Hkv, Dh)
    vb = v.reshape(B, nb, c, Hkv, Dh)
    qb = q.reshape(B, nb, c, Hkv, G, Dh)

    kbar = compress_blocks(kb, E)                       # (B,nb,r,Hkv,Dh)
    vbar = compress_blocks(vb, F)

    # --- local: exact causal attention within each block ----------------
    s_loc = jnp.einsum("bnchgd,bnkhd->bhgnck", qb, kb).astype(jnp.float32)
    s_loc = s_loc * scale
    causal = jnp.tril(jnp.ones((c, c), bool))
    s_loc = jnp.where(causal[None, None, None, None], s_loc, NEG_INF)

    # --- global: compressed slots of strictly-previous blocks -----------
    s_glob = jnp.einsum("bnchgd,bmrhd->bhgncmr", qb, kbar).astype(jnp.float32)
    s_glob = s_glob * scale
    blk_vis = (jnp.arange(nb)[:, None] > jnp.arange(nb)[None, :])  # (n_q, m_kv)
    s_glob = jnp.where(blk_vis[None, None, None, :, None, :, None],
                       s_glob, NEG_INF)
    s_glob = s_glob.reshape(*s_glob.shape[:-2], nb * r)

    # --- joint softmax over [own block | compressed prefix] -------------
    s = jnp.concatenate([s_loc, s_glob], axis=-1)       # (B,Hkv,G,nb,c,c+nb*r)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    p_loc, p_glob = p[..., :c], p[..., c:]

    out = jnp.einsum("bhgnck,bnkhd->bnchgd", p_loc, vb)
    vbar_flat = vbar.reshape(B, nb * r, Hkv, Dh)
    out = out + jnp.einsum("bhgncm,bmhd->bnchgd", p_glob, vbar_flat)
    out = out.reshape(B, S, H, Dh)
    if return_residuals:
        m = jnp.max(s, axis=-1)                         # (B,Hkv,G,nb,c)
        denom = jnp.sum(jnp.exp(s - m[..., None]), axis=-1)
        return (out, m.reshape(B, H, S).astype(jnp.float32),
                denom.reshape(B, H, S).astype(jnp.float32))
    return out


def blockwise_causal_prefix_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    comp_k: jax.Array,
    comp_v: jax.Array,
    start_blocks: jax.Array,
    *,
    block_size: int,
    block_slots: int,
    scale: Optional[float] = None,
) -> jax.Array:
    """Chunked-prefill form: a chunk of queries at a NONZERO per-row block
    offset attends [own block, causal | slot-resident compressed prefix].

    q: (B, P, H, Dh) — one prefill chunk, P % block_size == 0, whose row b
    starts at absolute position start_blocks[b]·c; k, v: (B, P, Hkv, Dh) the
    chunk's own keys/values (local, exact attention); comp_k, comp_v:
    (B, M, Hkv, Dh) the cache's compressed slot buffers with the chunk's own
    blocks ALREADY folded in at slot offset start_blocks·r (write first,
    attend after — chunk-internal global visibility then needs no separate
    operand). A query in chunk block j sees compressed slots of absolute
    blocks < start_blocks[b] + j, i.e. slots m with m // r < start + j.

    Identical math to :func:`blockwise_causal_attention` restricted to the
    chunk's rows — the basis of the serving engine's chunked-admission
    byte-parity with monolithic prefill. Memory-bounded like the chunked
    form: query blocks are processed under ``lax.map`` so the (P × M) global
    score tensor is materialized one block at a time.
    """
    B, P, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    c = block_size
    if P % c != 0:
        raise ValueError(f"chunk P={P} must be a multiple of block_size={c}")
    nb = P // c
    r = block_slots
    M = comp_k.shape[1]
    scale_ = scale if scale is not None else Dh ** -0.5
    start = jnp.asarray(start_blocks, jnp.int32)

    qb = q.reshape(B, nb, c, Hkv, G, Dh)
    kb = k.reshape(B, nb, c, Hkv, Dh)
    vb = v.reshape(B, nb, c, Hkv, Dh)
    causal = jnp.tril(jnp.ones((c, c), bool))
    slot_blk = jnp.arange(M) // r                        # owning block of slot

    def one_block(args):
        j, qi, ki, vi = args                             # qi: (B,c,Hkv,G,Dh)
        s_loc = jnp.einsum("bchgd,bkhd->bhgck", qi, ki).astype(jnp.float32)
        s_loc = jnp.where(causal[None, None, None], s_loc * scale_, NEG_INF)
        s_glob = jnp.einsum("bchgd,bmhd->bhgcm", qi,
                            comp_k).astype(jnp.float32)
        vis = slot_blk[None, :] < (start + j)[:, None]   # (B, M)
        s_glob = jnp.where(vis[:, None, None, None, :], s_glob * scale_,
                           NEG_INF)
        s = jnp.concatenate([s_loc, s_glob], axis=-1)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhgck,bkhd->bchgd", p[..., :c], vi)
        out = out + jnp.einsum("bhgcm,bmhd->bchgd", p[..., c:], comp_v)
        return out                                       # (B,c,Hkv,G,Dh)

    outs = jax.lax.map(
        one_block,
        (jnp.arange(nb), jnp.moveaxis(qb, 1, 0), jnp.moveaxis(kb, 1, 0),
         jnp.moveaxis(vb, 1, 0)))                        # (nb,B,c,Hkv,G,Dh)
    return jnp.moveaxis(outs, 0, 1).reshape(B, P, H, Dh)


def masked_decode_attention(
    q_t: jax.Array,           # (B, 1, H, Dh)
    raw_k: jax.Array,         # (B, c, Hkv, Dh) — raw ring buffer
    raw_v: jax.Array,
    comp_k: jax.Array,        # (B, M, Hkv, Dh) — compressed slots
    comp_v: jax.Array,
    loc_ok: jax.Array,        # (B, c) bool — attendable ring positions
    glob_ok: jax.Array,       # (B, M) bool — attendable compressed slots
    *,
    scale: float,
) -> jax.Array:
    """Reference single-token decode attention over [raw ring | compressed
    slots] with per-row validity masks — the pure-jnp einsum twin of the
    fused decode kernel (which receives the same masks as additive biases).
    Pure attention math: cache bookkeeping (ring writes, block folds) lives
    in core/cache.py; backend dispatch lives in parallel/plan.py."""
    B, c, Hkv, Dh = raw_k.shape
    M = comp_k.shape[1]
    H = q_t.shape[2]
    G = H // Hkv
    qg = q_t.reshape(B, Hkv, G, Dh)
    # local scores over the raw ring buffer
    s_loc = jnp.einsum("bhgd,bkhd->bhgk", qg,
                       raw_k).astype(jnp.float32) * scale
    s_loc = jnp.where(loc_ok[:, None, None, :], s_loc, NEG_INF)
    # global scores over compressed slots of completed previous blocks
    s_glob = jnp.einsum("bhgd,bmhd->bhgm", qg,
                        comp_k).astype(jnp.float32) * scale
    s_glob = jnp.where(glob_ok[:, None, None, :], s_glob, NEG_INF)

    s = jnp.concatenate([s_loc, s_glob], axis=-1)
    p = jax.nn.softmax(s, axis=-1).astype(q_t.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p[..., :c], raw_v)
    out = out + jnp.einsum("bhgm,bmhd->bhgd", p[..., c:], comp_v)
    return out.reshape(B, 1, H, Dh)


def blockwise_causal_attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    E: jax.Array,
    F: jax.Array,
    *,
    block_size: int,
    q_chunk_blocks: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Memory-bounded form: identical math, but query blocks are processed in
    chunks with lax.map so the (S × nb·r) global-score tensor is never fully
    materialized. Used for the 32k/500k prefill shapes.

    ``q_chunk_blocks`` is a pure perf knob (chunk granularity of the lax.map;
    the math is chunk-invariant). When left unset it resolves through the
    tuning table (form ``causal_chunked``, bucketed on seq) with a fallback
    to kernels/common.py's DEFAULT_Q_CHUNK_BLOCKS.
    """
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    c = block_size
    if S % c != 0:
        raise ValueError(f"S={S} must be a multiple of block_size={c}")
    nb = S // c
    r = E.shape[-1]
    scale_ = scale if scale is not None else Dh ** -0.5
    if q_chunk_blocks is None:
        from repro.tune import table as tuning
        q_chunk_blocks = tuning.q_chunk_blocks_for(seq=S)
    if nb % q_chunk_blocks != 0:
        q_chunk_blocks = 1
    n_chunks = nb // q_chunk_blocks

    kb = k.reshape(B, nb, c, Hkv, Dh)
    vb = v.reshape(B, nb, c, Hkv, Dh)
    kbar = compress_blocks(kb, E).reshape(B, nb * r, Hkv, Dh)
    vbar = compress_blocks(vb, F).reshape(B, nb * r, Hkv, Dh)
    qc = q.reshape(B, n_chunks, q_chunk_blocks, c, Hkv, G, Dh)
    kc = kb.reshape(B, n_chunks, q_chunk_blocks, c, Hkv, Dh)
    vc = vb.reshape(B, n_chunks, q_chunk_blocks, c, Hkv, Dh)

    causal = jnp.tril(jnp.ones((c, c), bool))
    slot_blk = jnp.arange(nb * r) // r                   # owning block of slot

    def one_chunk(args):
        ci, qi, ki, vi = args                            # qi:(B,qcb,c,Hkv,G,Dh)
        blk_ids = ci * q_chunk_blocks + jnp.arange(q_chunk_blocks)
        s_loc = jnp.einsum("bnchgd,bnkhd->bhgnck", qi, ki).astype(jnp.float32)
        s_loc = jnp.where(causal[None, None, None, None], s_loc * scale_, NEG_INF)
        s_glob = jnp.einsum("bnchgd,bmhd->bhgncm", qi, kbar).astype(jnp.float32)
        vis = blk_ids[:, None] > slot_blk[None, :]       # (qcb, nb*r)
        s_glob = jnp.where(vis[None, None, None, :, None, :], s_glob * scale_,
                           NEG_INF)
        s = jnp.concatenate([s_loc, s_glob], axis=-1)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhgnck,bnkhd->bnchgd", p[..., :c], vi)
        out = out + jnp.einsum("bhgncm,bmhd->bnchgd", p[..., c:], vbar)
        return out                                       # (B,qcb,c,Hkv,G,Dh)

    chunk_ids = jnp.arange(n_chunks)
    outs = jax.lax.map(
        one_chunk,
        (chunk_ids,
         jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
    )                                                    # (n_chunks,B,qcb,c,Hkv,G,Dh)
    outs = jnp.moveaxis(outs, 0, 1)
    return outs.reshape(B, S, H, Dh)
