"""Sequence-parallel Linformer attention (beyond-paper; DESIGN.md §3).

Because the paper's compression K̄ = EᵀK is a LINEAR reduction over the
sequence axis, sharding the sequence across devices costs only a collective
over the (k × d) compressed operands — communication independent of n.
Standard attention under sequence parallelism must ring-exchange O(n·d) of
K/V (ring attention); Linformer needs O(k·d).

Two forms, both exposed as SHARD-LOCAL bodies consumed inside the manual
region that `parallel/plan.py` opens (the plan owns the shard_map specs;
these functions own the per-shard math + collectives):

* :func:`sp_exact_linformer_attention` — the exact (bidirectional) form:
  each device projects its sequence shard with its E/F row block, psums the
  tiny compressed K̄/V̄, then attends its local queries. One psum of
  2·(B, K, Hkv, Dh) bytes.

* :func:`sp_blockwise_causal_attention` — the causal (blockwise) form: each
  device compresses its LOCAL blocks into r slots each, all-gathers the
  compressed prefix (2·(B, (S/c)·r, Hkv, Dh) bytes — the Linformer win: the
  raw causal blocks stay RESIDENT, only the c/r-compressed slots move), and
  attends its local query blocks through the offset (prefix-form) kernel at
  this device's absolute block offset. Training works end to end: the fused
  backward's full-buffer fp32 dk̄/dv̄ accumulators are reduced across shards
  by the all-gather's transpose (a psum-scatter inside the manual region),
  then chained through the local `compress_blocks` VJP.

`seq_parallel_linformer_attention` is the self-contained exact-form
shard_map kept for direct use and the test_distributed parity oracle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import causal as causal_lib
from repro.core import linformer as lin_lib
from repro.parallel.sharding import ParallelCtx, shard_map as _shard_map


# ---------------------------------------------------------------------------
# Shard-local bodies (run inside the plan's manual region)
# ---------------------------------------------------------------------------


def sp_exact_linformer_attention(
    q_l: jax.Array,          # (B, S/sp, H_l, Dh) — this shard's queries
    k_l: jax.Array,          # (B, S/sp, Hkv_l, Dh)
    v_l: jax.Array,
    E_l: jax.Array,          # (S/sp, K) — this shard's E row block
    F_l: jax.Array,
    *,
    seq_axis: str,
    scale: float,
    fused: bool,
) -> jax.Array:
    """Exact-form shard-local body: partial projection over local sequence
    rows, psum of the compressed K̄/V̄, local-query attention. Output stays
    sequence-sharded with zero further communication."""
    if fused:
        from repro.kernels import ops as kernel_ops
        kbar = kernel_ops.fused_seq_projection(k_l, E_l)
        vbar = kernel_ops.fused_seq_projection(v_l, F_l)
    else:
        kbar = jnp.einsum("bshd,sk->bkhd", k_l, E_l.astype(k_l.dtype))
        vbar = jnp.einsum("bshd,sk->bkhd", v_l, F_l.astype(v_l.dtype))
    kbar = jax.lax.psum(kbar, seq_axis)       # (B, K, Hkv, Dh) — tiny
    vbar = jax.lax.psum(vbar, seq_axis)
    if fused:
        return kernel_ops.fused_linformer_attention(q_l, kbar, vbar,
                                                    scale=scale)
    return lin_lib.attend_compressed(q_l, kbar, vbar, scale=scale)


def sp_blockwise_causal_attention(
    q_l: jax.Array,          # (B, S/sp, H_l, Dh) — this shard's queries
    k_l: jax.Array,          # (B, S/sp, Hkv_l, Dh) — resident causal blocks
    v_l: jax.Array,
    E_l: jax.Array,          # (c, r) or (Hkv_l, c, r)
    F_l: jax.Array,
    *,
    seq_axis: str,
    block_size: int,
    block_slots: int,
    scale: float,
    fused: bool,
    backward_impl: str = "fused",
) -> jax.Array:
    """Blockwise-causal shard-local body: compress local blocks, all-gather
    the compressed prefix, attend local queries at this shard's block offset.

    The sequence axis must be sharded CONTIGUOUSLY (shard_map's convention),
    with the local length a multiple of `block_size`: shard d then holds
    absolute blocks [d·nb_l, (d+1)·nb_l). `tiled=True` all-gather
    concatenates shards in axis order, so gathered slot m belongs to
    absolute block m // r — exactly the visibility rule the prefix kernel's
    causality cut applies at start block d·nb_l. Under `jax.grad`, the
    all-gather transposes to a psum-scatter: every shard's full-buffer
    dk̄/dv̄ (fused backward accumulators, exact zeros on slots its queries
    never see) are summed and re-sharded before the local
    `compress_blocks` VJP chains them into dk/dv/dE/dF.
    """
    B, S_l, Hkv, Dh = k_l.shape
    c, r = block_size, block_slots
    if S_l % c != 0:
        raise ValueError(
            f"sequence-parallel shard length {S_l} is not a multiple of the "
            f"attention block size {c}")
    nb_l = S_l // c
    kbar_l = causal_lib.compress_blocks(
        k_l.reshape(B, nb_l, c, Hkv, Dh), E_l).reshape(B, nb_l * r, Hkv, Dh)
    vbar_l = causal_lib.compress_blocks(
        v_l.reshape(B, nb_l, c, Hkv, Dh), F_l).reshape(B, nb_l * r, Hkv, Dh)
    kbar = jax.lax.all_gather(kbar_l, seq_axis, axis=1, tiled=True)
    vbar = jax.lax.all_gather(vbar_l, seq_axis, axis=1, tiled=True)
    start = jax.lax.axis_index(seq_axis) * nb_l
    start_blocks = jnp.broadcast_to(start, (B,))
    if fused:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.fused_chunk_prefill_attention(
            q_l, k_l, v_l, kbar, vbar, start_blocks, block_size=c,
            block_slots=r, scale=scale, backward_impl=backward_impl)
    return causal_lib.blockwise_causal_prefix_attention(
        q_l, k_l, v_l, kbar, vbar, start_blocks, block_size=c,
        block_slots=r, scale=scale)


# ---------------------------------------------------------------------------
# Self-contained exact-form shard_map (kept: direct use + parity oracle)
# ---------------------------------------------------------------------------


def seq_parallel_linformer_attention(
    q: jax.Array,            # (B, S, H, Dh)
    k: jax.Array,            # (B, S, Hkv, Dh)
    v: jax.Array,
    E: jax.Array,            # (S, K) — row-sharded with the sequence
    F: jax.Array,
    ctx: ParallelCtx,
    *,
    seq_axis: Optional[str] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Exact Linformer attention with the sequence axis sharded over
    `seq_axis` (default: the model axis). Returns (B, S, H, Dh) sharded the
    same way. Communication: one psum of 2·(B, K, Hkv, Dh)."""
    axis = seq_axis or ctx.model_axis
    mesh = ctx.mesh
    assert mesh is not None
    scale_ = scale if scale is not None else q.shape[-1] ** -0.5

    def body(q_l, k_l, v_l, E_l, F_l):
        return sp_exact_linformer_attention(
            q_l, k_l, v_l, E_l, F_l, seq_axis=axis, scale=scale_,
            fused=False)

    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis, None, None), P(None, axis, None, None),
                  P(None, axis, None, None), P(axis, None), P(axis, None)),
        out_specs=P(None, axis, None, None),
        check_vma=False,
    )(q, k, v, E, F)


# ---------------------------------------------------------------------------
# Communication-cost model (docs/parallelism.md §Comm bytes)
# ---------------------------------------------------------------------------


def seq_parallel_comm_bytes(n: int, k: int, d_total: int, shards: int,
                            dtype_bytes: int = 2) -> Tuple[int, int]:
    """(linformer_bytes, ring_attention_bytes) per device for one layer of
    the EXACT form — the collective-cost comparison quoted in
    EXPERIMENTS.md §Perf: a psum of K̄/V̄ vs a ring exchange of raw K/V."""
    lin = 2 * k * d_total * dtype_bytes                   # psum of K̄,V̄
    ring = 2 * (n // shards) * d_total * (shards - 1) * dtype_bytes
    return lin, ring


def blockwise_sp_comm_bytes(n: int, block_size: int, block_slots: int,
                            d_total: int, shards: int,
                            dtype_bytes: int = 2) -> Tuple[int, int]:
    """(linformer_bytes, ring_attention_bytes) per device for one layer of
    the CAUSAL (blockwise) form under sequence parallelism: the all-gather
    moves only the compressed prefix — 2·(n/c)·r·d bytes, a c/r-fold
    reduction over ring-exchanging the raw K/V — while the local causal
    blocks never leave their shard."""
    m_total = (n // block_size) * block_slots
    lin = 2 * m_total * d_total * dtype_bytes             # all-gather of k̄,v̄
    ring = 2 * (n // shards) * d_total * (shards - 1) * dtype_bytes
    return lin, ring
