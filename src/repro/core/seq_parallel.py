"""Sequence-parallel Linformer projection (beyond-paper; DESIGN.md §3).

Because the paper's compression K̄ = EᵀK is a LINEAR reduction over the
sequence axis, sharding the sequence across devices costs only a psum of the
(k × d) partial projections — communication independent of n. Standard
attention under sequence parallelism must ring-exchange O(n·d) of K/V
(ring attention); Linformer needs O(k·d).

`seq_parallel_linformer_attention` shard_maps the full exact-form attention
with S sharded: each device projects its sequence shard with its E/F row
block, psums the tiny compressed K̄/V̄, then attends its local queries — the
output stays sequence-sharded with zero further communication.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import linformer as lin_lib
from repro.parallel.sharding import ParallelCtx, shard_map as _shard_map



def seq_parallel_linformer_attention(
    q: jax.Array,            # (B, S, H, Dh)
    k: jax.Array,            # (B, S, Hkv, Dh)
    v: jax.Array,
    E: jax.Array,            # (S, K) — row-sharded with the sequence
    F: jax.Array,
    ctx: ParallelCtx,
    *,
    seq_axis: Optional[str] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Exact Linformer attention with the sequence axis sharded over
    `seq_axis` (default: the model axis). Returns (B, S, H, Dh) sharded the
    same way. Communication: one psum of 2·(B, K, Hkv, Dh)."""
    axis = seq_axis or ctx.model_axis
    mesh = ctx.mesh
    assert mesh is not None

    def body(q_l, k_l, v_l, E_l, F_l):
        # local partial projection over this device's sequence rows
        kbar = jnp.einsum("bshd,sk->bkhd", k_l, E_l.astype(k_l.dtype))
        vbar = jnp.einsum("bshd,sk->bkhd", v_l, F_l.astype(v_l.dtype))
        kbar = jax.lax.psum(kbar, axis)       # (B, K, Hkv, Dh) — tiny
        vbar = jax.lax.psum(vbar, axis)
        return lin_lib.attend_compressed(q_l, kbar, vbar, scale=scale)

    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis, None, None), P(None, axis, None, None),
                  P(None, axis, None, None), P(axis, None), P(axis, None)),
        out_specs=P(None, axis, None, None),
        check_vma=False,
    )(q, k, v, E, F)


def seq_parallel_comm_bytes(n: int, k: int, d_total: int, shards: int,
                            dtype_bytes: int = 2) -> Tuple[int, int]:
    """(linformer_bytes, ring_attention_bytes) per device for one layer —
    the collective-cost comparison quoted in EXPERIMENTS.md §Perf."""
    lin = 2 * k * d_total * dtype_bytes                   # psum of K̄,V̄
    ring = 2 * (n // shards) * d_total * (shards - 1) * dtype_bytes
    return lin, ring
