"""Linformer attention (Wang et al., 2020) — exact bidirectional form (Eq. 7)
plus E/F parameter management with the paper's three sharing strategies.

The exact form computes, per head i:

    head_i = softmax( Q Wq (E_i K Wk)^T / sqrt(d) ) · (F_i V Wv)

with E_i, F_i ∈ R^{n×k}. Cost: O(n·k) time/space instead of O(n²).

Sharing strategies (§4):
  * none      — distinct E_i, F_i per layer AND per head
  * headwise  — per layer: one E and one F shared across heads
  * kv        — per layer: a single E = F shared across heads
  * layerwise — one E = F for the whole network (all layers, heads, K and V)

Parameter layout (returned by :func:`init_linformer_params`):
  {"shared": {...}}     arrays without a layer axis (layerwise sharing)
  {"per_layer": {...}}  arrays with leading L axis (stacked for lax.scan)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig, LinformerConfig
from repro.core import projections as proj


def _ef_shape(cfg: AttentionConfig, n: int, k: int) -> Tuple[int, ...]:
    lin = cfg.linformer
    if lin.sharing == "none":
        return (cfg.num_kv_heads, n, k)
    return (n, k)


def init_linformer_params(
    rng: jax.Array,
    cfg: AttentionConfig,
    *,
    num_layers: int,
    max_seq: int,
    dtype=jnp.float32,
) -> Dict:
    """Create E/F per the configured sharing mode.

    Exact form ('linformer'): shapes use (n=max_seq, k).
    Causal form ('linformer_causal'): shapes use (c=block_size, r=block_slots)
    — the blockwise/conv projection weights.
    """
    lin = cfg.linformer
    if cfg.kind == "linformer_causal":
        n, k = lin.block_size, lin.block_slots
    else:
        n, k = max_seq, lin.k
    # JL-style init: N(0, 1/k) matches the theorem's construction and keeps
    # projected keys at the same scale as raw keys.
    std = 1.0 / jnp.sqrt(k)

    def mk(key, shape):
        return (jax.random.normal(key, shape) * std).astype(dtype)

    r_e, r_f = jax.random.split(rng)
    sharing = lin.sharing
    if sharing == "layerwise":
        return {"shared": {"E": mk(r_e, _ef_shape(cfg, n, k))}}
    if sharing == "kv":
        return {"per_layer": {"E": mk(r_e, (num_layers,) + _ef_shape(cfg, n, k))}}
    if sharing == "headwise":
        return {
            "per_layer": {
                "E": mk(r_e, (num_layers,) + _ef_shape(cfg, n, k)),
                "F": mk(r_f, (num_layers,) + _ef_shape(cfg, n, k)),
            }
        }
    if sharing == "none":
        return {
            "per_layer": {
                "E": mk(r_e, (num_layers,) + _ef_shape(cfg, n, k)),
                "F": mk(r_f, (num_layers,) + _ef_shape(cfg, n, k)),
            }
        }
    raise ValueError(f"unknown sharing mode {sharing!r}")


def num_projection_matrices(cfg: AttentionConfig, num_layers: int) -> int:
    """Distinct projection matrices implied by the sharing mode — paper §4:
    12L/12H gives headwise=24, kv=12, layerwise=1."""
    sharing = cfg.linformer.sharing
    if sharing == "layerwise":
        return 1
    if sharing == "kv":
        return num_layers
    if sharing == "headwise":
        return 2 * num_layers
    return 2 * num_layers * cfg.num_kv_heads


def resolve_ef(
    lin_params: Dict,
    layer_slice: Optional[Dict],
) -> Tuple[jax.Array, jax.Array]:
    """Return (E, F) for one layer given the param layout.

    `layer_slice` is the per-layer entry (leading L axis already indexed away,
    e.g. inside a scan body); for layerwise sharing it is None/ignored.
    """
    if "shared" in lin_params:
        E = lin_params["shared"]["E"]
        return E, E
    assert layer_slice is not None, "per-layer params need a layer slice"
    E = layer_slice["E"]
    F = layer_slice.get("F", E)
    return E, F


# ---------------------------------------------------------------------------
# Exact (bidirectional) Linformer attention — paper Eq. 7
# ---------------------------------------------------------------------------


def project_kv(
    k: jax.Array,
    v: jax.Array,
    E: jax.Array,
    F: jax.Array,
    *,
    kind: str = "linear",
) -> Tuple[jax.Array, jax.Array]:
    """Compress the sequence axis of K and V.

    k, v: (B, S, Hkv, Dh).  E/F per `kind`:
      linear: (S, K) or (Hkv, S, K)  — slices rows to S if stored for max_seq
      conv/pool: (c, r) blockwise weights
    Returns (B, K, Hkv, Dh) compressed keys/values.
    """
    if kind == "linear":
        S = k.shape[1]
        # E is stored for max_seq; rows beyond the batch's S are dropped
        # (positions that do not exist contribute nothing to the mixture).
        Es = E[..., :S, :] if E.shape[-2] != S else E
        Fs = F[..., :S, :] if F.shape[-2] != S else F
        return proj.linear_project(k, Es), proj.linear_project(v, Fs)
    if kind in ("conv", "pool"):
        return proj.blockwise_project(k, E), proj.blockwise_project(v, F)
    raise ValueError(f"unknown projection kind {kind!r}")


def attend_compressed(
    q: jax.Array,
    kbar: jax.Array,
    vbar: jax.Array,
    *,
    scale: Optional[float] = None,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """softmax(q·k̄ᵀ/√d)·v̄ with GQA-grouped heads.

    q: (B, S, H, Dh); kbar/vbar: (B, K, Hkv, Dh); H % Hkv == 0.
    kv_mask: optional (K,) or (B, K) bool — True = attendable slot.
    Returns (B, S, H, Dh).
    """
    B, S, H, Dh = q.shape
    Hkv = kbar.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    qg = q.reshape(B, S, Hkv, G, Dh)
    # scores: (B, Hkv, G, S, K) in fp32 for a stable softmax
    s = jnp.einsum("bshgd,bkhd->bhgsk", qg, kbar).astype(jnp.float32) * scale
    if kv_mask is not None:
        m = kv_mask if kv_mask.ndim == 1 else kv_mask[:, None, None, None, :]
        s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgsk,bkhd->bshgd", p, vbar)
    return out.reshape(B, S, H, Dh)


def exact_linformer_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    E: jax.Array,
    F: jax.Array,
    *,
    kind: str = "linear",
    scale: Optional[float] = None,
    key_padding_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """The paper's linear self-attention (Eq. 7), bidirectional.

    key_padding_mask: optional (B, S) bool, True = real token. Padded keys
    are zeroed *before* compression (compressed slots then simply receive
    less mass; there is no per-slot mask — slots mix positions).
    """
    if key_padding_mask is not None:
        keep = key_padding_mask[:, :, None, None].astype(k.dtype)
        k = k * keep
        v = v * keep
    kbar, vbar = project_kv(k, v, E, F, kind=kind)
    return attend_compressed(q, kbar, vbar, scale=scale)
