"""Spectrum analysis of the context-mapping matrix P (paper §3, Figure 1) and
empirical verification of the JL approximation (Theorems 1–2).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def context_mapping(
    q: jax.Array, k: jax.Array, *, scale: Optional[float] = None,
    causal: bool = False,
) -> jax.Array:
    """P = softmax(QKᵀ/√d) for one head. q,k: (S, Dh) -> (S, S)."""
    S, Dh = q.shape
    scale_ = scale if scale is not None else Dh ** -0.5
    a = (q @ k.T).astype(jnp.float32) * scale_
    if causal:
        a = jnp.where(jnp.tril(jnp.ones((S, S), bool)), a, -1e30)
    return jax.nn.softmax(a, axis=-1)


def cumulative_spectrum(P: jax.Array) -> jax.Array:
    """Normalized cumulative singular values of P (Figure 1, Y-axis).

    Returns (S,) monotone in [0,1]: out[i] = sum(sigma[:i+1]) / sum(sigma).
    """
    s = jnp.linalg.svd(P.astype(jnp.float32), compute_uv=False)
    c = jnp.cumsum(s)
    return c / c[-1]


def energy_at_rank(P: jax.Array, rank: int) -> jax.Array:
    """Figure 1 (right): cumulative singular-value mass at a given rank."""
    return cumulative_spectrum(P)[rank - 1]


def rank_for_energy(P: jax.Array, energy: float = 0.9) -> jax.Array:
    """Smallest rank capturing `energy` of the spectrum mass."""
    spec = cumulative_spectrum(P)
    return jnp.argmax(spec >= energy) + 1


def jl_projection_error(
    rng: jax.Array, P: jax.Array, w: jax.Array, k: int,
) -> jax.Array:
    """Relative error ||P RᵀR w − P w|| / ||P w|| for the Theorem-1
    construction (R ∈ R^{k×n}, entries N(0, 1/k))."""
    n = P.shape[0]
    R = jax.random.normal(rng, (k, n), jnp.float32) / jnp.sqrt(k)
    ref = P @ w
    approx = P @ (R.T @ (R @ w))
    return jnp.linalg.norm(approx - ref) / jnp.maximum(jnp.linalg.norm(ref), 1e-30)


def theorem2_error(
    rng: jax.Array, a_row: jax.Array, V: jax.Array, k: int,
) -> Tuple[jax.Array, jax.Array]:
    """Relative error of softmax(w Eᵀ) F V vs softmax(w) V (Theorem 2) with
    the E = δR, F = e^{-δ}R construction. a_row: (n,) one row of QKᵀ/√d;
    V: (n, d). Returns (error, reference_norm)."""
    n = a_row.shape[0]
    R = jax.random.normal(rng, (k, n), jnp.float32) / jnp.sqrt(k)
    delta = 1.0 / n
    E = delta * R            # (k, n) — acts as E^T in paper notation
    F = jnp.exp(-delta) * R
    ref = jax.nn.softmax(a_row) @ V
    approx = jax.nn.softmax(a_row @ E.T) @ (F @ V)
    err = jnp.linalg.norm(approx - ref)
    return err, jnp.linalg.norm(ref)
