"""Sequence-axis projection operators (the E/F of the paper, Eq. 7).

Three families, matching the paper §4 "Additional Efficiency Techniques /
General projections":

* ``linear``  — dense learned E ∈ R^{n×k}; K̄ = EᵀK. The paper's default.
* ``conv``    — 1-D convolution along the sequence with kernel = stride = c,
                r learned output slots per window (r=1 ⇒ the paper's n/k conv).
                Structurally this is a *block-diagonal* E with shared blocks.
* ``pool``    — mean pooling with kernel = stride = c (parameter-free).

The blockwise operators are also the building block of the causal variant
(DESIGN.md §4): a window's output slots depend only on that window's inputs,
so block-granular causality is preserved.

Shape conventions: sequence tensors are (B, S, H, Dh); projections act on S.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_project(x: jax.Array, E: jax.Array) -> jax.Array:
    """Dense sequence projection K̄ = EᵀK (paper Eq. 7).

    Args:
      x: (B, S, H, Dh) keys or values.
      E: (S, K) shared across heads, or (H, S, K) per-head.
    Returns:
      (B, K, H, Dh)
    """
    if E.ndim == 2:
        return jnp.einsum("bshd,sk->bkhd", x, E.astype(x.dtype))
    if E.ndim == 3:
        return jnp.einsum("bshd,hsk->bkhd", x, E.astype(x.dtype))
    raise ValueError(f"E must be (S,K) or (H,S,K), got {E.shape}")


def blockwise_project(x: jax.Array, W: jax.Array) -> jax.Array:
    """Conv-style projection: kernel = stride = c, r output slots per window.

    Args:
      x: (B, S, H, Dh) with S % c == 0.
      W: (c, r) shared across heads, or (H, c, r) per-head.
    Returns:
      (B, (S//c)*r, H, Dh) — window-major slot order.
    """
    per_head = W.ndim == 3
    c, r = (W.shape[1], W.shape[2]) if per_head else (W.shape[0], W.shape[1])
    B, S, H, Dh = x.shape
    if S % c != 0:
        raise ValueError(f"seq len {S} not divisible by block size {c}")
    nb = S // c
    xb = x.reshape(B, nb, c, H, Dh)
    if per_head:
        out = jnp.einsum("bnchd,hcr->bnrhd", xb, W.astype(x.dtype))
    else:
        out = jnp.einsum("bnchd,cr->bnrhd", xb, W.astype(x.dtype))
    return out.reshape(B, nb * r, H, Dh)


def pool_weights(c: int, r: int = 1, dtype=jnp.float32) -> jax.Array:
    """Mean-pool projection weights: each of r slots averages a c/r sub-window."""
    if c % r != 0:
        raise ValueError(f"block {c} not divisible by slots {r}")
    sub = c // r
    w = jnp.zeros((c, r), dtype)
    for j in range(r):
        w = w.at[j * sub:(j + 1) * sub, j].set(1.0 / sub)
    return w


def conv_as_linear(W: jax.Array, n: int) -> jax.Array:
    """Materialize the block-diagonal E ∈ R^{n×k} equivalent to a blockwise
    projection — used by tests/oracles to show the conv variant is a special
    case of the paper's linear E."""
    c, r = W.shape
    assert n % c == 0
    nb = n // c
    E = jnp.zeros((n, nb * r), W.dtype)
    for b in range(nb):
        E = E.at[b * c:(b + 1) * c, b * r:(b + 1) * r].set(W)
    return E


def effective_k(k: int, k_decay: float, layer_idx: int, num_layers: int) -> int:
    """Non-uniform projected dimension (paper §4): higher layers have more
    skewed spectra, so k can shrink with depth. Linear interpolation from k at
    layer 0 to ceil(k * k_decay) at the last layer, floored at 1."""
    if num_layers <= 1 or k_decay >= 1.0:
        return k
    frac = layer_idx / (num_layers - 1)
    kk = k * (1.0 - (1.0 - k_decay) * frac)
    return max(1, int(-(-kk // 1)))  # ceil
