"""Decode-time caches.

Two cache families:

* :func:`init_compressed_cache` — the Linformer-causal cache. Per layer it
  holds (a) a raw ring buffer for the current (incomplete) block of K/V and
  (b) a compressed slot buffer: r slots per completed block. Total width for a
  context of length n is c + r·⌊n/c⌋ — e.g. 32k context @ c=256, r=16 becomes
  2304 slots vs 32768 (14× smaller); 512k context becomes 33k slots (16×).

* :func:`init_full_cache` — the standard-attention baseline: full (S, Hkv, Dh)
  K/V per layer.

Caches are plain dicts of arrays (pytrees); layer axis leads so scanned layers
carry their slice through ``lax.scan``.

Per-row positions: the cache carries a ``lengths`` (B,) int32 vector — one
position counter per batch row — instead of a shared scalar. Every row of a
decode batch may sit at its own position (the continuous-batching scheduler
admits/evicts rows between decode chunks, so rows are never aligned); masks,
ring-buffer writes and the block fold are all per-row. The decode attention
functions still accept a scalar ``t`` (broadcast to every row), which is the
legacy shared-position behaviour.

Chunked prefill: :func:`compressed_prefill_chunk` / :func:`full_prefill_chunk`
are the multi-token siblings of the decode steps — they commit one P-token
prefill chunk per row at the row's own offset (mid-prefill cache writes at
arbitrary per-row positions; for the compressed cache every chunk boundary
is a block-fold boundary, so chunks fold straight into compressed slots).
The serving scheduler uses them to stream long prompts into pool slots
between decode chunks.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.causal import NEG_INF


def rowwise_t(t: jax.Array, batch: int) -> jax.Array:
    """Broadcast a scalar position to a (B,) per-row position vector."""
    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 0:
        return jnp.broadcast_to(t, (batch,))
    return t


def _row_update(buf: jax.Array, new: jax.Array, start: jax.Array) -> jax.Array:
    """Per-row dynamic_update_slice along axis 1: buf (B, N, ...), new
    (B, n, ...), start (B,) int32 — row b gets new[b] written at start[b]."""
    return jax.vmap(
        lambda b, u, s: jax.lax.dynamic_update_slice_in_dim(b, u, s, axis=0)
    )(buf, new, start)


# ---------------------------------------------------------------------------
# Compressed (Linformer-causal) cache
# ---------------------------------------------------------------------------


def compressed_cache_spec(
    *, num_layers: int, batch: int, max_seq: int, block_size: int,
    block_slots: int, num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    max_blocks = max_seq // block_size
    M = max_blocks * block_slots
    kv = lambda *s: jax.ShapeDtypeStruct(s, dtype)
    return {
        "raw_k": kv(num_layers, batch, block_size, num_kv_heads, head_dim),
        "raw_v": kv(num_layers, batch, block_size, num_kv_heads, head_dim),
        "comp_k": kv(num_layers, batch, M, num_kv_heads, head_dim),
        "comp_v": kv(num_layers, batch, M, num_kv_heads, head_dim),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def init_compressed_cache(**kw) -> Dict[str, jax.Array]:
    spec = compressed_cache_spec(**kw)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}


def compressed_decode_attention(
    q_t: jax.Array,           # (B, 1, H, Dh) — rope already applied at pos t
    k_t: jax.Array,           # (B, 1, Hkv, Dh)
    v_t: jax.Array,
    layer_cache: Dict[str, jax.Array],   # per-layer slices: raw_k (B,c,Hkv,Dh), comp_k (B,M,Hkv,Dh)
    E: jax.Array,             # (c, r) or (Hkv, c, r)
    F: jax.Array,
    t: jax.Array,             # () or (B,) int32 — tokens already cached per row
    *,
    scale: Optional[float] = None,
    plan=None,                # AttentionPlan | backend string | None
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step of blockwise-causal Linformer attention.

    Appends (k_t, v_t) at each row's position t[b], attends [raw block ≤ t[b]
    | compressed prefix blocks], and folds a row's block into r compressed
    slots when t[b] completes it. Every mask, ring-buffer write and block
    fold is PER ROW — rows of a continuous batch sit at unequal positions.
    A scalar t broadcasts to all rows (the legacy shared-position form).
    Returns (out (B,1,H,Dh), updated per-layer cache).

    The attention math itself dispatches through `plan`
    (parallel/plan.py AttentionPlan; a bare backend string resolves to a
    single-device plan): the fused plan routes through the Pallas decode
    kernel — GQA group axis folded into the kernel's query axis, raw +
    compressed caches as two pinned operands (per-shard slots under tensor
    parallelism), slot validity as per-row additive score biases. Cache
    bookkeeping here is identical for every plan.
    """
    from repro.parallel.plan import as_plan
    plan = as_plan(plan)
    raw_k, raw_v = layer_cache["raw_k"], layer_cache["raw_v"]
    comp_k, comp_v = layer_cache["comp_k"], layer_cache["comp_v"]
    B, c, Hkv, Dh = raw_k.shape
    M = comp_k.shape[1]
    r = E.shape[-1]
    scale_ = scale if scale is not None else Dh ** -0.5

    t = rowwise_t(t, B)
    pos = jnp.mod(t, c)                         # (B,)
    blk = t // c                                # (B,)

    raw_k = _row_update(raw_k, k_t.astype(raw_k.dtype), pos)
    raw_v = _row_update(raw_v, v_t.astype(raw_v.dtype), pos)

    loc_ok = jnp.arange(c)[None, :] <= pos[:, None]         # (B, c)
    glob_ok = jnp.arange(M)[None, :] < (blk * r)[:, None]   # (B, M)
    out = plan.decode_attention(q_t, raw_k, raw_v, comp_k, comp_v,
                                loc_ok, glob_ok, scale=scale_)

    # fold a row's block into its compressed slots when it completes
    # (pos[b] == c-1). Compute unconditionally (O(c·r·Dh·Hkv), tiny) and
    # commit per row via select — cheaper than lax.cond's control flow.
    if E.ndim == 2:
        new_ks = jnp.einsum("bchd,cr->brhd", raw_k, E.astype(raw_k.dtype))
        new_vs = jnp.einsum("bchd,cr->brhd", raw_v, F.astype(raw_v.dtype))
    else:
        new_ks = jnp.einsum("bchd,hcr->brhd", raw_k, E.astype(raw_k.dtype))
        new_vs = jnp.einsum("bchd,hcr->brhd", raw_v, F.astype(raw_v.dtype))
    done = (pos == (c - 1))[:, None, None, None]
    comp_k_new = _row_update(comp_k, new_ks, blk * r)
    comp_v_new = _row_update(comp_v, new_vs, blk * r)
    comp_k = jnp.where(done, comp_k_new, comp_k)
    comp_v = jnp.where(done, comp_v_new, comp_v)

    return out, {"raw_k": raw_k, "raw_v": raw_v,
                 "comp_k": comp_k, "comp_v": comp_v}


def compressed_prefill_chunk(
    q: jax.Array,             # (B, P, H, Dh) — one prefill chunk, rope applied
    k: jax.Array,             # (B, P, Hkv, Dh)
    v: jax.Array,
    layer_cache: Dict[str, jax.Array],
    E: jax.Array,             # (c, r) or (Hkv, c, r)
    F: jax.Array,
    t0: jax.Array,            # (B,) int32 — row's current length, multiple of c
    *,
    scale: Optional[float] = None,
    plan=None,                # AttentionPlan | backend string | None
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunked-prefill step of blockwise-causal Linformer attention.

    Mid-prefill cache write at an arbitrary PER-ROW offset: row b's chunk
    covers absolute positions [t0[b], t0[b] + P); every chunk boundary is a
    block-fold boundary (t0 and P are multiples of c), so the chunk's P/c
    blocks fold straight into r compressed slots each, written at slot offset
    (t0[b] // c)·r — the raw ring buffer is untouched (it only ever holds the
    current incomplete block, and a chunk never ends mid-block; remainder
    tokens go through the decode path). Attention then reads the UPDATED slot
    buffer: [own block, causal | compressed slots of absolute blocks
    < t0//c + j] — identical math to the monolithic prefill forward when the
    cache dtype matches the activation dtype. With a lower-precision cache
    (e.g. bf16 under fp32 compute) earlier chunks' slots are read back
    cache-rounded, where the monolithic forward attends them at full
    precision and only rounds when materializing the cache — the standard
    chunked-prefill tradeoff.

    Rows whose chunk is partially padded (n_valid < P, whole padded blocks at
    the END) write garbage slots beyond their valid blocks; those slots are
    never visible (visibility is bounded by the row's committed length) and
    are overwritten by the next chunk or by the decode-time block fold before
    visibility reaches them, so no masking of the write is needed.

    Returns (out (B, P, H, Dh), updated per-layer cache).
    """
    from repro.parallel.plan import as_plan
    plan = as_plan(plan)
    raw_k, raw_v = layer_cache["raw_k"], layer_cache["raw_v"]
    comp_k, comp_v = layer_cache["comp_k"], layer_cache["comp_v"]
    B, P, Hkv, Dh = k.shape
    c = raw_k.shape[1]
    r = E.shape[-1]
    scale_ = scale if scale is not None else q.shape[-1] ** -0.5
    if P % c != 0:
        raise ValueError(f"prefill chunk P={P} not a multiple of block {c}")
    nb = P // c

    from repro.core.causal import compress_blocks
    kbar = compress_blocks(k.reshape(B, nb, c, Hkv, Dh), E)
    vbar = compress_blocks(v.reshape(B, nb, c, Hkv, Dh), F)
    t0 = rowwise_t(t0, B)
    slot0 = (t0 // c) * r
    comp_k = _row_update(comp_k, kbar.reshape(B, nb * r, Hkv, Dh)
                         .astype(comp_k.dtype), slot0)
    comp_v = _row_update(comp_v, vbar.reshape(B, nb * r, Hkv, Dh)
                         .astype(comp_v.dtype), slot0)

    start_blocks = t0 // c
    out = plan.chunk_prefill_attention(
        q, k, v, comp_k, comp_v, start_blocks,
        block_size=c, block_slots=r, scale=scale_)
    return out, {"raw_k": raw_k, "raw_v": raw_v,
                 "comp_k": comp_k, "comp_v": comp_v}


# ---------------------------------------------------------------------------
# Full KV cache (standard-attention baseline)
# ---------------------------------------------------------------------------


def full_cache_spec(
    *, num_layers: int, batch: int, max_seq: int, num_kv_heads: int,
    head_dim: int, dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    kv = lambda *s: jax.ShapeDtypeStruct(s, dtype)
    return {
        "k": kv(num_layers, batch, max_seq, num_kv_heads, head_dim),
        "v": kv(num_layers, batch, max_seq, num_kv_heads, head_dim),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def init_full_cache(**kw) -> Dict[str, jax.Array]:
    spec = full_cache_spec(**kw)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}


def full_decode_attention(
    q_t: jax.Array,           # (B, 1, H, Dh)
    k_t: jax.Array,           # (B, 1, Hkv, Dh)
    v_t: jax.Array,
    layer_cache: Dict[str, jax.Array],   # k/v: (B, S, Hkv, Dh)
    t: jax.Array,             # () or (B,) int32 per-row positions
    *,
    scale: Optional[float] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step of standard causal attention with a full KV cache.
    Writes and masks are per row; a scalar t broadcasts to all rows."""
    ck, cv = layer_cache["k"], layer_cache["v"]
    B, S, Hkv, Dh = ck.shape
    H = q_t.shape[2]
    G = H // Hkv
    scale_ = scale if scale is not None else Dh ** -0.5
    t = rowwise_t(t, B)
    ck = _row_update(ck, k_t.astype(ck.dtype), t)
    cv = _row_update(cv, v_t.astype(cv.dtype), t)
    qg = q_t.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, ck).astype(jnp.float32) * scale_
    ok = jnp.arange(S)[None, :] <= t[:, None]               # (B, S)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q_t.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", p, cv).reshape(B, 1, H, Dh)
    return out, {"k": ck, "v": cv}


def full_prefill_chunk(
    q: jax.Array,             # (B, P, H, Dh)
    k: jax.Array,             # (B, P, Hkv, Dh)
    v: jax.Array,
    layer_cache: Dict[str, jax.Array],   # k/v: (B, S, Hkv, Dh)
    t0: jax.Array,            # (B,) int32 — row's current length
    *,
    scale: Optional[float] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunked-prefill step of standard causal attention with a full KV
    cache: row b's chunk is written at positions [t0[b], t0[b] + P) and each
    query i attends cache positions ≤ t0[b] + i. Padded tail tokens
    (n_valid < P) write garbage the decode path overwrites position-by-
    position before its mask can reach them."""
    ck, cv = layer_cache["k"], layer_cache["v"]
    B, S, Hkv, Dh = ck.shape
    P = q.shape[1]
    H = q.shape[2]
    G = H // Hkv
    scale_ = scale if scale is not None else Dh ** -0.5
    t0 = rowwise_t(t0, B)
    ck = _row_update(ck, k.astype(ck.dtype), t0)
    cv = _row_update(cv, v.astype(cv.dtype), t0)
    qg = q.reshape(B, P, Hkv, G, Dh)
    s = jnp.einsum("bphgd,bshd->bhgps", qg, ck).astype(jnp.float32) * scale_
    qpos = t0[:, None] + jnp.arange(P)[None, :]              # (B, P)
    ok = jnp.arange(S)[None, None, :] <= qpos[:, :, None]    # (B, P, S)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgps,bshd->bphgd", p, cv).reshape(B, P, H, Dh)
    return out, {"k": ck, "v": cv}
