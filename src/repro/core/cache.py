"""Decode-time caches.

Two cache families:

* :func:`init_compressed_cache` — the Linformer-causal cache. Per layer it
  holds (a) a raw ring buffer for the current (incomplete) block of K/V and
  (b) a compressed slot buffer: r slots per completed block. Total width for a
  context of length n is c + r·⌊n/c⌋ — e.g. 32k context @ c=256, r=16 becomes
  2304 slots vs 32768 (14× smaller); 512k context becomes 33k slots (16×).

* :func:`init_full_cache` — the standard-attention baseline: full (S, Hkv, Dh)
  K/V per layer.

Caches are plain dicts of arrays (pytrees); layer axis leads so scanned layers
carry their slice through ``lax.scan``.

Per-row positions: the cache carries a ``lengths`` (B,) int32 vector — one
position counter per batch row — instead of a shared scalar. Every row of a
decode batch may sit at its own position (the continuous-batching scheduler
admits/evicts rows between decode chunks, so rows are never aligned); masks,
ring-buffer writes and the block fold are all per-row. The decode attention
functions still accept a scalar ``t`` (broadcast to every row), which is the
legacy shared-position behaviour.

Chunked prefill: :func:`compressed_prefill_chunk` / :func:`full_prefill_chunk`
are the multi-token siblings of the decode steps — they commit one P-token
prefill chunk per row at the row's own offset (mid-prefill cache writes at
arbitrary per-row positions; for the compressed cache every chunk boundary
is a block-fold boundary, so chunks fold straight into compressed slots).
The serving scheduler uses them to stream long prompts into pool slots
between decode chunks.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.causal import NEG_INF


def rowwise_t(t: jax.Array, batch: int) -> jax.Array:
    """Broadcast a scalar position to a (B,) per-row position vector."""
    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 0:
        return jnp.broadcast_to(t, (batch,))
    return t


def _row_update(buf: jax.Array, new: jax.Array, start: jax.Array) -> jax.Array:
    """Per-row dynamic_update_slice along axis 1: buf (B, N, ...), new
    (B, n, ...), start (B,) int32 — row b gets new[b] written at start[b]."""
    return jax.vmap(
        lambda b, u, s: jax.lax.dynamic_update_slice_in_dim(b, u, s, axis=0)
    )(buf, new, start)


# ---------------------------------------------------------------------------
# Compressed (Linformer-causal) cache
# ---------------------------------------------------------------------------


def compressed_cache_spec(
    *, num_layers: int, batch: int, max_seq: int, block_size: int,
    block_slots: int, num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    max_blocks = max_seq // block_size
    M = max_blocks * block_slots
    kv = lambda *s: jax.ShapeDtypeStruct(s, dtype)
    return {
        "raw_k": kv(num_layers, batch, block_size, num_kv_heads, head_dim),
        "raw_v": kv(num_layers, batch, block_size, num_kv_heads, head_dim),
        "comp_k": kv(num_layers, batch, M, num_kv_heads, head_dim),
        "comp_v": kv(num_layers, batch, M, num_kv_heads, head_dim),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def init_compressed_cache(**kw) -> Dict[str, jax.Array]:
    spec = compressed_cache_spec(**kw)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}


def compressed_decode_attention(
    q_t: jax.Array,           # (B, 1, H, Dh) — rope already applied at pos t
    k_t: jax.Array,           # (B, 1, Hkv, Dh)
    v_t: jax.Array,
    layer_cache: Dict[str, jax.Array],   # per-layer slices: raw_k (B,c,Hkv,Dh), comp_k (B,M,Hkv,Dh)
    E: jax.Array,             # (c, r) or (Hkv, c, r)
    F: jax.Array,
    t: jax.Array,             # () or (B,) int32 — tokens already cached per row
    *,
    scale: Optional[float] = None,
    plan=None,                # AttentionPlan | backend string | None
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step of blockwise-causal Linformer attention.

    Appends (k_t, v_t) at each row's position t[b], attends [raw block ≤ t[b]
    | compressed prefix blocks], and folds a row's block into r compressed
    slots when t[b] completes it. Every mask, ring-buffer write and block
    fold is PER ROW — rows of a continuous batch sit at unequal positions.
    A scalar t broadcasts to all rows (the legacy shared-position form).
    Returns (out (B,1,H,Dh), updated per-layer cache).

    The attention math itself dispatches through `plan`
    (parallel/plan.py AttentionPlan; a bare backend string resolves to a
    single-device plan): the fused plan routes through the Pallas decode
    kernel — GQA group axis folded into the kernel's query axis, raw +
    compressed caches as two pinned operands (per-shard slots under tensor
    parallelism), slot validity as per-row additive score biases. Cache
    bookkeeping here is identical for every plan.
    """
    from repro.parallel.plan import as_plan
    plan = as_plan(plan)
    raw_k, raw_v = layer_cache["raw_k"], layer_cache["raw_v"]
    comp_k, comp_v = layer_cache["comp_k"], layer_cache["comp_v"]
    B, c, Hkv, Dh = raw_k.shape
    M = comp_k.shape[1]
    r = E.shape[-1]
    scale_ = scale if scale is not None else Dh ** -0.5

    t = rowwise_t(t, B)
    pos = jnp.mod(t, c)                         # (B,)
    blk = t // c                                # (B,)

    raw_k = _row_update(raw_k, k_t.astype(raw_k.dtype), pos)
    raw_v = _row_update(raw_v, v_t.astype(raw_v.dtype), pos)

    loc_ok = jnp.arange(c)[None, :] <= pos[:, None]         # (B, c)
    glob_ok = jnp.arange(M)[None, :] < (blk * r)[:, None]   # (B, M)
    out = plan.decode_attention(q_t, raw_k, raw_v, comp_k, comp_v,
                                loc_ok, glob_ok, scale=scale_)

    # fold a row's block into its compressed slots when it completes
    # (pos[b] == c-1). Compute unconditionally (O(c·r·Dh·Hkv), tiny) and
    # commit per row via select — cheaper than lax.cond's control flow.
    if E.ndim == 2:
        new_ks = jnp.einsum("bchd,cr->brhd", raw_k, E.astype(raw_k.dtype))
        new_vs = jnp.einsum("bchd,cr->brhd", raw_v, F.astype(raw_v.dtype))
    else:
        new_ks = jnp.einsum("bchd,hcr->brhd", raw_k, E.astype(raw_k.dtype))
        new_vs = jnp.einsum("bchd,hcr->brhd", raw_v, F.astype(raw_v.dtype))
    done = (pos == (c - 1))[:, None, None, None]
    comp_k_new = _row_update(comp_k, new_ks, blk * r)
    comp_v_new = _row_update(comp_v, new_vs, blk * r)
    comp_k = jnp.where(done, comp_k_new, comp_k)
    comp_v = jnp.where(done, comp_v_new, comp_v)

    return out, {"raw_k": raw_k, "raw_v": raw_v,
                 "comp_k": comp_k, "comp_v": comp_v}


def compressed_prefill_chunk(
    q: jax.Array,             # (B, P, H, Dh) — one prefill chunk, rope applied
    k: jax.Array,             # (B, P, Hkv, Dh)
    v: jax.Array,
    layer_cache: Dict[str, jax.Array],
    E: jax.Array,             # (c, r) or (Hkv, c, r)
    F: jax.Array,
    t0: jax.Array,            # (B,) int32 — row's current length, multiple of c
    *,
    scale: Optional[float] = None,
    plan=None,                # AttentionPlan | backend string | None
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunked-prefill step of blockwise-causal Linformer attention.

    Mid-prefill cache write at an arbitrary PER-ROW offset: row b's chunk
    covers absolute positions [t0[b], t0[b] + P); every chunk boundary is a
    block-fold boundary (t0 and P are multiples of c), so the chunk's P/c
    blocks fold straight into r compressed slots each, written at slot offset
    (t0[b] // c)·r — the raw ring buffer is untouched (it only ever holds the
    current incomplete block, and a chunk never ends mid-block; remainder
    tokens go through the decode path). Attention then reads the UPDATED slot
    buffer: [own block, causal | compressed slots of absolute blocks
    < t0//c + j] — identical math to the monolithic prefill forward when the
    cache dtype matches the activation dtype. With a lower-precision cache
    (e.g. bf16 under fp32 compute) earlier chunks' slots are read back
    cache-rounded, where the monolithic forward attends them at full
    precision and only rounds when materializing the cache — the standard
    chunked-prefill tradeoff.

    Rows whose chunk is partially padded (n_valid < P, whole padded blocks at
    the END) write garbage slots beyond their valid blocks; those slots are
    never visible (visibility is bounded by the row's committed length) and
    are overwritten by the next chunk or by the decode-time block fold before
    visibility reaches them, so no masking of the write is needed.

    Returns (out (B, P, H, Dh), updated per-layer cache).
    """
    from repro.parallel.plan import as_plan
    plan = as_plan(plan)
    raw_k, raw_v = layer_cache["raw_k"], layer_cache["raw_v"]
    comp_k, comp_v = layer_cache["comp_k"], layer_cache["comp_v"]
    B, P, Hkv, Dh = k.shape
    c = raw_k.shape[1]
    r = E.shape[-1]
    scale_ = scale if scale is not None else q.shape[-1] ** -0.5
    if P % c != 0:
        raise ValueError(f"prefill chunk P={P} not a multiple of block {c}")
    nb = P // c

    from repro.core.causal import compress_blocks
    kbar = compress_blocks(k.reshape(B, nb, c, Hkv, Dh), E)
    vbar = compress_blocks(v.reshape(B, nb, c, Hkv, Dh), F)
    t0 = rowwise_t(t0, B)
    slot0 = (t0 // c) * r
    comp_k = _row_update(comp_k, kbar.reshape(B, nb * r, Hkv, Dh)
                         .astype(comp_k.dtype), slot0)
    comp_v = _row_update(comp_v, vbar.reshape(B, nb * r, Hkv, Dh)
                         .astype(comp_v.dtype), slot0)

    start_blocks = t0 // c
    out = plan.chunk_prefill_attention(
        q, k, v, comp_k, comp_v, start_blocks,
        block_size=c, block_slots=r, scale=scale_)
    return out, {"raw_k": raw_k, "raw_v": raw_v,
                 "comp_k": comp_k, "comp_v": comp_v}


# ---------------------------------------------------------------------------
# Paged, quantized (Linformer-causal) cache
# ---------------------------------------------------------------------------
#
# Same attention math as the compressed cache above, different storage:
#
# * the raw ring buffer is stored quantized (int8, or fp8 where the jnp
#   build has ``float8_e4m3fn``) with one fp32 scale per cached token per
#   KV head (symmetric, amax over Dh);
# * the compressed slot buffer becomes a shared PAGE ARENA: one page holds
#   the r compressed slots of one completed block (page size == the block
#   fold), quantized with one fp32 scale per page per KV head (amax over
#   r·Dh);
# * a per-row page table (B, max_pages) int32 maps a row's block index to a
#   physical arena page; -1 = unallocated. Pages are allocated HOST-side
#   (serving/paged.PageAllocator) between chunks; device code never
#   allocates. A block fold whose table entry is unallocated (or whose
#   block index is out of table range — padded prefill garbage) is
#   redirected to the reserved TRASH page (arena page Np-1), whose contents
#   are never read: slot visibility is bounded by ``glob_ok`` (completed
#   blocks only) and snapshots slice to the row's valid page count.
#
# The page_table leaf carries a leading layer axis like every other leaf
# (broadcast-identical rows) purely so it scans through the per-layer
# ``lax.scan`` in transformer.py unchanged.


def resolve_page_dtype(name: str = "int8"):
    """Map a page-dtype name to (jnp dtype, symmetric qmax).

    ``int8`` is always available; ``fp8`` requires a jnp build with
    ``float8_e4m3fn`` (qmax 448) and raises otherwise so callers can gate.
    """
    if name == "int8":
        return jnp.int8, 127.0
    if name == "fp8":
        fp8 = getattr(jnp, "float8_e4m3fn", None)
        if fp8 is None:
            raise ValueError("fp8 page dtype requires jnp.float8_e4m3fn")
        return fp8, 448.0
    raise ValueError(f"unknown page dtype {name!r} (expected int8|fp8)")


def _qmax_for(dtype) -> float:
    """Symmetric quantization ceiling for a page storage dtype."""
    return 127.0 if dtype == jnp.dtype(jnp.int8) else 448.0


def quantize_blockwise(x: jax.Array, axes, *, dtype=jnp.int8,
                       qmax: float = 127.0) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-block quantization: ``scale = max(amax, eps)/qmax`` over
    the reduced ``axes`` (fp32 math), values rounded+clipped for integer
    dtypes, clipped only for fp8. Returns (q, scale) with the reduced axes
    squeezed out of ``scale``."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = xf / scale
    if jnp.issubdtype(dtype, jnp.integer):
        q = jnp.clip(jnp.round(q), -qmax, qmax)
    else:
        q = jnp.clip(q, -qmax, qmax)
    return q.astype(dtype), jnp.squeeze(scale, axis=axes)


def dequantize_blockwise(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_blockwise` for the cache layouts used here:
    ``scale`` must broadcast against ``q`` once a trailing Dh axis is
    appended (all cache scales reduce exactly the Dh axis plus, for pages,
    the slot axis already repeated back by the gather)."""
    return q.astype(jnp.float32) * scale[..., None]


def paged_cache_spec(
    *, num_layers: int, batch: int, max_seq: int, block_size: int,
    block_slots: int, num_kv_heads: int, head_dim: int,
    arena_pages: Optional[int] = None, page_dtype: str = "int8",
) -> Dict[str, jax.ShapeDtypeStruct]:
    """Spec for the paged, quantized Linformer-causal cache.

    ``arena_pages`` defaults to one full table per row plus the TRASH page
    (capacity-equivalent to the dense pool); serving shrinks it to
    oversubscribe. The last arena page is always reserved as TRASH.
    """
    maxp = max_seq // block_size
    if arena_pages is None:
        arena_pages = batch * maxp + 1
    if arena_pages < 2:
        raise ValueError("arena_pages must be >= 2 (1 usable + TRASH)")
    pdt, _ = resolve_page_dtype(page_dtype)
    L, B, c, r = num_layers, batch, block_size, block_slots
    Hkv, Dh, Np = num_kv_heads, head_dim, arena_pages
    f32, i32 = jnp.float32, jnp.int32
    sd = jax.ShapeDtypeStruct
    return {
        "raw_k_q": sd((L, B, c, Hkv, Dh), pdt),
        "raw_v_q": sd((L, B, c, Hkv, Dh), pdt),
        "raw_k_s": sd((L, B, c, Hkv), f32),
        "raw_v_s": sd((L, B, c, Hkv), f32),
        "page_k": sd((L, Np, r, Hkv, Dh), pdt),
        "page_v": sd((L, Np, r, Hkv, Dh), pdt),
        "page_k_s": sd((L, Np, Hkv), f32),
        "page_v_s": sd((L, Np, Hkv), f32),
        "page_table": sd((L, B, maxp), i32),
        "lengths": sd((B,), i32),
    }


def init_paged_cache(**kw) -> Dict[str, jax.Array]:
    """Zero-initialized paged cache; the page table starts all-unallocated
    (-1), NOT zero — page 0 is a real arena page."""
    spec = paged_cache_spec(**kw)
    out = {}
    for k, v in spec.items():
        if k == "page_table":
            out[k] = jnp.full(v.shape, -1, v.dtype)
        else:
            out[k] = jnp.zeros(v.shape, v.dtype)
    return out


def paged_gather(page_q: jax.Array, page_s: jax.Array,
                 page_table: jax.Array, ) -> Tuple[jax.Array, jax.Array]:
    """Gather a row-major dense (B, M, Hkv, Dh) quantized slot view plus
    per-slot scales (B, M, Hkv) from the page arena through the page table.
    Unallocated entries (-1) read page 0's bytes; those slots are never
    visible (``glob_ok`` bounds visibility to allocated, completed blocks)."""
    B, maxp = page_table.shape
    Np, r, Hkv, Dh = page_q.shape
    idx = jnp.clip(page_table, 0, Np - 1)
    gq = page_q[idx].reshape(B, maxp * r, Hkv, Dh)
    gs = jnp.repeat(page_s[idx], r, axis=1)            # (B, maxp·r, Hkv)
    return gq, gs


def paged_decode_attention(
    q_t: jax.Array,           # (B, 1, H, Dh) — rope already applied at pos t
    k_t: jax.Array,           # (B, 1, Hkv, Dh)
    v_t: jax.Array,
    layer_cache: Dict[str, jax.Array],
    E: jax.Array,             # (c, r) or (Hkv, c, r)
    F: jax.Array,
    t: jax.Array,             # () or (B,) int32 — tokens already cached per row
    *,
    scale: Optional[float] = None,
    plan=None,                # AttentionPlan | backend string | None
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step over the paged, quantized cache.

    Identical bookkeeping to :func:`compressed_decode_attention` with three
    storage differences: (a) the incoming token is quantized per (row, head)
    into the int8/fp8 ring alongside its scale; (b) attention reads a dense
    gather of the page arena (dequantized INSIDE the kernel on the fused
    path — see ``plan.decode_attention_q``); (c) a completed block's fold is
    re-quantized per (row, head) over (r, Dh) and scattered to the row's
    table page — rows that did not complete a block, or whose block has no
    allocated page, scatter to the TRASH page instead.
    """
    from repro.parallel.plan import as_plan
    plan = as_plan(plan)
    rk_q, rv_q = layer_cache["raw_k_q"], layer_cache["raw_v_q"]
    rk_s, rv_s = layer_cache["raw_k_s"], layer_cache["raw_v_s"]
    pk, pv = layer_cache["page_k"], layer_cache["page_v"]
    pk_s, pv_s = layer_cache["page_k_s"], layer_cache["page_v_s"]
    pt = layer_cache["page_table"]
    B, c, Hkv, Dh = rk_q.shape
    Np, r = pk.shape[0], pk.shape[1]
    maxp = pt.shape[1]
    M = maxp * r
    qmax = _qmax_for(pk.dtype)
    trash = Np - 1
    scale_ = scale if scale is not None else Dh ** -0.5

    t = rowwise_t(t, B)
    pos = jnp.mod(t, c)                         # (B,)
    blk = t // c                                # (B,)

    k_q, k_s = quantize_blockwise(k_t, (3,), dtype=pk.dtype, qmax=qmax)
    v_q, v_s = quantize_blockwise(v_t, (3,), dtype=pk.dtype, qmax=qmax)
    rk_q = _row_update(rk_q, k_q, pos)
    rv_q = _row_update(rv_q, v_q, pos)
    rk_s = _row_update(rk_s, k_s, pos)
    rv_s = _row_update(rv_s, v_s, pos)

    gk, gk_s = paged_gather(pk, pk_s, pt)
    gv, gv_s = paged_gather(pv, pv_s, pt)
    loc_ok = jnp.arange(c)[None, :] <= pos[:, None]         # (B, c)
    glob_ok = jnp.arange(M)[None, :] < (blk * r)[:, None]   # (B, M)
    out = plan.decode_attention_q(
        q_t, rk_q, rv_q, rk_s, rv_s, gk, gv, gk_s, gv_s,
        loc_ok, glob_ok, scale=scale_)

    # fold a completed block: dequantize the ring, compress, re-quantize per
    # (row, head) over (r, Dh), scatter to the row's table page. Rows not on
    # a fold boundary — or without an allocated page — go to TRASH.
    raw_k_f = dequantize_blockwise(rk_q, rk_s)
    raw_v_f = dequantize_blockwise(rv_q, rv_s)
    Ef, Ff = E.astype(jnp.float32), F.astype(jnp.float32)
    if E.ndim == 2:
        new_ks = jnp.einsum("bchd,cr->brhd", raw_k_f, Ef)
        new_vs = jnp.einsum("bchd,cr->brhd", raw_v_f, Ff)
    else:
        new_ks = jnp.einsum("bchd,hcr->brhd", raw_k_f, Ef)
        new_vs = jnp.einsum("bchd,hcr->brhd", raw_v_f, Ff)
    fk_q, fk_s = quantize_blockwise(new_ks, (1, 3), dtype=pk.dtype, qmax=qmax)
    fv_q, fv_s = quantize_blockwise(new_vs, (1, 3), dtype=pk.dtype, qmax=qmax)

    done = pos == (c - 1)
    pt_blk = jnp.take_along_axis(
        pt, jnp.clip(blk, 0, maxp - 1)[:, None], axis=1)[:, 0]
    commit = done & (pt_blk >= 0) & (blk < maxp)
    dst = jnp.where(commit, pt_blk, trash)                  # (B,)
    pk = pk.at[dst].set(fk_q)
    pv = pv.at[dst].set(fv_q)
    pk_s = pk_s.at[dst].set(fk_s)
    pv_s = pv_s.at[dst].set(fv_s)

    return out, {"raw_k_q": rk_q, "raw_v_q": rv_q,
                 "raw_k_s": rk_s, "raw_v_s": rv_s,
                 "page_k": pk, "page_v": pv,
                 "page_k_s": pk_s, "page_v_s": pv_s,
                 "page_table": pt}


def paged_prefill_chunk(
    q: jax.Array,             # (B, P, H, Dh) — one prefill chunk, rope applied
    k: jax.Array,             # (B, P, Hkv, Dh)
    v: jax.Array,
    layer_cache: Dict[str, jax.Array],
    E: jax.Array,             # (c, r) or (Hkv, c, r)
    F: jax.Array,
    t0: jax.Array,            # (B,) int32 — row's current length, multiple of c
    *,
    scale: Optional[float] = None,
    plan=None,                # AttentionPlan | backend string | None
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunked-prefill step over the paged, quantized cache.

    The chunk's P/c block folds are quantized per (row, block, head) and
    scattered to the row's table pages (unallocated or out-of-range blocks —
    padded prefill garbage — go to TRASH). Attention then reads the dense
    gather of the arena taken AFTER the scatter, so a chunk's own earlier
    blocks are visible CACHE-ROUNDED — the same chunked-admission rounding
    contract as the low-precision dense cache (see
    :func:`compressed_prefill_chunk`), one notch coarser. The raw ring is
    untouched, as in the dense path.
    """
    from repro.parallel.plan import as_plan
    plan = as_plan(plan)
    rk_q, rv_q = layer_cache["raw_k_q"], layer_cache["raw_v_q"]
    rk_s, rv_s = layer_cache["raw_k_s"], layer_cache["raw_v_s"]
    pk, pv = layer_cache["page_k"], layer_cache["page_v"]
    pk_s, pv_s = layer_cache["page_k_s"], layer_cache["page_v_s"]
    pt = layer_cache["page_table"]
    B, P, Hkv, Dh = k.shape
    c = rk_q.shape[1]
    r = E.shape[-1]
    Np = pk.shape[0]
    maxp = pt.shape[1]
    qmax = _qmax_for(pk.dtype)
    trash = Np - 1
    scale_ = scale if scale is not None else q.shape[-1] ** -0.5
    if P % c != 0:
        raise ValueError(f"prefill chunk P={P} not a multiple of block {c}")
    nb = P // c

    from repro.core.causal import compress_blocks
    kf = k.astype(jnp.float32).reshape(B, nb, c, Hkv, Dh)
    vf = v.astype(jnp.float32).reshape(B, nb, c, Hkv, Dh)
    kbar = compress_blocks(kf, E.astype(jnp.float32))       # (B, nb, r, Hkv, Dh)
    vbar = compress_blocks(vf, F.astype(jnp.float32))
    bk_q, bk_s = quantize_blockwise(kbar, (2, 4), dtype=pk.dtype, qmax=qmax)
    bv_q, bv_s = quantize_blockwise(vbar, (2, 4), dtype=pk.dtype, qmax=qmax)

    t0 = rowwise_t(t0, B)
    blk0 = t0 // c
    abs_blk = blk0[:, None] + jnp.arange(nb)[None, :]       # (B, nb)
    pids = jnp.take_along_axis(pt, jnp.clip(abs_blk, 0, maxp - 1), axis=1)
    dst = jnp.where((pids >= 0) & (abs_blk < maxp), pids, trash).reshape(-1)
    pk = pk.at[dst].set(bk_q.reshape(B * nb, r, Hkv, Dh))
    pv = pv.at[dst].set(bv_q.reshape(B * nb, r, Hkv, Dh))
    pk_s = pk_s.at[dst].set(bk_s.reshape(B * nb, Hkv))
    pv_s = pv_s.at[dst].set(bv_s.reshape(B * nb, Hkv))

    gk, gk_s = paged_gather(pk, pk_s, pt)
    gv, gv_s = paged_gather(pv, pv_s, pt)
    out = plan.chunk_prefill_attention_q(
        q, k, v, gk, gv, gk_s, gv_s, blk0,
        block_size=c, block_slots=r, scale=scale_)
    return out, {"raw_k_q": rk_q, "raw_v_q": rv_q,
                 "raw_k_s": rk_s, "raw_v_s": rv_s,
                 "page_k": pk, "page_v": pv,
                 "page_k_s": pk_s, "page_v_s": pv_s,
                 "page_table": pt}


# ---------------------------------------------------------------------------
# Full KV cache (standard-attention baseline)
# ---------------------------------------------------------------------------


def full_cache_spec(
    *, num_layers: int, batch: int, max_seq: int, num_kv_heads: int,
    head_dim: int, dtype=jnp.bfloat16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    kv = lambda *s: jax.ShapeDtypeStruct(s, dtype)
    return {
        "k": kv(num_layers, batch, max_seq, num_kv_heads, head_dim),
        "v": kv(num_layers, batch, max_seq, num_kv_heads, head_dim),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def init_full_cache(**kw) -> Dict[str, jax.Array]:
    spec = full_cache_spec(**kw)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in spec.items()}


def full_decode_attention(
    q_t: jax.Array,           # (B, 1, H, Dh)
    k_t: jax.Array,           # (B, 1, Hkv, Dh)
    v_t: jax.Array,
    layer_cache: Dict[str, jax.Array],   # k/v: (B, S, Hkv, Dh)
    t: jax.Array,             # () or (B,) int32 per-row positions
    *,
    scale: Optional[float] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step of standard causal attention with a full KV cache.
    Writes and masks are per row; a scalar t broadcasts to all rows."""
    ck, cv = layer_cache["k"], layer_cache["v"]
    B, S, Hkv, Dh = ck.shape
    H = q_t.shape[2]
    G = H // Hkv
    scale_ = scale if scale is not None else Dh ** -0.5
    t = rowwise_t(t, B)
    ck = _row_update(ck, k_t.astype(ck.dtype), t)
    cv = _row_update(cv, v_t.astype(cv.dtype), t)
    qg = q_t.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, ck).astype(jnp.float32) * scale_
    ok = jnp.arange(S)[None, :] <= t[:, None]               # (B, S)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q_t.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", p, cv).reshape(B, 1, H, Dh)
    return out, {"k": ck, "v": cv}


def full_prefill_chunk(
    q: jax.Array,             # (B, P, H, Dh)
    k: jax.Array,             # (B, P, Hkv, Dh)
    v: jax.Array,
    layer_cache: Dict[str, jax.Array],   # k/v: (B, S, Hkv, Dh)
    t0: jax.Array,            # (B,) int32 — row's current length
    *,
    scale: Optional[float] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunked-prefill step of standard causal attention with a full KV
    cache: row b's chunk is written at positions [t0[b], t0[b] + P) and each
    query i attends cache positions ≤ t0[b] + i. Padded tail tokens
    (n_valid < P) write garbage the decode path overwrites position-by-
    position before its mask can reach them."""
    ck, cv = layer_cache["k"], layer_cache["v"]
    B, S, Hkv, Dh = ck.shape
    P = q.shape[1]
    H = q.shape[2]
    G = H // Hkv
    scale_ = scale if scale is not None else Dh ** -0.5
    t0 = rowwise_t(t0, B)
    ck = _row_update(ck, k.astype(ck.dtype), t0)
    cv = _row_update(cv, v.astype(cv.dtype), t0)
    qg = q.reshape(B, P, Hkv, G, Dh)
    s = jnp.einsum("bphgd,bshd->bhgps", qg, ck).astype(jnp.float32) * scale_
    qpos = t0[:, None] + jnp.arange(P)[None, :]              # (B, P)
    ok = jnp.arange(S)[None, None, :] <= qpos[:, :, None]    # (B, P, S)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgps,bshd->bphgd", p, cv).reshape(B, P, H, Dh)
    return out, {"k": ck, "v": cv}
