"""Backend dispatch parity: model-level forward/decode/gradients through the
fused Pallas kernels (interpret mode on CPU) must match the pure-jnp
reference implementations, for both linformer kinds, including GQA and the
custom VJPs. This is what certifies that the default ("auto" -> fused)
compute path is the same math as the einsum reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import AttentionConfig, LinformerConfig, ModelConfig
from repro.kernels import ops
from repro.models import model as M
from tests.conftest import f32, make_batch

TOL = dict(atol=1e-4, rtol=1e-4)


def _gqa_linformer_cfg():
    """Exact (bidirectional) Linformer with num_heads != num_kv_heads."""
    return ModelConfig(
        name="parity-linformer-gqa",
        num_layers=2,
        d_model=64,
        vocab_size=512,
        max_seq_len=128,
        objective="mlm",
        attention=AttentionConfig(
            kind="linformer",
            num_heads=4,
            num_kv_heads=2,
            head_dim=16,
            causal=False,
            use_rope=False,
            linformer=LinformerConfig(k=16, sharing="layerwise"),
        ),
        dtype="float32",
        remat="none",
    )


def _both(cfg):
    return cfg.with_attention_backend("reference"), \
        cfg.with_attention_backend("fused")


def test_auto_backend_resolves_to_fused():
    """The acceptance contract: the default knob executes the kernel path."""
    assert AttentionConfig().backend == "auto"
    assert ops.resolve_backend("auto") == "fused"


@pytest.mark.parametrize("cfg_fn", [
    lambda: f32(get_smoke_config("linformer-paper")),   # linformer, MHA
    _gqa_linformer_cfg,                                 # linformer, GQA
    lambda: f32(get_smoke_config("qwen3-8b")),          # linformer_causal, GQA
])
def test_forward_parity(cfg_fn):
    cfg_ref, cfg_fused = _both(cfg_fn())
    params = M.init_params(jax.random.PRNGKey(0), cfg_ref)
    batch = make_batch(cfg_ref)
    ref, _, _ = M.forward(params, cfg_ref, batch)
    fused, _, _ = M.forward(params, cfg_fused, batch)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref), **TOL)


@pytest.mark.parametrize("cfg_fn", [
    lambda: f32(get_smoke_config("linformer-paper")),
    _gqa_linformer_cfg,
    lambda: f32(get_smoke_config("qwen3-8b")),
])
def test_gradient_parity(cfg_fn):
    """Training path: grads through the fused kernels' custom VJPs
    (fused_linformer_attention analytic; blockwise-causal fused Pallas
    backward; seq-projection linear) match reference autodiff — including
    grads into the learned E/F projections."""
    cfg_ref, cfg_fused = _both(cfg_fn())
    params = M.init_params(jax.random.PRNGKey(0), cfg_ref)
    batch = make_batch(cfg_ref)
    g_ref = jax.grad(lambda p: M.loss_fn(p, cfg_ref, batch)[0])(params)
    g_fused = jax.grad(lambda p: M.loss_fn(p, cfg_fused, batch)[0])(params)
    flat_ref, tree_ref = jax.tree.flatten(g_ref)
    flat_fused, tree_fused = jax.tree.flatten(g_fused)
    assert tree_ref == tree_fused
    for a, b in zip(flat_ref, flat_fused):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), **TOL)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_backward_impl_parity_model_level(dtype):
    """Whole-model grads (loss_fn → scanned layers → fused blockwise-causal
    attention, GQA) through the fused Pallas backward match the
    backward_impl="reference" recompute oracle, in fp32 and bf16."""
    cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), dtype=dtype)
    cfg = cfg.with_attention_backend("fused")
    assert cfg.attention.backward_impl == "fused"   # the default
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    g_fused = jax.grad(lambda p: M.loss_fn(p, cfg, batch)[0])(params)
    cfg_ref = cfg.with_backward_impl("reference")
    g_ref = jax.grad(lambda p: M.loss_fn(p, cfg_ref, batch)[0])(params)
    flat_f, tree_f = jax.tree.flatten(g_fused)
    flat_r, tree_r = jax.tree.flatten(g_ref)
    assert tree_f == tree_r
    tol = TOL if dtype == "float32" else dict(atol=5e-2, rtol=5e-2)
    for a, b in zip(flat_f, flat_r):
        b32 = np.asarray(b, np.float32)
        atol = tol["atol"] * max(1.0, float(np.max(np.abs(b32))))
        np.testing.assert_allclose(np.asarray(a, np.float32), b32,
                                   atol=atol, rtol=tol["rtol"])


def test_trainer_threads_backward_impl():
    """Trainer(backward_impl=...) overrides the config knob like
    attention_backend does."""
    from repro.configs.base import TrainConfig
    from repro.train.trainer import Trainer
    cfg = f32(get_smoke_config("qwen3-8b"))
    tr = Trainer(cfg, TrainConfig(steps=1, seq_len=32, global_batch=2),
                 log_fn=lambda s: None, backward_impl="reference")
    assert tr.cfg.attention.backward_impl == "reference"


def test_decode_parity_linformer_causal_gqa():
    """Stepwise decode (fused masked kernel, GQA group axis folded into the
    kernel's query axis) matches the reference decode AND the parallel
    forward, block folds included."""
    cfg_ref, cfg_fused = _both(f32(get_smoke_config("qwen3-8b")))
    assert cfg_ref.attention.num_heads != cfg_ref.attention.num_kv_heads
    params = M.init_params(jax.random.PRNGKey(0), cfg_ref)
    B, S = 2, 32
    batch = make_batch(cfg_ref, B=B, S=S)
    decs = {}
    for name, cfg in [("reference", cfg_ref), ("fused", cfg_fused)]:
        cache = M.init_cache(cfg, batch=B, max_seq=64, dtype=jnp.float32)
        outs = []
        for t in range(S):
            lg, cache = M.decode_step(
                params, cfg, {"tokens": batch["tokens"][:, t:t + 1]}, cache)
            outs.append(lg)
        decs[name] = np.asarray(jnp.concatenate(outs, 1))
    np.testing.assert_allclose(decs["fused"], decs["reference"], **TOL)
    fwd, _, _ = M.forward(params, cfg_fused, batch)
    np.testing.assert_allclose(decs["fused"], np.asarray(fwd),
                               atol=2e-4, rtol=2e-3)


def test_scanned_generation_matches_per_token_loop():
    """The device-resident chunked decode emits exactly the tokens of the
    legacy per-token loop (greedy)."""
    from repro.serving import ServingEngine
    cfg = f32(get_smoke_config("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, max_seq=128, cache_dtype=jnp.float32,
                        decode_chunk=5)   # ragged: 12 = 5 + 5 + 2
    prompt = np.array([[1, 5, 9, 2, 7, 4, 8, 3] * 2,
                       [2, 6, 1, 9, 3, 3, 7, 5] * 2], np.int32)
    scanned = eng.generate_batch(prompt, max_new_tokens=12)
    per_token = eng.generate_batch_per_token(prompt, max_new_tokens=12)
    np.testing.assert_array_equal(scanned, per_token)


def test_non_uniform_k_unrolled_fused():
    """k_decay forces unrolled layers with per-layer E shapes — the fused
    path must handle per-layer static shapes too."""
    cfg = f32(get_smoke_config("linformer-paper"))
    cfg = dataclasses.replace(
        cfg, scan_layers=False,
        attention=dataclasses.replace(
            cfg.attention,
            linformer=dataclasses.replace(cfg.attention.linformer,
                                          sharing="headwise", k_decay=0.5)))
    cfg_ref, cfg_fused = _both(cfg)
    params = M.init_params(jax.random.PRNGKey(0), cfg_ref)
    batch = make_batch(cfg_ref)
    ref, _, _ = M.forward(params, cfg_ref, batch)
    fused, _, _ = M.forward(params, cfg_fused, batch)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref), **TOL)
