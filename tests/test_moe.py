"""MoE: routing, capacity, aux loss, and expert-offset correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLPConfig, MoEConfig
from repro.models.moe import _capacity, _moe_local, apply_moe, init_moe


def _setup(E=8, topk=2, cf=4.0, D=16, ff=32, T=64, seed=0):
    cfg = MoEConfig(num_experts=E, top_k=topk, expert_d_ff=ff,
                    capacity_factor=cf)
    mlp = MLPConfig(activation="swiglu")
    p = init_moe(jax.random.PRNGKey(seed), D, cfg, mlp, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, D))
    return cfg, mlp, p, x


class TestLocalMoE:
    def test_output_shape_and_finite(self):
        cfg, mlp, p, x = _setup()
        out, aux = _moe_local(p["router"], p["w_in"], p["w_gate"], p["w_out"],
                              x, cfg=cfg, activation="swiglu", e_offset=0)
        assert out.shape == x.shape
        assert bool(jnp.isfinite(out).all())
        assert float(aux) > 0

    def test_capacity(self):
        cfg = MoEConfig(num_experts=8, top_k=2, capacity_factor=1.0)
        assert _capacity(64, cfg) == 16
        # default floor is 1 (capacity_floor_one — §Perf kimi/decode #1)
        assert _capacity(4, MoEConfig(num_experts=64, top_k=2,
                                      capacity_factor=1.0)) == 1
        # paper-baseline floor at top_k when the knob is off
        assert _capacity(4, MoEConfig(num_experts=64, top_k=2,
                                      capacity_factor=1.0,
                                      capacity_floor_one=False)) == 2

    def test_high_capacity_matches_dense_routing(self):
        """With capacity >> need, each token gets exactly its top-k experts:
        output equals the explicit dense mixture."""
        cfg, mlp, p, x = _setup(cf=100.0)
        out, _ = _moe_local(p["router"], p["w_in"], p["w_gate"], p["w_out"],
                            x, cfg=cfg, activation="swiglu", e_offset=0)
        logits = x @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        topw, topi = jax.lax.top_k(probs, 2)
        topw = topw / topw.sum(-1, keepdims=True)
        ref = jnp.zeros_like(x)
        for t in range(x.shape[0]):
            for j in range(2):
                e = int(topi[t, j])
                h = x[t] @ p["w_in"][e]
                h = jax.nn.silu(x[t] @ p["w_gate"][e]) * h
                ref = ref.at[t].add(topw[t, j] * (h @ p["w_out"][e]))
        np.testing.assert_allclose(out, ref, atol=1e-4)

    def test_capacity_drops_tokens(self):
        """With capacity 0-ish, everything drops -> output ~ 0."""
        cfg, mlp, p, x = _setup(cf=0.0)   # capacity floor = top_k = 2
        out, _ = _moe_local(p["router"], p["w_in"], p["w_gate"], p["w_out"],
                            x, cfg=cfg, activation="swiglu", e_offset=0)
        # only ≤ 2 tokens per expert survive
        nonzero_rows = int((jnp.abs(out).sum(-1) > 1e-6).sum())
        assert nonzero_rows <= 2 * cfg.num_experts

    def test_expert_offset_partitions_work(self):
        """Sum of per-shard outputs (offsets) == all-experts output — the
        expert-parallel invariant behind the shard_map psum."""
        cfg, mlp, p, x = _setup(E=8)
        full, _ = _moe_local(p["router"], p["w_in"], p["w_gate"], p["w_out"],
                             x, cfg=cfg, activation="swiglu", e_offset=0)
        half1, _ = _moe_local(p["router"], p["w_in"][:4], p["w_gate"][:4],
                              p["w_out"][:4], x, cfg=cfg,
                              activation="swiglu", e_offset=0)
        half2, _ = _moe_local(p["router"], p["w_in"][4:], p["w_gate"][4:],
                              p["w_out"][4:], x, cfg=cfg,
                              activation="swiglu", e_offset=4)
        np.testing.assert_allclose(full, half1 + half2, atol=1e-5)

    def test_aux_loss_prefers_balance(self):
        """A uniformly-routing router has lower aux loss than a collapsed one."""
        cfg, mlp, p, x = _setup()
        balanced = jnp.zeros_like(p["router"])
        collapsed = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
        _, aux_b = _moe_local(balanced, p["w_in"], p["w_gate"], p["w_out"],
                              x, cfg=cfg, activation="swiglu", e_offset=0)
        _, aux_c = _moe_local(collapsed, p["w_in"], p["w_gate"], p["w_out"],
                              x, cfg=cfg, activation="swiglu", e_offset=0)
        assert float(aux_c) > float(aux_b)

    def test_apply_moe_unsharded_path(self):
        cfg, mlp, p, _ = _setup()
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 16))
        out, aux = apply_moe(p, x, cfg, mlp, None)
        assert out.shape == x.shape

    def test_gradients_flow_to_router_and_experts(self):
        cfg, mlp, p, x = _setup()

        def loss(pp):
            out, aux = _moe_local(pp["router"], pp["w_in"], pp["w_gate"],
                                  pp["w_out"], x, cfg=cfg,
                                  activation="swiglu", e_offset=0)
            return jnp.sum(out ** 2) + 0.01 * aux

        g = jax.grad(loss)(p)
        assert float(jnp.abs(g["router"]).max()) > 0
        assert float(jnp.abs(g["w_in"]).max()) > 0
