"""AttentionPlan: resolution rules, fail-fast mesh validation, and the
multi-device parity suite — fused-under-shard_map == single-device fused ==
reference, for train grads (MHA + GQA), chunk prefill, and decode, on tp,
sp, and tp×sp meshes (subprocesses with 8 forced host devices, like
test_distributed.py).

These are the PR 5 acceptance tests: the fused Pallas kernels run PER SHARD
inside the plan's manual region — head-parallel over the KV-head axis,
sequence-parallel via the all-gathered compressed prefix — and nothing
about the math may change.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MESHES = "{'tp2': (2, 1), 'sp2': (1, 2), 'tp2xsp2': (2, 2)}"


def run_py(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# Resolution rules (in-process, single device)
# ---------------------------------------------------------------------------


def test_plan_resolves_single_device():
    from repro.configs.base import AttentionConfig
    from repro.parallel.plan import resolve_attention_plan
    p = resolve_attention_plan(AttentionConfig(backend="auto"))
    assert p.backend == "fused"          # auto -> fused on this container
    assert p.mesh is None and p.tp_axis is None and p.sp_axis is None
    assert not p.manual
    assert p.tp == 1 and p.sp == 1


def test_plan_resolution_is_cached():
    from repro.configs.base import AttentionConfig
    from repro.parallel.plan import resolve_attention_plan
    a = resolve_attention_plan(AttentionConfig())
    b = resolve_attention_plan(AttentionConfig())
    assert a is b


def test_as_plan_normalizes_strings():
    from repro.parallel.plan import AttentionPlan, as_plan
    assert as_plan("fused").backend == "fused"
    assert as_plan("reference").backend == "reference"
    assert as_plan(None).backend == "reference"
    p = as_plan("fused")
    assert as_plan(p) is p
    with pytest.raises(ValueError, match="unknown attention backend"):
        as_plan("mosaic")


def test_validate_seq_shards_fails_fast():
    from repro.launch.mesh import validate_seq_shards
    validate_seq_shards(64, 8, 2)                    # 4 blocks per shard: ok
    with pytest.raises(ValueError, match="whole number of 8-token"):
        validate_seq_shards(24, 8, 2)                # 1.5 blocks per shard


def test_sp_body_rejects_partial_blocks():
    import jax.numpy as jnp
    from repro.core.seq_parallel import sp_blockwise_causal_attention
    x = jnp.zeros((1, 12, 2, 4))
    with pytest.raises(ValueError, match="not a multiple"):
        sp_blockwise_causal_attention(
            x, x, x, jnp.zeros((8, 2)), jnp.zeros((8, 2)), seq_axis="seq",
            block_size=8, block_slots=2, scale=0.5, fused=False)


# ---------------------------------------------------------------------------
# Multi-device parity (subprocess, 8 host devices)
# ---------------------------------------------------------------------------


_COMMON = """
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import (AttentionConfig, LinformerConfig,
                                        ModelConfig)
        from repro.launch.mesh import make_local_mesh
        from repro.models import model as M
        from repro.parallel.plan import resolve_attention_plan
        from repro.parallel.sharding import ParallelCtx, param_shardings

        def cfg_(Hkv, backend="fused"):
            return ModelConfig(
                name="plan-parity", num_layers=2, d_model=32, vocab_size=256,
                max_seq_len=64,
                attention=AttentionConfig(
                    kind="linformer_causal", num_heads=4, num_kv_heads=Hkv,
                    head_dim=8, backend=backend,
                    linformer=LinformerConfig(block_size=8, block_slots=2)),
                dtype="float32", remat="full")

        MESHES = %s
""" % MESHES


@pytest.mark.slow
@pytest.mark.parametrize("hkv", [4, 2])   # MHA, GQA
def test_multi_device_train_grad_parity(hkv):
    """Model-level loss + param grads (incl. E/F through the fused backward)
    under every mesh must match the single-device fused run, which must
    match the reference — the PR 4 parity tolerances."""
    out = run_py(_COMMON + """
        Hkv = %d
        cfg = cfg_(Hkv)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, 256)
        batch = {"tokens": toks, "labels": toks,
                 "loss_mask": jnp.ones((4, 64), jnp.int32)}

        def grads_for(cfg, ctx=None, shardings=None):
            fn = jax.value_and_grad(
                lambda p: M.loss_fn(p, cfg, batch, ctx=ctx)[0])
            fn = jax.jit(fn, in_shardings=(shardings,))
            loss, g = fn(params)
            return float(loss), g

        l_ref, g_ref = grads_for(cfg_(Hkv, backend="reference"))
        l_one, g_one = grads_for(cfg)
        assert abs(l_ref - l_one) < 1e-4, (l_ref, l_one)

        for name, (ms, ss) in MESHES.items():
            mesh = make_local_mesh(model_shards=ms, seq_shards=ss)
            ctx = ParallelCtx(mesh=mesh, fsdp="data")
            plan = resolve_attention_plan(cfg.attention, ctx)
            assert plan.manual, name
            with mesh:
                l_m, g_m = grads_for(cfg, ctx=ctx,
                                     shardings=param_shardings(params, ctx))
            assert abs(l_m - l_one) < 1e-5, (name, l_m, l_one)
            for (pa, a), (pb, b) in zip(
                    jax.tree_util.tree_leaves_with_path(g_m),
                    jax.tree_util.tree_leaves_with_path(g_one)):
                scale = max(1.0, float(jnp.abs(b).max()))
                d = float(jnp.abs(a - b).max())
                assert d < 1e-4 * scale, (name, pa, d)
            # and against the reference oracle
            for a, b in zip(jax.tree.leaves(g_m), jax.tree.leaves(g_ref)):
                scale = max(1.0, float(jnp.abs(b).max()))
                assert float(jnp.abs(a - b).max()) < 2e-3 * scale
            print("OK", name)
        print("DONE")
        """ % hkv)
    assert "DONE" in out


@pytest.mark.slow
def test_multi_device_chunk_prefill_and_decode_parity():
    """Cache-level chunk prefill (per-row offsets) and decode under every
    mesh == the single-device fused step == the reference step, GQA."""
    out = run_py(_COMMON + """
        from repro.core import cache as cache_lib
        from repro.parallel.plan import AttentionPlan, as_plan

        B, S, H, Hkv, Dh, c, r = 4, 32, 4, 2, 8, 8, 2
        P_chunk, max_seq = 16, 64
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        q = jax.random.normal(ks[0], (B, P_chunk, H, Dh))
        k = jax.random.normal(ks[1], (B, P_chunk, Hkv, Dh))
        v = jax.random.normal(ks[2], (B, P_chunk, Hkv, Dh))
        E = jax.random.normal(ks[3], (c, r)) * 0.3
        F = jax.random.normal(ks[4], (c, r)) * 0.3
        M_ = (max_seq // c) * r
        lc = {
            "raw_k": jnp.zeros((B, c, Hkv, Dh)),
            "raw_v": jnp.zeros((B, c, Hkv, Dh)),
            "comp_k": jax.random.normal(ks[5], (B, M_, Hkv, Dh)) * 0.1,
            "comp_v": jax.random.normal(ks[5], (B, M_, Hkv, Dh)) * 0.1,
        }
        t0 = jnp.asarray([0, 8, 16, 24], jnp.int32)   # per-row offsets

        o_ref, c_ref = cache_lib.compressed_prefill_chunk(
            q, k, v, lc, E, F, t0, plan="reference")
        o_one, c_one = cache_lib.compressed_prefill_chunk(
            q, k, v, lc, E, F, t0, plan="fused")
        np.testing.assert_allclose(o_one, o_ref, atol=1e-4, rtol=1e-4)

        # decode single-device baselines
        qd = q[:, :1]
        kd = k[:, :1]
        vd = v[:, :1]
        td = jnp.asarray([3, 7, 12, 20], jnp.int32)
        do_ref, dc_ref = cache_lib.compressed_decode_attention(
            qd, kd, vd, lc, E, F, td, plan="reference")
        do_one, dc_one = cache_lib.compressed_decode_attention(
            qd, kd, vd, lc, E, F, td, plan="fused")
        np.testing.assert_allclose(do_one, do_ref, atol=1e-4, rtol=1e-4)

        for name, (ms, ss) in MESHES.items():
            mesh = make_local_mesh(model_shards=ms, seq_shards=ss)
            ctx = ParallelCtx(mesh=mesh)
            plan = resolve_attention_plan(
                cfg_(Hkv).attention, ctx)
            with mesh:
                o_m, c_m = jax.jit(
                    lambda *a: cache_lib.compressed_prefill_chunk(
                        *a, plan=plan))(q, k, v, lc, E, F, t0)
                do_m, dc_m = jax.jit(
                    lambda *a: cache_lib.compressed_decode_attention(
                        *a, plan=plan))(qd, kd, vd, lc, E, F, td)
            np.testing.assert_allclose(np.asarray(o_m), np.asarray(o_one),
                                       atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(np.asarray(do_m), np.asarray(do_one),
                                       atol=1e-5, rtol=1e-5)
            for key in c_one:
                np.testing.assert_allclose(
                    np.asarray(c_m[key]), np.asarray(c_one[key]),
                    atol=1e-5, rtol=1e-5, err_msg=(name, key))
                np.testing.assert_allclose(
                    np.asarray(dc_m[key]), np.asarray(dc_one[key]),
                    atol=1e-5, rtol=1e-5, err_msg=(name, key))
            print("OK", name)
        print("DONE")
        """)
    assert "DONE" in out


@pytest.mark.slow
def test_multi_device_exact_linformer_parity():
    """Exact (bidirectional) form: fwd + grads under tp×sp — the fused
    sequence-projection psum path — match the single-device fused run."""
    out = run_py(_COMMON + """
        def ecfg(backend):
            return ModelConfig(
                name="plan-exact", num_layers=2, d_model=32, vocab_size=256,
                max_seq_len=64, objective="mlm",
                attention=AttentionConfig(
                    kind="linformer", num_heads=4, num_kv_heads=2,
                    head_dim=8, causal=False, use_rope=False,
                    backend=backend,
                    linformer=LinformerConfig(k=16, sharing="layerwise")),
                dtype="float32", remat="none")

        cfg = ecfg("fused")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, 256)
        batch = {"tokens": toks, "labels": toks,
                 "loss_mask": jnp.ones((4, 64), jnp.int32)}

        def grads_for(cfg, ctx=None):
            fn = jax.jit(jax.value_and_grad(
                lambda p: M.loss_fn(p, cfg, batch, ctx=ctx)[0]))
            loss, g = fn(params)
            return float(loss), g

        l_ref, g_ref = grads_for(ecfg("reference"))
        l_one, g_one = grads_for(cfg)
        assert abs(l_ref - l_one) < 1e-4

        mesh = make_local_mesh(model_shards=2, seq_shards=2)
        ctx = ParallelCtx(mesh=mesh)
        with mesh:
            l_m, g_m = grads_for(cfg, ctx=ctx)
        assert abs(l_m - l_one) < 1e-5, (l_m, l_one)
        for a, b in zip(jax.tree.leaves(g_m), jax.tree.leaves(g_one)):
            scale = max(1.0, float(jnp.abs(b).max()))
            assert float(jnp.abs(a - b).max()) < 1e-4 * scale
        print("DONE")
        """)
    assert "DONE" in out


@pytest.mark.slow
def test_serving_engine_chunked_prefill_on_tp_mesh():
    """End-to-end serving (chunked admission prefill + continuous decode)
    on a tp=2 mesh is byte-identical to the single-device engine — the
    sharded pool cache (per-shard slots) changes nothing observable."""
    out = run_py(_COMMON + """
        from repro.serving.engine import ServingEngine
        cfg = cfg_(2)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompts = [[5, 6, 7] * 6, [9, 10] * 8, [3] * 21, [8] * 4]
        one = ServingEngine(params, cfg, max_seq=64, decode_chunk=4,
                            prefill_chunk=16)
        out1 = one.serve(prompts, 6, max_batch=2)
        mesh = make_local_mesh(model_shards=2)
        ctx = ParallelCtx(mesh=mesh)
        with mesh:
            two = ServingEngine(params, cfg, max_seq=64, ctx=ctx,
                                decode_chunk=4, prefill_chunk=16)
            assert two.plan.tp == 2
            # the pool cache really is sharded: per-shard slots on Hkv
            pool = two.init_pool_cache(2)
            spec = pool["comp_k"].sharding.spec
            assert spec[-2] == "model", spec
            out2 = two.serve(prompts, 6, max_batch=2)
        assert out1 == out2, (out1, out2)
        print("DONE")
        """)
    assert "DONE" in out


@pytest.mark.slow
def test_serving_snapshot_roundtrip_on_tp_mesh():
    """Preemption on a tp=2 mesh: slot snapshots gather from the SHARDED
    pool cache, restores scatter back into it, and the whole
    preempt -> requeue -> resume cycle is byte-identical to the
    single-device engine. After restores the pool must still carry the
    plan's layout (per-shard slots on the KV-head axis) — snapshot
    round-trips preserve sharding exactly as donation does."""
    out = run_py(_COMMON + """
        from repro.serving.engine import ServingEngine
        from repro.serving.faults import Fault, FaultInjector
        cfg = cfg_(2)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompts = [[5, 6, 7] * 6, [9, 10] * 8, [3] * 21, [8] * 4,
                   [11, 4] * 5, [2, 3, 4] * 4]
        budgets = [16, 16, 16, 6, 6, 6]   # low-pri long, hi-pri short
        kw = dict(max_batch=2, priorities=[3, 3, 3, 0, 0, 0],
                  arrival_chunks=[0, 0, 0, 1, 1, 2],
                  return_scheduler=True)
        one = ServingEngine(params, cfg, max_seq=64, decode_chunk=4,
                            prefill_chunk=16)
        out1, s1 = one.serve(prompts, budgets, **kw)
        assert s1.stats.preemptions > 0, s1.stats
        mesh = make_local_mesh(model_shards=2)
        ctx = ParallelCtx(mesh=mesh)
        with mesh:
            two = ServingEngine(params, cfg, max_seq=64, ctx=ctx,
                                decode_chunk=4, prefill_chunk=16)
            out2, s2 = two.serve(prompts, budgets, **kw)
            assert s2.stats.preemptions == s1.stats.preemptions
            # a fault-recovery restore also round-trips the sharded pool
            inj = FaultInjector([Fault("slot_step", chunk=1, row=0)])
            out3, s3 = two.serve(prompts, budgets, max_batch=2,
                                 snapshot_chunks=1, fault_injector=inj,
                                 return_scheduler=True)
            assert s3.stats.quarantines == 1
            # primitive-level: gather -> host -> scatter round-trips the
            # sharded pool byte-exactly AND restores the plan's layout
            pool = two.init_pool_cache(2)
            spec0 = pool["comp_k"].sharding.spec
            assert spec0[-2] == "model", spec0
            snap = two.snapshot_pool_rows(pool, [0, 1], pad_to=2)
            pool = two.restore_pool_rows(
                pool, {k: jnp.asarray(v) for k, v in snap[0].items()}, 0)
            assert pool["comp_k"].sharding.spec == spec0, \\
                pool["comp_k"].sharding.spec
            back = two.snapshot_pool_rows(pool, [0, 1], pad_to=2)
            for a, b in zip(snap, back):
                for key in a:
                    np.testing.assert_array_equal(a[key], b[key])
        assert out1 == out2, (out1, out2)
        plain = one.serve(prompts, budgets, max_batch=2)
        assert out3 == plain, (out3, plain)
        print("DONE")
        """)
    assert "DONE" in out


@pytest.mark.slow
def test_paged_pool_snapshot_roundtrip_on_tp_mesh():
    """Paged, quantized pool on a tp=2 mesh: the page arena is sharded over
    the KV-head axis (scale leaves on their LAST axis), preempt/restore
    through quantized snapshots is byte-identical to the single-device
    paged engine, and a primitive-level snapshot -> restore-into-fresh-pages
    round-trip preserves both the bytes and the plan's layout."""
    out = run_py(_COMMON + """
        from repro.serving import Request
        from repro.serving.engine import ServingEngine
        from repro.serving.scheduler import SlotPool
        cfg = cfg_(2)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompts = [[5, 6, 7] * 6, [9, 10] * 8, [3] * 21, [8] * 4,
                   [11, 4] * 5, [2, 3, 4] * 4]
        budgets = [16, 16, 16, 6, 6, 6]   # low-pri long, hi-pri short
        kw = dict(max_batch=2, priorities=[3, 3, 3, 0, 0, 0],
                  arrival_chunks=[0, 0, 0, 1, 1, 2],
                  snapshot_chunks=2, return_scheduler=True)
        mk = lambda ctx=None, pc=16: ServingEngine(
            params, cfg, max_seq=64, ctx=ctx, decode_chunk=4,
            prefill_chunk=pc, cache_format="paged")
        one = mk()
        out1, s1 = one.serve(prompts, budgets, **kw)
        assert s1.stats.preemptions > 0, s1.stats   # restores exercised
        mesh = make_local_mesh(model_shards=2)
        ctx = ParallelCtx(mesh=mesh)
        with mesh:
            two = mk(ctx)
            assert two.plan.tp == 2
            pool = two.init_pool_cache(2)
            # the arena is genuinely sharded: payloads on the Hkv axis
            # (nd-2), per-page scales on THEIR Hkv axis (last)
            assert pool["page_k"].sharding.spec[-2] == "model"
            assert pool["page_k_s"].sharding.spec[-1] == "model"
            assert pool["raw_k_s"].sharding.spec[-1] == "model"
            out2, s2 = two.serve(prompts, budgets, **kw)
            assert s2.stats.preemptions == s1.stats.preemptions
            # primitive-level: admit one row, snapshot it, restore into
            # FRESH pages on another row — bytes and layout both survive.
            # (monolithic admission requires prefill_chunk=0: the external
            # prefill's slot count must equal the arena fold maxp*r)
            two0 = mk(ctx, pc=0)
            sp = SlotPool(two0, 2)
            spec0 = sp.cache["page_k"].sharding.spec
            prompt = [5, 6, 7] * 6
            cache, logits = two0.prefill(np.asarray([prompt], np.int32))
            req = Request(rid=0, tokens=tuple(prompt), max_new_tokens=4)
            sp.admit(0, req, cache, int(jnp.argmax(logits[0])))
            snap = sp.snapshot_rows([0], tick=0)[0]
            assert snap.verify()
            sp.restore(1, req, snap)
            assert sp.cache["page_k"].sharding.spec == spec0
            back = sp.snapshot_rows([1], tick=0)[0]
            for key in snap.cache_rows:
                np.testing.assert_array_equal(snap.cache_rows[key],
                                              back.cache_rows[key], key)
        assert out1 == out2, (out1, out2)
        print("DONE")
        """)
    assert "DONE" in out


@pytest.mark.slow
def test_mesh_validation_indivisible_hkv():
    """tp that does not divide Hkv: strict validation raises the clear
    launch/mesh.py error; plan resolution warns and demotes attention to
    the unsharded-fused path (the model axis is shared with expert
    parallelism, so e.g. MoE's 4-wide expert axis over Hkv=2 must keep
    working — test_distributed.py::test_tiny_mesh_train_step covers the
    full model)."""
    out = run_py(_COMMON + """
        import warnings
        from repro.launch.mesh import validate_attention_mesh
        mesh = make_local_mesh(model_shards=8)     # tp=8, Hkv=2
        ctx = ParallelCtx(mesh=mesh)
        try:
            validate_attention_mesh(mesh, num_heads=4, num_kv_heads=2,
                                    strict=True)
        except ValueError as e:
            assert "does not divide num_kv_heads" in str(e), e
        else:
            raise AssertionError("expected strict ValueError")
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            plan = resolve_attention_plan(cfg_(2).attention, ctx)
        assert any("does not divide num_kv_heads" in str(x.message)
                   for x in w), [str(x.message) for x in w]
        assert plan.tp_axis is None and not plan.manual
        print("DONE")
        """)
    assert "DONE" in out


@pytest.mark.slow
def test_sp_train_fails_fast_on_indivisible_seq():
    """An S that cannot hold whole blocks per sp shard raises the clear
    validate_seq_shards error from inside the training path."""
    out = run_py(_COMMON + """
        cfg = cfg_(2)
        mesh = make_local_mesh(seq_shards=4)       # S=24 -> 3 blocks, sp=4
        ctx = ParallelCtx(mesh=mesh)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0, 256)
        batch = {"tokens": toks, "labels": toks,
                 "loss_mask": jnp.ones((4, 24), jnp.int32)}
        try:
            with mesh:
                jax.jit(lambda p: M.loss_fn(p, cfg, batch, ctx=ctx)[0])(
                    params)
        except ValueError as e:
            assert "whole number of 8-token attention blocks" in str(e), e
            print("DONE")
        else:
            raise AssertionError("expected fail-fast ValueError")
        """)
    assert "DONE" in out
