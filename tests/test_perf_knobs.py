"""§Perf optimization knobs must be exact-equivalence transforms."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from tests.conftest import f32


@pytest.fixture(scope="module")
def setup():
    cfg = f32(get_smoke_config("qwen3-8b"))
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks,
             "loss_mask": jnp.ones((2, 32), jnp.int32)}
    return cfg, p, batch


def test_single_pass_cache_identical(setup):
    cfg, p, batch = setup
    _, _, two = M.forward(p, cfg, batch, return_cache=True, cache_max_seq=64,
                          cache_dtype=jnp.float32)
    cfg1 = dataclasses.replace(cfg, single_pass_cache=True)
    _, _, one = M.forward(p, cfg1, batch, return_cache=True, cache_max_seq=64,
                          cache_dtype=jnp.float32)
    for a, b in zip(jax.tree.leaves(one), jax.tree.leaves(two)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_single_pass_cache_decodes_correctly(setup):
    cfg, p, batch = setup
    cfg1 = dataclasses.replace(cfg, single_pass_cache=True)
    full, _, _ = M.forward(p, cfg, {**batch, "tokens": batch["tokens"]})
    logits, _, cache = M.forward(p, cfg1,
                                 {"tokens": batch["tokens"][:, :16]},
                                 return_cache=True, cache_max_seq=64,
                                 cache_dtype=jnp.float32)
    for t in range(16, 32):
        lg, cache = M.decode_step(p, cfg1,
                                  {"tokens": batch["tokens"][:, t:t + 1]},
                                  cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-4,
                                   rtol=2e-3)


def test_chunked_ce_matches_full(setup):
    cfg, p, batch = setup
    cfgc = dataclasses.replace(cfg, chunked_ce=8)
    l0, m0 = M.loss_fn(p, cfg, batch)
    l1, m1 = M.loss_fn(p, cfgc, batch)
    assert abs(float(l0) - float(l1)) < 1e-5
    g0 = jax.grad(lambda pp: M.loss_fn(pp, cfg, batch)[0])(p)
    g1 = jax.grad(lambda pp: M.loss_fn(pp, cfgc, batch)[0])(p)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_chunked_ce_non_divisible_falls_back(setup):
    cfg, p, batch = setup
    cfgc = dataclasses.replace(cfg, chunked_ce=7)   # 32 % 7 != 0
    l1, _ = M.loss_fn(p, cfgc, batch)
    l0, _ = M.loss_fn(p, cfg, batch)
    assert abs(float(l0) - float(l1)) < 1e-5


def test_moe_capacity_floor_one_smoke():
    cfg = f32(get_smoke_config("kimi-k2-1t-a32b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_floor_one=True,
                                     capacity_factor=8.0))
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.ones((2, 32), jnp.int32)
    batch = {"tokens": toks, "labels": toks,
             "loss_mask": jnp.ones((2, 32), jnp.int32)}
    loss, _ = M.loss_fn(p, cfg, batch)
    assert bool(jnp.isfinite(loss))
