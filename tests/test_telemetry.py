"""Telemetry subsystem (docs/observability.md): tracer/metrics/timeline
export round-trips, the disabled-is-free contract, the no-added-host-syncs
negative test (byte-identical serving with telemetry on vs off), and the
fail-fast paths of benchmarks/report.py and scripts/check_trace.py."""
import importlib.util
import json
import math
import os

import pytest

from repro.telemetry import (MS_BUCKETS, NULL_TELEMETRY, NULL_TIMELINES,
                             MetricsRegistry, ServingTimelines, Telemetry,
                             TICK_BUCKETS, Tracer, as_telemetry,
                             percentile_from_cumulative, write_chrome_trace)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


class TestTracer:
    def test_span_nesting_and_chrome_export(self, tmp_path):
        tr = Tracer()
        with tr.span("outer", cat="test", run=1):
            with tr.span("inner", cat="test") as sp:
                sp.annotate(rows=3)
            tr.instant("marker", cat="test", tick=0)
        events = tr.chrome_events()
        by_name = {e["name"]: e for e in events}
        assert by_name["inner"]["ph"] == "X"
        assert by_name["marker"]["ph"] == "i"
        assert by_name["inner"]["args"]["rows"] == 3
        # inner closes before outer, and nests inside it on the timeline
        outer, inner = by_name["outer"], by_name["inner"]
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3

        path = write_chrome_trace(str(tmp_path / "t.json"), events,
                                  metadata={"who": "test"})
        doc = json.load(open(path))
        assert doc["metadata"]["who"] == "test"
        ts = [e["ts"] for e in doc["traceEvents"] if "ts" in e]
        assert ts == sorted(ts)
        for e in doc["traceEvents"]:
            assert "ph" in e and "name" in e and "pid" in e

    def test_ring_overflow_counts_drops_and_keeps_newest(self):
        tr = Tracer(capacity=8)
        for i in range(20):
            tr.instant(f"e{i}")
        events = tr.chrome_events()
        assert len(events) == 8
        assert tr.dropped == 12
        assert [e["name"] for e in events] == [f"e{i}" for i in range(12, 20)]

    def test_disabled_tracer_is_free(self):
        tr = Tracer(enabled=False)
        # the null span is a shared singleton: no per-call allocation
        assert tr.span("a") is tr.span("b")
        with tr.span("x") as sp:
            sp.annotate(ignored=1)
        tr.instant("y")
        assert tr.events() == []
        assert tr.dropped == 0


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_gauge_histogram_export_roundtrip(self):
        reg = MetricsRegistry()
        reg.counter("reqs_total", priority="0").inc(3)
        reg.counter("reqs_total", priority="1").inc()
        reg.gauge("occupancy").set(0.75)
        h = reg.histogram("wait_ticks", buckets=TICK_BUCKETS, priority="0")
        for v in (0, 1, 3, 7, 200):
            h.observe(v)

        txt = reg.prometheus_text()
        assert '# TYPE reqs_total counter' in txt
        assert 'reqs_total{priority="0"} 3' in txt
        assert 'reqs_total{priority="1"} 1' in txt
        assert 'wait_ticks_bucket{le="+Inf",priority="0"} 5' in txt
        assert 'wait_ticks_count{priority="0"} 5' in txt

        recs = {(r["metric"], tuple(sorted(r["labels"].items()))): r
                for r in reg.jsonl_records()}
        hr = recs[("wait_ticks", (("priority", "0"),))]
        assert hr["count"] == 5 and hr["min"] == 0 and hr["max"] == 200
        # cumulative buckets are monotone and end at the total count
        cums = [c for _, c in hr["buckets"]]
        assert cums == sorted(cums) and cums[-1] == 5
        json.dumps(recs[("occupancy", ())])  # JSON-serializable throughout

    def test_type_conflict_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        with pytest.raises(ValueError):
            reg.gauge("x_total")

    def test_percentiles_survive_jsonl_roundtrip(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat_ms", buckets=MS_BUCKETS)
        for v in (0.3, 0.9, 2.0, 4.0, 8.0, 30.0, 90.0, 400.0, 900.0, 3000.0):
            h.observe(v)
        (rec,) = reg.jsonl_records()
        cum = [(math.inf if le == "+Inf" else float(le), c)
               for le, c in rec["buckets"]]
        for p in (50, 90, 99):
            assert percentile_from_cumulative(
                cum, rec["count"], p, rec["min"], rec["max"]
            ) == pytest.approx(h.percentile(p))
        # percentiles are clamped into the observed range
        assert h.percentile(99) <= h.max
        assert h.percentile(1) >= h.min


# ---------------------------------------------------------------------------
# Serving timelines
# ---------------------------------------------------------------------------


class TestServingTimelines:
    def _stamped(self):
        tr = Tracer()
        tl = ServingTimelines(tr)
        tl.stamp(0, "queued", 0, priority=1, deadline=5)
        tl.stamp(0, "admitted", 1, row=0)
        tl.stamp(0, "first_token", 2)
        tl.stamp(0, "retired", 3, n_tokens=4)
        tl.stamp(1, "queued", 0, priority=0, deadline=1)
        tl.stamp(1, "admitted", 1, row=1)
        tl.stamp(1, "first_token", 2)
        tl.stamp(1, "retired", 3, n_tokens=2)     # deadline 1 < tick 3
        tl.stamp(2, "queued", 0, priority=2)
        tl.stamp(2, "shed", 1, reason="queue_full")
        return tr, tl

    def test_finalize_derives_slo_metrics(self):
        _, tl = self._stamped()
        reg = MetricsRegistry()
        tl.finalize(reg)
        m = {(name, tuple(sorted(labels.items()))): obj
             for name, labels, obj in reg.items()}
        ttft = m[("serving_ttft_ticks", (("priority", "1"),))]
        assert ttft.count == 1 and ttft.sum == 2          # tick 2 - tick 0
        wait = m[("serving_queue_wait_ticks", (("priority", "1"),))]
        assert wait.sum == 1
        assert m[("serving_tpot_ms", (("priority", "1"),))].count == 1
        assert m[("serving_deadline_miss_total",
                  (("priority", "0"),))].value == 1
        assert m[("serving_shed_events_total",
                  (("priority", "2"), ("reason", "queue_full")))].value == 1
        # rid 0 met its deadline: slack recorded, no miss counter
        assert m[("serving_deadline_slack_ticks",
                  (("priority", "1"),))].sum == 2
        assert ("serving_deadline_miss_total",
                (("priority", "1"),)) not in m

    def test_perfetto_lanes_one_per_request(self):
        _, tl = self._stamped()
        events = tl.trace_events(pid=100, run_label="serving#0")
        lanes = {e["args"]["name"] for e in events
                 if e["ph"] == "M" and e["name"] == "thread_name"}
        assert lanes == {"req 0 (pri 1)", "req 1 (pri 0)", "req 2 (pri 2)"}
        bars = [e for e in events if e["ph"] == "X"]
        assert {"queued", "prefilling", "decoding"} <= {b["name"]
                                                        for b in bars}
        assert all(b["dur"] >= 0 for b in bars)
        # instants carry the stamp fields
        shed = [e for e in events if e["ph"] == "i" and e["name"] == "shed"]
        assert shed and shed[0]["args"]["reason"] == "queue_full"

    def test_null_timelines_noop(self):
        NULL_TIMELINES.stamp(0, "queued", 0, priority=0)
        NULL_TIMELINES.finalize(MetricsRegistry())
        assert not NULL_TIMELINES.enabled


# ---------------------------------------------------------------------------
# Disabled-telemetry contract
# ---------------------------------------------------------------------------


class TestDisabledContract:
    def test_as_telemetry_none_is_shared_singleton(self):
        assert as_telemetry(None) is NULL_TELEMETRY
        t = Telemetry()
        assert as_telemetry(t) is t

    def test_disabled_facade_all_noops(self):
        tel = Telemetry(enabled=False)
        assert tel.span("a") is tel.span("b")       # shared null span
        assert tel.new_timelines() is NULL_TIMELINES
        tel.record("kind", x=1)
        assert tel.records == []
        tel.adopt_registry(MetricsRegistry())
        assert tel.chrome_events() == [
            e for e in tel.chrome_events()]         # stable & harmless
        assert tel.metrics_records() == []


# ---------------------------------------------------------------------------
# Serving integration: byte parity + no added host syncs
# ---------------------------------------------------------------------------


class TestServingIntegration:
    def test_serve_byte_identical_and_same_chunk_count(self):
        """Enabling telemetry must change neither a single decoded token
        nor the number of decode chunks — all stamping rides the existing
        one-host-sync-per-chunk boundary (docs/observability.md
        §Overhead contract)."""
        from tests.test_serving_scheduler import _engine, _requests
        eng, _, _ = _engine()
        prompts, budgets = _requests(6, seed=3)
        plain, sched_plain = eng.serve(prompts, budgets, max_batch=3,
                                       return_scheduler=True)
        tel = Telemetry()
        traced, sched_traced = eng.serve(prompts, budgets, max_batch=3,
                                         return_scheduler=True,
                                         telemetry=tel)
        assert traced == plain
        assert sched_traced.stats.chunks == sched_plain.stats.chunks
        assert sched_traced.stats.counters_line() == \
            sched_plain.stats.counters_line()
        # and the trace's decode_chunk spans equal the chunk count exactly
        spans = [e for e in tel.tracer.chrome_events()
                 if e["ph"] == "X" and e["name"] == "decode_chunk"]
        assert len(spans) == sched_traced.stats.chunks

    def test_serve_exports_lifecycle_and_attribution(self, tmp_path):
        from tests.test_serving_scheduler import _engine, _requests
        eng, _, _ = _engine()
        prompts, budgets = _requests(4, seed=1)
        tel = Telemetry()
        eng.serve(prompts, budgets, max_batch=2, telemetry=tel)
        path = tel.export_trace(str(tmp_path / "t.json"))
        names = {e["name"] for e in json.load(open(path))["traceEvents"]}
        assert {"serve", "decode_chunk", "request_queued",
                "request_admitted", "request_first_token",
                "request_retired"} <= names
        recs = tel.metrics_records()
        assert any(r.get("kind") == "plan_attribution" for r in recs)
        ttft = [r for r in recs if r.get("metric") == "serving_ttft_ticks"]
        assert ttft and all(r["count"] for r in ttft)

    def test_stats_view_is_registry_backed(self):
        from repro.serving.scheduler import ScheduleStats
        s = ScheduleStats()
        s.chunks += 3
        s.preemptions += 1
        assert s.chunks == 3 and isinstance(s.chunks, int)
        assert s.registry.counter("serving_chunks_total").value == 3
        assert "preemptions=1" in s.counters_line()
        with pytest.raises(AttributeError):
            s.not_a_counter = 1


# ---------------------------------------------------------------------------
# Trainer integration
# ---------------------------------------------------------------------------


class TestTrainerTelemetry:
    def test_trainer_emits_step_records(self, tmp_path):
        from repro.configs.base import (AttentionConfig, LinformerConfig,
                                        ModelConfig, OptimizerConfig,
                                        TrainConfig)
        from repro.train import Trainer
        cfg = ModelConfig(
            name="telemetry-test", num_layers=1, d_model=32, vocab_size=64,
            max_seq_len=16,
            attention=AttentionConfig(
                kind="linformer_causal", num_heads=2, num_kv_heads=2,
                head_dim=8,
                linformer=LinformerConfig(block_size=8, block_slots=4)),
            dtype="float32", remat="none")
        tcfg = TrainConfig(seq_len=16, global_batch=2, steps=3,
                           log_every=100, checkpoint_every=100,
                           checkpoint_dir=str(tmp_path),
                           optimizer=OptimizerConfig(lr=1e-3,
                                                     warmup_steps=1,
                                                     total_steps=10))
        tel = Telemetry()
        Trainer(cfg, tcfg, log_fn=lambda s: None, telemetry=tel).run()
        steps = [r for r in tel.records if r["kind"] == "train_step"]
        assert len(steps) == 3
        assert all(r["step_ms"] > 0 and r["loss"] is not None
                   for r in steps)
        assert any(r["kind"] == "plan_attribution" for r in tel.records)
        assert tel.metrics.counter("train_steps_total").value == 3
        spans = [e for e in tel.tracer.chrome_events()
                 if e["ph"] == "X" and e["name"] == "train_step"]
        assert len(spans) == 3


# ---------------------------------------------------------------------------
# report.py / check_trace.py fail-fast
# ---------------------------------------------------------------------------


def _load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(REPO_ROOT, "scripts", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestReportFailFast:
    def test_malformed_bench_json_raises(self, tmp_path):
        from benchmarks.report import BenchJsonError, bench_json_summary
        (tmp_path / "BENCH_broken.json").write_text('{"mode": "quick"')
        with pytest.raises(BenchJsonError, match="malformed JSON"):
            bench_json_summary(out=open(os.devnull, "w"),
                               bench_dir=str(tmp_path))

    def test_non_object_bench_json_raises(self, tmp_path):
        from benchmarks.report import BenchJsonError, bench_json_summary
        (tmp_path / "BENCH_list.json").write_text('[1, 2]')
        with pytest.raises(BenchJsonError, match="expected a JSON object"):
            bench_json_summary(out=open(os.devnull, "w"),
                               bench_dir=str(tmp_path))

    def test_missing_required_field_raises(self, tmp_path):
        from benchmarks.report import BenchJsonError, bench_json_summary
        # a train_step record without its required fields
        (tmp_path / "BENCH_train_step.json").write_text('{"mode": "quick"}')
        with pytest.raises(BenchJsonError, match="missing"):
            bench_json_summary(out=open(os.devnull, "w"),
                               bench_dir=str(tmp_path))

    def test_main_exits_nonzero(self, tmp_path, capsys):
        from benchmarks.report import main
        (tmp_path / "BENCH_broken.json").write_text('not json')
        with pytest.raises(SystemExit) as exc:
            main(["--bench-dir", str(tmp_path)])
        assert exc.value.code == 1
        assert "[report] ERROR" in capsys.readouterr().err

    def test_trace_summary_rejects_non_trace(self, tmp_path):
        from benchmarks.report import BenchJsonError, trace_summary
        p = tmp_path / "not_a_trace.json"
        p.write_text('{"hello": 1}')
        with pytest.raises(BenchJsonError, match="traceEvents"):
            trace_summary(str(p), out=open(os.devnull, "w"))


class TestCheckTrace:
    def test_missing_lifecycle_events_fail(self, tmp_path, capsys):
        ct = _load_script("check_trace")
        trace = tmp_path / "t.json"
        trace.write_text(json.dumps({"traceEvents": [
            {"ph": "i", "name": "request_queued", "ts": 0, "pid": 0,
             "args": {}}]}))
        metrics = tmp_path / "m.jsonl"
        metrics.write_text("")
        assert ct.main([str(trace), str(metrics)]) == 1
        err = capsys.readouterr().err
        assert "request_preempted" in err
        assert "deadline_infeasible" in err

    def test_unreadable_inputs_fail(self, tmp_path, capsys):
        ct = _load_script("check_trace")
        assert ct.main([str(tmp_path / "absent.json"),
                        str(tmp_path / "absent.jsonl")]) == 1
        assert "unreadable" in capsys.readouterr().err
