"""SSM blocks: Mamba2 chunked SSD vs recurrence; RWKV6 chunked vs step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RWKVConfig, SSMConfig
from repro.models import mamba2 as m2
from repro.models import rwkv6 as r6


class TestMamba2:
    cfg = SSMConfig(state_dim=8, head_dim=8, expand=2, conv_width=4,
                    chunk_size=8)

    def test_chunked_equals_scan(self):
        p = m2.init_mamba2(jax.random.PRNGKey(0), 32, self.cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.5
        np.testing.assert_allclose(
            m2.apply_mamba2(p, x, self.cfg),
            m2.apply_mamba2_scan(p, x, self.cfg), atol=2e-5)

    def test_chunk_boundary_independence(self):
        p = m2.init_mamba2(jax.random.PRNGKey(0), 32, self.cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32)) * 0.5
        import dataclasses
        cfg4 = dataclasses.replace(self.cfg, chunk_size=4)
        cfg16 = dataclasses.replace(self.cfg, chunk_size=16)
        np.testing.assert_allclose(m2.apply_mamba2(p, x, cfg4),
                                   m2.apply_mamba2(p, x, cfg16), atol=2e-5)

    def test_step_state_carries_context(self):
        p = m2.init_mamba2(jax.random.PRNGKey(0), 32, self.cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32)) * 0.5
        st = m2.init_mamba2_state(1, 32, self.cfg)
        for t in range(16):
            y, st = m2.step_mamba2(p, x[:, t:t + 1], st, self.cfg)
        # state after context differs from fresh state
        assert float(jnp.abs(st["ssm"]).max()) > 0

    def test_decay_stays_bounded(self):
        p = m2.init_mamba2(jax.random.PRNGKey(0), 32, self.cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32)) * 3.0
        y = m2.apply_mamba2(p, x, self.cfg)
        assert bool(jnp.isfinite(y).all())


class TestRWKV6:
    cfg = RWKVConfig(head_dim=8, chunk_size=8)

    def _setup(self, S=32, D=32):
        p = r6.init_rwkv6(jax.random.PRNGKey(0), D, 64, self.cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, S, D)) * 0.5
        st = r6.init_rwkv6_state(2, D, self.cfg)
        return p, x, st

    def test_chunked_equals_stepwise(self):
        p, x, st = self._setup()
        y_par, sh, hl = r6.time_mix(p, x, self.cfg, st["tm_shift"], st["wkv"])
        state = {"wkv": st["wkv"], "tm_shift": st["tm_shift"]}
        outs = []
        for t in range(32):
            o, state = r6.step_time_mix(p, x[:, t:t + 1], self.cfg, state)
            outs.append(o)
        np.testing.assert_allclose(jnp.concatenate(outs, 1), y_par, atol=5e-5)
        np.testing.assert_allclose(hl, state["wkv"], atol=1e-5)

    def test_initial_state_is_consumed(self):
        """Nonzero wkv state must change outputs (cross-chunk correctness)."""
        p, x, st = self._setup()
        y0, _, _ = r6.time_mix(p, x, self.cfg, st["tm_shift"], st["wkv"])
        warm = jnp.ones_like(st["wkv"]) * 0.3
        y1, _, _ = r6.time_mix(p, x, self.cfg, st["tm_shift"], warm)
        assert not np.allclose(y0, y1)

    def test_decay_clamp_consistency(self):
        """Clamp applies identically in parallel and step paths (by shared
        _log_decay); extreme inputs stay finite."""
        p, x, st = self._setup()
        xb = x * 50.0
        y, _, _ = r6.time_mix(p, xb, self.cfg, st["tm_shift"], st["wkv"])
        assert bool(jnp.isfinite(y).all())

    def test_channel_mix_shift(self):
        p, x, st = self._setup()
        out, sh = r6.channel_mix(p, x, st["cm_shift"])
        assert out.shape == x.shape
        np.testing.assert_allclose(sh, x[:, -1], atol=0)
