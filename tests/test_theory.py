"""Empirical verification of the paper's theory: Theorem 1 (self-attention is
low rank / JL), Theorem 2 (linear attention approximation), Figure 1 spectrum
behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import low_rank


def _context_matrix(n=256, d=32, seed=0, sharp=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    q = jax.random.normal(ks[0], (n, d)) * sharp
    k = jax.random.normal(ks[1], (n, d)) * sharp
    return low_rank.context_mapping(q, k)


class TestTheorem1:
    def test_jl_error_decreases_with_k(self):
        """Theorem 1's k-dependence: the JL approximation error shrinks like
        ~1/sqrt(k). (The absolute relative error is large here because a
        random-logit P has near-uniform rows, so ||Pw|| is tiny relative to
        the additive JL error scale; trained attention in Figure 1 is the
        structured case.)"""
        P = _context_matrix()
        w = jax.random.normal(jax.random.PRNGKey(7), (256,))
        errs = []
        for k in (8, 32, 128):
            trials = [float(low_rank.jl_projection_error(
                jax.random.PRNGKey(100 + t * 7 + k), P, w, k))
                for t in range(8)]
            errs.append(np.mean(trials))
        assert errs[0] > errs[1] > errs[2]
        # 16x more projection dims -> ~4x less error (1/sqrt(k) scaling)
        assert errs[0] / errs[2] > 2.5
        assert errs[0] / errs[2] < 8.0

    def test_projection_rank_bounded(self):
        P = _context_matrix()
        n = P.shape[0]
        k = 16
        R = jax.random.normal(jax.random.PRNGKey(0), (k, n)) / np.sqrt(k)
        P_tilde = P @ R.T @ R
        rank = int(jnp.linalg.matrix_rank(P_tilde.astype(jnp.float32)))
        assert rank <= k


class TestTheorem2:
    def test_linear_attention_error_decreases_with_k(self):
        d = 32
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        a_row = jax.random.normal(ks[0], (256,))
        V = jax.random.normal(ks[1], (256, d))
        rel = []
        for k in (8, 32, 128):
            errs, refs = [], []
            for t in range(8):
                e, r = low_rank.theorem2_error(
                    jax.random.PRNGKey(200 + 13 * t + k), a_row, V, k)
                errs.append(float(e))
                refs.append(float(r))
            rel.append(np.mean(errs) / np.mean(refs))
        assert rel[0] > rel[1] > rel[2]


class TestSpectrum:
    """Figure 1: cumulative singular-value distribution of P."""

    def test_cumulative_spectrum_monotone_normalized(self):
        P = _context_matrix()
        spec = low_rank.cumulative_spectrum(P)
        assert spec.shape == (256,)
        assert float(spec[-1]) == pytest.approx(1.0, abs=1e-5)
        assert bool(jnp.all(jnp.diff(spec) >= -1e-7))

    def test_softmax_matrix_is_effectively_low_rank(self):
        """The paper's core claim: most spectral mass in few singular values.
        Softmax row-normalization concentrates mass — for moderate logit
        scales P is far from full-rank. (Extremely sharp RANDOM logits tend
        toward a permutation matrix, which is full rank — the trained-model
        spectrum is measured in benchmarks/figure1_spectrum.py.)"""
        e_flat = float(low_rank.energy_at_rank(_context_matrix(sharp=0.3),
                                               64))
        e_mid = float(low_rank.energy_at_rank(_context_matrix(sharp=1.0),
                                              64))
        assert e_flat > 0.95         # near rank-1: rows ≈ uniform
        assert e_mid > 0.5           # rank-64 of 256 holds most of the mass
        # an unnormalized random matrix has a much flatter spectrum
        g = jax.random.normal(jax.random.PRNGKey(3), (256, 256)) / 16
        s = jnp.linalg.svd(g, compute_uv=False)
        e_rand = float(jnp.cumsum(s)[63] / jnp.sum(s))
        assert e_mid > e_rand

    def test_rank_for_energy(self):
        P = _context_matrix(sharp=1.0)
        r90 = int(low_rank.rank_for_energy(P, 0.9))
        assert 1 <= r90 <= 192       # well below n=256

    def test_causal_mapping_rows_are_distributions(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        P = low_rank.context_mapping(q, k, causal=True)
        np.testing.assert_allclose(P.sum(-1), np.ones(64), atol=1e-5)
        assert float(jnp.abs(jnp.triu(P, k=1)).max()) < 1e-12
