"""Tolerance-banded low-precision parity suite for the paged, quantized
pool cache.

The paged cache stores the ring and the compressed page arena in int8 (or
fp8 where the jnp build supports it) with per-block fp32 scales, dequantized
inside the fused kernels. Quantization is the ONLY intended divergence from
the dense fp32 cache, so this suite pins three contracts:

* **Tolerance bands** (`DECODE_TOL` / `PREFILL_TOL`): paged decode/prefill
  attention vs the dense fp32 oracle stays inside a per-storage-dtype band.
  The bands are documented in docs/serving.md; measured worst-case error at
  the suite's shapes is ~0.013 (int8), so the 0.05 band has ~4x headroom
  without masking real regressions (a missing scale shows up as O(1)).
* **Backend parity** (`FUSED_TOL`): the fused Pallas kernels, which
  dequantize in VMEM, match the reference jnp path (which dequantizes
  up front) on IDENTICAL quantized operands — so the bands above measure
  quantization, never kernel bugs.
* **The chunked-admission rounding contract**: a prefill chunk attends
  earlier blocks CACHE-ROUNDED (dequantized pages), exactly — the same
  contract tests/test_chunked_prefill.py characterizes for the dense
  low-precision cache, one notch coarser.

Engine-level legs cover GQA (all configs here use Hkv < H), fold-boundary
prompt lengths, preempt/restore byte-identity under page pressure, and the
`pages_exhausted` shed reason.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, LinformerConfig, ModelConfig
from repro.core import cache as cache_lib
from repro.core.causal import blockwise_causal_prefix_attention
from repro.models import model as M
from repro.serving import ServingEngine, ShedResult
from repro.serving.scheduler import SHED_PAGES_EXHAUSTED

# Documented per-storage-dtype tolerance bands (max |paged - dense fp32|
# attention output, pre-softmax inputs O(1) normal). int8 rounds to
# 0.5/127 of each block's amax; fp8 e4m3 carries 3 mantissa bits, so its
# band is ~4x wider. docs/serving.md quotes these numbers.
DECODE_TOL = {"int8": 0.05, "fp8": 0.2}
PREFILL_TOL = {"int8": 0.05, "fp8": 0.2}
# fused-vs-reference on identical quantized operands: pure fp32 math
# reassociation, no quantization term.
FUSED_TOL = 1e-5

HAS_FP8 = getattr(jnp, "float8_e4m3fn", None) is not None
PAGE_DTYPES = ["int8"] + (["fp8"] if HAS_FP8 else [])

B, H, HKV, DH = 2, 4, 2, 8           # GQA: 2 query heads share each kv head
C, R, MAXP = 8, 4, 8                 # page = one fold of C tokens -> R slots
M_SLOTS = MAXP * R


def _inputs(S, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, H, DH))
    k = jax.random.normal(ks[1], (B, S, HKV, DH))
    v = jax.random.normal(ks[2], (B, S, HKV, DH))
    E = jax.random.normal(ks[3], (C, R)) * 0.3
    F = jax.random.normal(ks[4], (C, R)) * 0.3
    return q, k, v, E, F


def _dense_layer_cache():
    f32 = jnp.float32
    return {"raw_k": jnp.zeros((B, C, HKV, DH), f32),
            "raw_v": jnp.zeros((B, C, HKV, DH), f32),
            "comp_k": jnp.zeros((B, M_SLOTS, HKV, DH), f32),
            "comp_v": jnp.zeros((B, M_SLOTS, HKV, DH), f32)}


def _paged_layer_cache(page_dtype="int8", table="full"):
    """Single-layer paged cache slice. `table="full"` pre-allocates row b's
    pages as b*MAXP..(b+1)*MAXP-1 (the serving layer does this dynamically);
    `table="empty"` leaves every block unallocated (-1)."""
    n_pages = B * MAXP + 1                    # + TRASH
    pdt, _ = cache_lib.resolve_page_dtype(page_dtype)
    f32 = jnp.float32
    if table == "full":
        tab = jnp.arange(B * MAXP, dtype=jnp.int32).reshape(B, MAXP)
    else:
        tab = jnp.full((B, MAXP), -1, jnp.int32)
    return {"raw_k_q": jnp.zeros((B, C, HKV, DH), pdt),
            "raw_v_q": jnp.zeros((B, C, HKV, DH), pdt),
            "raw_k_s": jnp.zeros((B, C, HKV), f32),
            "raw_v_s": jnp.zeros((B, C, HKV), f32),
            "page_k": jnp.zeros((n_pages, R, HKV, DH), pdt),
            "page_v": jnp.zeros((n_pages, R, HKV, DH), pdt),
            "page_k_s": jnp.zeros((n_pages, HKV), f32),
            "page_v_s": jnp.zeros((n_pages, HKV), f32),
            "page_table": tab}


def _stream(S, *, plan="reference", page_dtype="int8", t0=None, seed=0):
    """Decode S tokens through BOTH caches (identical inputs), collecting
    per-step attention outputs. `t0` (B,) offsets rows to unequal positions
    — the continuous-batching case where every per-row (pos, blk) combo is
    live at once."""
    q, k, v, E, F = _inputs(S, seed=seed)
    dlc, plc = _dense_layer_cache(), _paged_layer_cache(page_dtype)
    base = jnp.zeros((B,), jnp.int32) if t0 is None else jnp.asarray(t0)
    outs_d, outs_p = [], []
    for t in range(S):
        tt = base + t
        sl = (q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1])
        od, dlc = cache_lib.compressed_decode_attention(
            *sl, dlc, E, F, tt, plan="reference")
        op, plc = cache_lib.paged_decode_attention(
            *sl, plc, E, F, tt, plan=plan)
        outs_d.append(od)
        outs_p.append(op)
    return (np.asarray(jnp.concatenate(outs_d, axis=1)),
            np.asarray(jnp.concatenate(outs_p, axis=1)), plc)


# ---------------------------------------------------------------------------
# Quantization primitives
# ---------------------------------------------------------------------------


class TestQuantization:
    def test_int8_roundtrip_error_bound(self):
        """Symmetric round-to-nearest int8: per-element reconstruction error
        is <= 0.5 * that block's scale — the bound the serving telemetry
        accumulates as `serving_quant_error_bound_sum`."""
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 2, 8))
        q, s = cache_lib.quantize_blockwise(x, (3,))
        deq = cache_lib.dequantize_blockwise(q, s)
        err = np.abs(np.asarray(deq) - np.asarray(x))
        bound = 0.5 * np.asarray(s)[..., None]
        assert (err <= bound + 1e-7).all()

    def test_scale_covers_amax(self):
        """qmax * scale >= amax: the block extreme is representable, so
        clipping never bites on the quantizer's own input."""
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 4)) * 100.0
        q, s = cache_lib.quantize_blockwise(x, (2,))
        amax = np.abs(np.asarray(x)).max(axis=2)
        assert (127.0 * np.asarray(s) >= amax - 1e-5).all()
        assert (np.abs(np.asarray(q, np.int32)) <= 127).all()

    def test_zero_block_safe(self):
        """An all-zero block quantizes to zeros with a tiny positive scale
        (no 0/0 NaN), and dequantizes back to exact zeros."""
        x = jnp.zeros((2, 8, 4))
        q, s = cache_lib.quantize_blockwise(x, (2,))
        assert np.isfinite(np.asarray(s)).all() and (np.asarray(s) > 0).all()
        assert (np.asarray(cache_lib.dequantize_blockwise(q, s)) == 0).all()

    def test_resolve_page_dtype(self):
        dt, qmax = cache_lib.resolve_page_dtype("int8")
        assert dt == jnp.int8 and qmax == 127.0
        with pytest.raises(ValueError, match="int8|fp8"):
            cache_lib.resolve_page_dtype("int4")
        if HAS_FP8:
            dt, qmax = cache_lib.resolve_page_dtype("fp8")
            assert qmax == 448.0
        else:
            with pytest.raises(ValueError, match="float8"):
                cache_lib.resolve_page_dtype("fp8")

    @pytest.mark.skipif(not HAS_FP8, reason="no jnp.float8_e4m3fn")
    def test_fp8_roundtrip_relative_error(self):
        """fp8 e4m3 (3 mantissa bits): relative reconstruction error per
        element stays under 2^-3 of the block amax."""
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 8))
        fp8 = jnp.float8_e4m3fn
        q, s = cache_lib.quantize_blockwise(x, (2,), dtype=fp8, qmax=448.0)
        deq = cache_lib.dequantize_blockwise(q, s)
        amax = np.abs(np.asarray(x)).max(axis=2, keepdims=True)
        err = np.abs(np.asarray(deq) - np.asarray(x))
        assert (err <= amax * 2.0 ** -3 + 1e-6).all()


# ---------------------------------------------------------------------------
# Decode parity: paged quantized vs dense fp32, and fused vs reference
# ---------------------------------------------------------------------------


class TestDecodeParity:
    @pytest.mark.parametrize("page_dtype", PAGE_DTYPES)
    def test_quantized_vs_fp32_band(self, page_dtype):
        """40 decode steps (5 full folds): every step's paged output is
        inside the storage dtype's band of the dense fp32 oracle."""
        outs_d, outs_p, _ = _stream(40, page_dtype=page_dtype)
        err = np.abs(outs_p - outs_d).max()
        assert err <= DECODE_TOL[page_dtype], \
            f"{page_dtype} decode error {err} exceeds band"

    @pytest.mark.parametrize("page_dtype", PAGE_DTYPES)
    def test_per_row_offsets(self, page_dtype):
        """Rows at unequal positions (t0 = [0, 16]): per-row masks, folds
        and page scatters stay inside the band — no cross-row mixing."""
        outs_d, outs_p, _ = _stream(
            17, page_dtype=page_dtype, t0=[0, 16], seed=3)
        err = np.abs(outs_p - outs_d).max()
        assert err <= DECODE_TOL[page_dtype]

    def test_fused_matches_reference(self):
        """Fused kernel (dequant in VMEM) vs reference (dequant in jnp) on
        identical quantized caches: fp32-reassociation-only difference, and
        the updated caches are byte-identical (bookkeeping is shared)."""
        _, ref, plc_ref = _stream(24, plan="reference", seed=1)
        _, fus, plc_fus = _stream(24, plan="fused", seed=1)
        assert np.abs(fus - ref).max() <= FUSED_TOL
        for key in plc_ref:
            np.testing.assert_array_equal(np.asarray(plc_ref[key]),
                                          np.asarray(plc_fus[key]), key)

    def test_trash_page_never_read(self):
        """Poisoning the TRASH page (saturated payloads, huge scales) must
        not change any output: TRASH is written by redirected folds but
        never becomes visible."""
        q, k, v, E, F = _inputs(24, seed=4)
        clean = _paged_layer_cache()
        poisoned = dict(clean)
        trash = clean["page_k"].shape[0] - 1
        poisoned["page_k"] = clean["page_k"].at[trash].set(127)
        poisoned["page_v"] = clean["page_v"].at[trash].set(-127)
        poisoned["page_k_s"] = clean["page_k_s"].at[trash].set(1e6)
        poisoned["page_v_s"] = clean["page_v_s"].at[trash].set(1e6)
        for t in range(24):
            sl = (q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1])
            oc, clean = cache_lib.paged_decode_attention(
                *sl, clean, E, F, jnp.full((B,), t, jnp.int32))
            op, poisoned = cache_lib.paged_decode_attention(
                *sl, poisoned, E, F, jnp.full((B,), t, jnp.int32))
            np.testing.assert_array_equal(np.asarray(oc), np.asarray(op))

    def test_unallocated_fold_redirects_to_trash(self):
        """With an all-unallocated table, a completed fold lands on TRASH
        and every real arena page stays zero — device code never allocates,
        and a missing page can't corrupt a neighbour."""
        q, k, v, E, F = _inputs(8, seed=5)
        plc = _paged_layer_cache(table="empty")
        for t in range(8):
            _, plc = cache_lib.paged_decode_attention(
                q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1],
                plc, E, F, jnp.full((B,), t, jnp.int32))
        pages_k = np.asarray(plc["page_k"])
        assert (pages_k[:-1] == 0).all()       # all real pages untouched
        assert (pages_k[-1] != 0).any()        # the fold DID go somewhere


# ---------------------------------------------------------------------------
# Prefill-chunk parity + the chunked-admission rounding contract
# ---------------------------------------------------------------------------


class TestPrefillParity:
    def _run_chunks(self, plan, page_dtype, S=32, P=16, seed=2):
        q, k, v, E, F = _inputs(S, seed=seed)
        dlc, plc = _dense_layer_cache(), _paged_layer_cache(page_dtype)
        outs_d, outs_p = [], []
        for t0 in range(0, S, P):
            tt = jnp.full((B,), t0, jnp.int32)
            sl = (q[:, t0:t0 + P], k[:, t0:t0 + P], v[:, t0:t0 + P])
            od, dlc = cache_lib.compressed_prefill_chunk(
                *sl, dlc, E, F, tt, plan="reference")
            op, plc = cache_lib.paged_prefill_chunk(
                *sl, plc, E, F, tt, plan=plan)
            outs_d.append(od)
            outs_p.append(op)
        return (np.asarray(jnp.concatenate(outs_d, axis=1)),
                np.asarray(jnp.concatenate(outs_p, axis=1)), plc)

    @pytest.mark.parametrize("page_dtype", PAGE_DTYPES)
    def test_quantized_vs_fp32_band(self, page_dtype):
        outs_d, outs_p, _ = self._run_chunks("reference", page_dtype)
        err = np.abs(outs_p - outs_d).max()
        assert err <= PREFILL_TOL[page_dtype], \
            f"{page_dtype} prefill error {err} exceeds band"

    def test_fused_matches_reference(self):
        _, ref, plc_ref = self._run_chunks("reference", "int8")
        _, fus, plc_fus = self._run_chunks("fused", "int8")
        assert np.abs(fus - ref).max() <= FUSED_TOL
        for key in plc_ref:
            np.testing.assert_array_equal(np.asarray(plc_ref[key]),
                                          np.asarray(plc_fus[key]), key)

    def test_rounding_contract_is_exactly_dequantized_pages(self):
        """The chunked-admission rounding contract, characterized: chunk 2's
        paged output equals BITWISE the dense prefix attention computed over
        the dequantized post-scatter page gather. Quantization of the
        visible prefix is the whole contract — there is no other divergence
        source (the dense-cache analogue lives in
        tests/test_chunked_prefill.py::TestPrefixAttentionParity)."""
        q, k, v, E, F = _inputs(32, seed=6)
        plc = _paged_layer_cache()
        _, plc = cache_lib.paged_prefill_chunk(
            q[:, :16], k[:, :16], v[:, :16], plc, E, F,
            jnp.zeros((B,), jnp.int32))
        out, plc = cache_lib.paged_prefill_chunk(
            q[:, 16:], k[:, 16:], v[:, 16:], plc, E, F,
            jnp.full((B,), 16, jnp.int32))
        gk, gk_s = cache_lib.paged_gather(
            plc["page_k"], plc["page_k_s"], plc["page_table"])
        gv, gv_s = cache_lib.paged_gather(
            plc["page_v"], plc["page_v_s"], plc["page_table"])
        want = blockwise_causal_prefix_attention(
            q[:, 16:], k[:, 16:], v[:, 16:],
            cache_lib.dequantize_blockwise(gk, gk_s),
            cache_lib.dequantize_blockwise(gv, gv_s),
            jnp.full((B,), 2, jnp.int32), block_size=C, block_slots=R,
            scale=DH ** -0.5)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------------------------------------
# Engine-level: GQA serving on fold-boundary lengths, preemption, shedding
# ---------------------------------------------------------------------------


def _cfg(max_seq=160):
    attn = AttentionConfig(
        kind="linformer_causal",
        backend="auto",
        num_heads=4,
        num_kv_heads=2,              # GQA on every engine leg
        head_dim=8,
        linformer=LinformerConfig(block_size=8, block_slots=4),
    )
    return ModelConfig(name="paged-cache-test", num_layers=2, d_model=32,
                       vocab_size=256, max_seq_len=max_seq, attention=attn,
                       dtype="float32", remat="none")


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), _cfg())


def _paged_engine(params, prefill_chunk=0, **kw):
    return ServingEngine(params, _cfg(), max_seq=160,
                         cache_dtype=jnp.float32, decode_chunk=4,
                         prefill_chunk=prefill_chunk, cache_format="paged",
                         **kw)


# fold-boundary coverage: < one block (5), exact block (8), mid-block (12),
# exact fold multiples (16, 32), fold+remainder (19, 40), long (61, 80)
LENS = [5, 8, 12, 16, 19, 32, 40, 61, 80, 24]


def _prompts(seed=0):
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(4, 256, L)) for L in LENS]
    budgets = [int(rng.choice([3, 6, 10])) for _ in LENS]
    return prompts, budgets


class TestPagedEngine:
    def test_serve_deterministic_and_leak_free(self, params):
        """Paged serve over fold-boundary lengths: repeatable outputs, the
        allocator's partition invariant holds afterwards, and every page
        came back (retire frees + scrubs)."""
        eng = _paged_engine(params)
        prompts, budgets = _prompts()
        out, sched = eng.serve(prompts, budgets, max_batch=4,
                               return_scheduler=True)
        assert all(o and not isinstance(o, ShedResult) for o in out)
        alloc = sched.pool.alloc
        alloc.check()
        assert alloc.free_pages == alloc.usable_pages
        assert sched.pool.pages_allocated == sched.pool.pages_freed > 0
        assert eng.serve(prompts, budgets, max_batch=4) == out

    def test_chunked_admission_rounding_contract(self, params):
        """Chunked vs monolithic admission on the SAME paged engine params:
        both modes complete, and the agreed-fraction floor documents the
        rounding contract at token granularity — divergence only where a
        near-tie argmax flips under the (deterministic) quantized-prefix
        rounding. Seeds are fixed, so this is exact, not statistical."""
        prompts, budgets = _prompts()
        mono = _paged_engine(params).serve(prompts, budgets, max_batch=4)
        chun = _paged_engine(params, prefill_chunk=16).serve(
            prompts, budgets, max_batch=4)
        agree = sum(a == b for a, b in zip(mono, chun))
        assert agree >= len(LENS) // 2, (mono, chun)
        assert all(len(o) == b for o, b in zip(chun, budgets))

    @pytest.mark.parametrize("prefill_chunk", [0, 16])
    def test_preempt_restore_byte_identical_under_page_pressure(
            self, params, prefill_chunk):
        """A page-tight arena forces page preemptions mid-decode; with
        snapshots enabled the preempted rows resume from quantized
        snapshots into FRESH physical pages — outputs must equal the
        uncontended run byte-for-byte (the table indirection makes physical
        placement invisible to the math)."""
        prompts, budgets = _prompts(seed=1)
        want = _paged_engine(params, prefill_chunk).serve(
            prompts, budgets, max_batch=4)
        tight = _paged_engine(params, prefill_chunk, arena_pages=14)
        out, sched = tight.serve(prompts, budgets, max_batch=4,
                                 snapshot_chunks=2, return_scheduler=True)
        assert out == want
        assert sched.stats.page_preemptions > 0
        sched.pool.alloc.check()
        assert sched.pool.alloc.free_pages == sched.pool.alloc.usable_pages

    def test_lifetime_infeasible_request_shed(self, params):
        """A request whose prompt+budget can NEVER fit the arena is shed
        with the explicit pages_exhausted reason instead of wedging the
        admission queue."""
        eng = _paged_engine(params, arena_pages=4)   # 3 usable pages
        prompts = [[1] * 40, [2] * 8]                # 40+6 needs 6 pages
        out = eng.serve(prompts, [6, 3], max_batch=2)
        assert isinstance(out[0], ShedResult)
        assert out[0].reason == SHED_PAGES_EXHAUSTED
        assert not isinstance(out[1], ShedResult)    # 8+3 fits in 2 pages

    @pytest.mark.skipif(not HAS_FP8, reason="no jnp.float8_e4m3fn")
    def test_fp8_engine_serves(self, params):
        """fp8 page storage end-to-end where supported: deterministic serve
        and clean page accounting (the parity band for fp8 is pinned at the
        cache level above)."""
        eng = _paged_engine(params, page_dtype="fp8")
        prompts, budgets = _prompts(seed=2)
        out, sched = eng.serve(prompts, budgets, max_batch=4,
                               return_scheduler=True)
        assert all(o and not isinstance(o, ShedResult) for o in out)
        sched.pool.alloc.check()
        assert eng.serve(prompts, budgets, max_batch=4) == out

    def test_fp8_requires_support(self, params):
        if HAS_FP8:
            pytest.skip("build has fp8; the negative leg is above")
        with pytest.raises(ValueError, match="float8"):
            _paged_engine(params, page_dtype="fp8")
