"""End-to-end system behaviour: train -> checkpoint -> preempt -> resume ->
serve, exercising the whole stack on a reduced Linformer LM; plus the
paper-track MLM encoder pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import OptimizerConfig, TrainConfig
from repro.models import model as M
from repro.serving import ServingEngine
from repro.train import Trainer
from tests.conftest import f32


def test_train_checkpoint_resume_serve(tmp_path):
    cfg = f32(get_smoke_config("qwen3-8b"))
    tcfg = TrainConfig(seq_len=32, global_batch=4, steps=8, log_every=100,
                       checkpoint_every=4, checkpoint_dir=str(tmp_path),
                       optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                 total_steps=50))
    # phase 1: train 8 steps with a checkpoint at 4 and 8
    tr = Trainer(cfg, tcfg, log_fn=lambda s: None)
    m = tr.run()
    assert tr.ckpt.latest_step() == 8

    # phase 2: "node failure" -> new trainer resumes at 8, trains to 12
    tcfg2 = dataclasses.replace(tcfg, steps=12)
    tr2 = Trainer(cfg, tcfg2, log_fn=lambda s: None)
    m2 = tr2.run()
    assert tr2.ckpt.latest_step() == 12
    assert np.isfinite(m2["loss"])

    # phase 3: serve with the trained weights
    restored, _ = tr2.ckpt.restore(
        12, {"params": M.init_params(jax.random.PRNGKey(0), cfg)})
    eng = ServingEngine(restored["params"], cfg, max_seq=64,
                        cache_dtype=jnp.float32)
    outs = eng.serve([[1, 2, 3, 4], [5, 6, 7, 8]], max_new_tokens=4)
    assert len(outs) == 2


def test_mlm_encoder_paper_track(tmp_path):
    """The paper-faithful track: exact Linformer encoder + MLM objective."""
    cfg = f32(get_smoke_config("linformer-paper"))
    assert cfg.objective == "mlm"
    assert cfg.attention.kind == "linformer"
    tcfg = TrainConfig(seq_len=64, global_batch=4, steps=20, log_every=100,
                       checkpoint_every=100, checkpoint_dir=str(tmp_path),
                       optimizer=OptimizerConfig(lr=2e-3, warmup_steps=2,
                                                 total_steps=100))
    tr = Trainer(cfg, tcfg, log_fn=lambda s: None)
    params, opt, ds = tr.init_state()
    from repro.data import pipeline
    stream = pipeline.batches(tr.corpus, ds, batch=4, seq=64,
                              objective="mlm")
    losses = []
    for _ in range(20):
        b, ds = next(stream)
        params, opt, m = tr.train_step(params, opt,
                                       jax.tree.map(jnp.asarray, b))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_standard_vs_linformer_parity_tiny():
    """Same init, same data: both attention kinds produce comparable losses
    (the paper's 'performs on par' claim, CPU-scale)."""
    cfg_lin = f32(get_smoke_config("linformer-paper"))
    cfg_std = cfg_lin.with_attention_kind("standard")
    from repro.data import DataState, SyntheticCorpus, make_mlm_batch
    corpus = SyntheticCorpus(cfg_lin.vocab_size, seed=0)
    b = jax.tree.map(jnp.asarray, make_mlm_batch(
        corpus, DataState(0, 0), batch=4, seq=64))
    p_lin = M.init_params(jax.random.PRNGKey(0), cfg_lin)
    p_std = M.init_params(jax.random.PRNGKey(0), cfg_std)
    l_lin, _ = M.loss_fn(p_lin, cfg_lin, b)
    l_std, _ = M.loss_fn(p_std, cfg_std, b)
    # at init both are ~ln(V); within 15%
    assert abs(float(l_lin) - float(l_std)) / float(l_std) < 0.15
