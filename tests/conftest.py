"""Shared test fixtures. NOTE: no XLA_FLAGS here — tests must see 1 device;
the multi-device dry-run tests spawn subprocesses with their own flags."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def f32(cfg):
    """Smoke configs in float32 for numerically tight assertions."""
    return dataclasses.replace(cfg, dtype="float32")


def make_batch(cfg, B=2, S=32, seed=0):
    rng_ = jax.random.PRNGKey(seed)
    if cfg.embedding_inputs:
        return {
            "embeds": jax.random.normal(rng_, (B, S, cfg.d_model),
                                        jnp.float32),
            "labels": jnp.zeros((B, S), jnp.int32),
            "loss_mask": jnp.ones((B, S), jnp.int32),
        }
    text = S - cfg.frontend_embed_len
    toks = jax.random.randint(rng_, (B, text), 0, cfg.vocab_size)
    b = {
        "tokens": toks,
        "labels": toks,
        "loss_mask": jnp.ones((B, text), jnp.int32),
    }
    if cfg.frontend_embed_len:
        b["frontend_embeds"] = jax.random.normal(
            jax.random.fold_in(rng_, 1),
            (B, cfg.frontend_embed_len, cfg.d_model), jnp.float32)
    return b
