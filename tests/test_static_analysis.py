"""repro-lint: positive/negative fixtures per rule, the pragma-waiver
grammar, the jaxpr audits (including injected-expectation negative legs),
the shared check-CLI convention, and the repo self-audit (the tree must
be lint-clean so scripts/static_baseline.json can stay empty)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import astlint
from repro.analysis import jaxpr_audit as JA

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint(sources, **kw):
    return astlint.lint_mapping(
        {k: textwrap.dedent(v) for k, v in sources.items()}, **kw)


def rules_of(res):
    return [f.rule for f in res.findings]


# ---------------------------------------------------------------------------
# RL000 — hygiene
# ---------------------------------------------------------------------------


class TestRL000:
    def test_print_in_library_code(self):
        res = lint({"src/repro/core/x.py": 'print("hi")\n'})
        assert rules_of(res) == ["RL000"]

    def test_print_allowed_in_launch(self):
        res = lint({"src/repro/launch/cli.py": 'print("hi")\n'})
        assert res.findings == []

    def test_committed_artifact(self):
        res = lint({}, tracked_paths=[
            "src/repro/core/__pycache__/x.cpython-311.pyc"])
        assert rules_of(res) == ["RL000"]
        assert "artifact" in res.findings[0].msg

    def test_pragma_without_reason_is_a_finding(self):
        res = lint({"src/repro/core/x.py": """\
            # repro-lint: allow[RL002]
            y = 1
            """})
        assert rules_of(res) == ["RL000"]
        assert "reason" in res.findings[0].msg

    def test_pragma_with_unknown_rule(self):
        res = lint({"src/repro/core/x.py": """\
            # repro-lint: allow[RL999] because
            y = 1
            """})
        assert rules_of(res) == ["RL000"]

    def test_prose_mention_is_not_a_pragma(self):
        res = lint({"src/repro/core/x.py": """\
            # repro-lint's RL005 rule is documented elsewhere
            y = 1
            """})
        assert res.findings == []

    def test_syntax_error_is_reported_not_raised(self):
        res = lint({"src/repro/core/x.py": "def broken(:\n"})
        assert rules_of(res) == ["RL000"]


# ---------------------------------------------------------------------------
# RL001 — dispatch purity
# ---------------------------------------------------------------------------


class TestRL001:
    def test_resolver_call_outside_plan(self):
        res = lint({"src/repro/models/x.py": """\
            def f(cfg, ctx):
                return resolve_backend(cfg, ctx)
            """})
        assert rules_of(res) == ["RL001"]

    def test_resolver_allowed_in_plan_layer(self):
        res = lint({"src/repro/parallel/plan.py": """\
            def g(cfg, ctx):
                return resolve_backend(cfg, ctx)
            """})
        assert res.findings == []

    def test_backend_string_compare(self):
        res = lint({"src/repro/models/x.py": """\
            def f(backend):
                if backend == "fused":
                    return 1
                return 0
            """})
        assert rules_of(res) == ["RL001"]

    def test_axis_names_membership(self):
        res = lint({"src/repro/train/x.py": """\
            def f(mesh):
                return "pod" in mesh.axis_names
            """})
        assert rules_of(res) == ["RL001"]

    def test_plain_string_compare_ok(self):
        res = lint({"src/repro/models/x.py": """\
            def f(kind):
                return kind == "linformer_causal"
            """})
        assert res.findings == []


# ---------------------------------------------------------------------------
# RL002 — host-sync discipline
# ---------------------------------------------------------------------------

HOT = "src/repro/serving/engine.py"


class TestRL002:
    def test_item_in_hot_module(self):
        res = lint({HOT: """\
            def f(x):
                return x.item()
            """})
        assert rules_of(res) == ["RL002"]

    def test_item_outside_hot_modules_ok(self):
        res = lint({"src/repro/data/x.py": """\
            def f(x):
                return x.item()
            """})
        assert res.findings == []

    def test_float_of_shape_is_host_safe(self):
        res = lint({HOT: """\
            def f(x):
                return float(x.shape[0])
            """})
        assert res.findings == []

    def test_np_asarray_of_device_data(self):
        res = lint({HOT: """\
            import numpy as np
            def f(x):
                return np.asarray(x)
            """})
        assert rules_of(res) == ["RL002"]

    def test_subscripted_container_stays_suspect(self):
        res = lint({HOT: """\
            def f(self):
                return int(self.cache["lengths"][0])
            """})
        assert rules_of(res) == ["RL002"]

    def test_pragma_waives_with_reason(self):
        res = lint({HOT: """\
            def f(x):
                # repro-lint: allow[RL002] the chunk's one sync
                return x.item()
            """})
        assert res.findings == []
        assert res.pragmas_used == 1

    def test_pragma_for_wrong_rule_does_not_waive(self):
        res = lint({HOT: """\
            def f(x):
                # repro-lint: allow[RL001] wrong rule
                return x.item()
            """})
        assert rules_of(res) == ["RL002"]


# ---------------------------------------------------------------------------
# RL003 — kernel contract
# ---------------------------------------------------------------------------

KERNEL = """\
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def _body(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def my_kernel(x):
        return pl.pallas_call(_body, out_shape=x)(x)
    """

GUARDED_OPS = """\
    MAX_PINNED_SLOTS = 64
    from repro.kernels import mykern as mk

    def fused_thing(x):
        if x.shape[0] > MAX_PINNED_SLOTS:
            raise ValueError("too many slots")
        return mk.my_kernel(x)
    """


class TestRL003:
    def test_unguarded_public_wrapper(self):
        res = lint({
            "src/repro/kernels/mykern.py": KERNEL,
            "src/repro/kernels/ops.py": """\
                from repro.kernels import mykern as mk

                def fused_thing(x):
                    return mk.my_kernel(x)
                """})
        assert rules_of(res) == ["RL003"]
        assert "fail-fast" in res.findings[0].msg

    def test_guarded_wrapper_clean(self):
        res = lint({
            "src/repro/kernels/mykern.py": KERNEL,
            "src/repro/kernels/ops.py": GUARDED_OPS})
        assert res.findings == []

    def test_direct_kernel_call_outside_kernels(self):
        res = lint({
            "src/repro/kernels/mykern.py": KERNEL,
            "src/repro/kernels/ops.py": GUARDED_OPS,
            "src/repro/models/x.py": """\
                from repro.kernels import mykern as mk

                def f(x):
                    return mk.my_kernel(x)
                """})
        assert rules_of(res) == ["RL003"]
        assert "direct call" in res.findings[0].msg

    def test_transitive_reach_needs_guard(self):
        res = lint({
            "src/repro/kernels/mykern.py": KERNEL,
            "src/repro/kernels/ops.py": """\
                from repro.kernels import mykern as mk

                def _inner(x):
                    return mk.my_kernel(x)

                def fused_outer(x):
                    return _inner(x)
                """})
        assert rules_of(res) == ["RL003"]
        assert "fused_outer" in res.findings[0].msg

    def test_non_fp32_vmem_scratch(self):
        res = lint({"src/repro/kernels/bad.py": """\
            import jax.numpy as jnp
            from jax.experimental import pallas as pl
            from jax.experimental.pallas import tpu as pltpu

            def _body(x_ref, o_ref, acc):
                o_ref[...] = x_ref[...]

            def k(x):
                if x.shape[0] % 8 != 0:
                    raise ValueError("grid")
                return pl.pallas_call(
                    _body, out_shape=x,
                    scratch_shapes=[pltpu.VMEM((8, 8), jnp.bfloat16)])(x)
            """})
        assert rules_of(res) == ["RL003"]
        assert "fp32" in res.findings[0].msg

    def test_fp32_vmem_scratch_clean(self):
        res = lint({"src/repro/kernels/good.py": """\
            import jax.numpy as jnp
            from jax.experimental import pallas as pl
            from jax.experimental.pallas import tpu as pltpu

            def _body(x_ref, o_ref, acc):
                o_ref[...] = x_ref[...]

            def k(x):
                if x.shape[0] % 8 != 0:
                    raise ValueError("grid")
                return pl.pallas_call(
                    _body, out_shape=x,
                    scratch_shapes=[pltpu.VMEM((8, 8), jnp.float32)])(x)
            """})
        assert res.findings == []


# ---------------------------------------------------------------------------
# RL004 — donation safety
# ---------------------------------------------------------------------------


class TestRL004:
    def test_donation_outside_allowed_modules(self):
        res = lint({"src/repro/models/x.py": """\
            import jax
            f = jax.jit(lambda x: x, donate_argnums=(0,))
            """})
        assert rules_of(res) == ["RL004"]

    def test_donation_allowed_in_trainer(self):
        res = lint({"src/repro/train/trainer.py": """\
            import jax
            f = jax.jit(lambda x: x, donate_argnums=(0,))
            """})
        assert res.findings == []


# ---------------------------------------------------------------------------
# RL005 — spec hygiene
# ---------------------------------------------------------------------------


class TestRL005:
    def test_undeclared_axis_literal(self):
        res = lint({"src/repro/parallel/x.py": """\
            from jax.sharding import PartitionSpec as P
            spec = P("bogus", None)
            """}, declared_axes={"data", "model", "seq", "pod"})
        assert rules_of(res) == ["RL005"]
        assert "bogus" in res.findings[0].msg

    def test_declared_axes_clean(self):
        res = lint({"src/repro/parallel/x.py": """\
            from jax.sharding import PartitionSpec as P
            spec = P("data", "model")
            """}, declared_axes={"data", "model", "seq", "pod"})
        assert res.findings == []

    def test_registry_read_from_plan_source(self):
        plan = 'DECLARED_AXES = frozenset({"data"})\n'
        res = lint({
            "src/repro/parallel/plan.py": plan,
            "src/repro/parallel/x.py": """\
                from jax.sharding import PartitionSpec as P
                spec = P("data")
                bad = P("model")
                """})
        assert rules_of(res) == ["RL005"]
        assert "model" in res.findings[0].msg

    def test_repo_plan_declares_the_four_axes(self):
        from repro.parallel import plan
        assert plan.DECLARED_AXES == {"data", "model", "seq", "pod"}


# ---------------------------------------------------------------------------
# RL006 — tuning discipline
# ---------------------------------------------------------------------------


class TestRL006:
    def test_literal_block_q_at_fused_call_site(self):
        res = lint({"src/repro/models/x.py": """\
            from repro.kernels import ops
            out = ops.fused_linformer_attention(q, k, v, scale=1.0,
                                                block_q=128)
            """})
        assert rules_of(res) == ["RL006"]
        assert "block_q=128" in res.findings[0].msg

    def test_literal_q_chunk_blocks_at_chunked_call_site(self):
        res = lint({"src/repro/models/x.py": """\
            from repro.core.causal import blockwise_causal_attention_chunked
            out = blockwise_causal_attention_chunked(
                q, k, v, E, F, block_size=64, q_chunk_blocks=4)
            """})
        assert rules_of(res) == ["RL006"]

    def test_variable_knob_is_clean(self):
        res = lint({"src/repro/models/x.py": """\
            from repro.kernels import ops
            bq = resolve_somehow()
            out = ops.fused_seq_projection(x, E, block_s=bq)
            """})
        assert res.findings == []

    def test_literal_allowed_in_tune_and_common(self):
        src = """\
            from repro.kernels import ops
            out = ops.fused_seq_projection(x, E, block_s=128)
            """
        for rel in ("src/repro/tune/autotune.py",
                    "src/repro/kernels/common.py"):
            assert lint({rel: src}).findings == []

    def test_block_size_kwarg_is_not_a_tuned_knob(self):
        # block_size is a MODEL hyperparameter (the causal form's c),
        # not a kernel grid knob — literals there are fine anywhere
        res = lint({"src/repro/models/x.py": """\
            from repro.core.causal import blockwise_causal_attention_chunked
            out = blockwise_causal_attention_chunked(
                q, k, v, E, F, block_size=64)
            """})
        assert res.findings == []

    def test_pragma_waives_rl006(self):
        res = lint({"src/repro/models/x.py": """\
            # repro-lint: allow[RL006] parity fixture pins the grid
            out = fused_linformer_attention(q, k, v, scale=1.0, block_q=64)
            """})
        assert res.findings == []


# ---------------------------------------------------------------------------
# Self-audit: the tree itself is clean, so the shipped baseline is empty
# ---------------------------------------------------------------------------


class TestSelfAudit:
    def test_tree_is_lint_clean(self):
        res = astlint.lint_tree(ROOT)
        assert res.findings == [], "\n".join(
            f"{f.rule} {f.path}:{f.line}: {f.msg}" for f in res.findings)
        assert res.files_checked > 50
        assert res.pragmas_used > 0      # the triaged RL001/RL002 waivers

    def test_shipped_baseline_is_empty(self):
        with open(os.path.join(ROOT, "scripts",
                               "static_baseline.json")) as fh:
            assert json.load(fh) == []


# ---------------------------------------------------------------------------
# jaxpr audits
# ---------------------------------------------------------------------------


class TestJaxprAudit:
    def test_sp_causal_matches_comm_model(self):
        findings, stats = JA.audit_sp_causal()
        assert findings == []
        assert stats["all_gathers"] == 2
        assert stats["gathered_bytes"] == stats["model_bytes"]

    def test_sp_causal_fires_on_injected_expectation(self):
        findings, _ = JA.audit_sp_causal(expect_lin=1)
        assert [f.rule for f in findings] == ["JX002"]
        assert findings[0].path == "jaxpr:sp_causal"

    def test_sp_exact_matches_comm_model(self):
        findings, stats = JA.audit_sp_exact()
        assert findings == []
        assert stats["psums"] == 2
        assert stats["psum_bytes"] == stats["model_bytes"]

    def test_sp_exact_fires_on_injected_expectation(self):
        findings, _ = JA.audit_sp_exact(expect_lin=1)
        assert [f.rule for f in findings] == ["JX002"]

    def test_decode_scan_body_is_host_effect_free(self):
        findings, stats = JA.audit_decode()
        assert findings == []
        assert stats["scan_eqns"] >= 1
        assert stats["host_effects"] == 0
        assert stats["widenings"] == 0

    def test_host_effect_detection_fires_on_debug_print(self):
        def noisy(x):
            def body(c, _):
                jax.debug.print("c={c}", c=c)
                return c + 1, c
            return jax.lax.scan(body, x, None, length=3)

        jpr = jax.make_jaxpr(noisy)(jnp.float32(0))
        bodies = JA.scan_bodies(jpr)
        assert len(bodies) == 1
        prims = JA.host_effect_prims(bodies[0])
        assert any("callback" in p or "debug" in p for p in prims)

    def test_widening_detection(self):
        jpr = jax.make_jaxpr(lambda x: x.astype(jnp.float16))(
            jnp.zeros(3, jnp.float32))
        assert JA.widenings(jpr, {"float16"}) == ["float16"]
        assert JA.widenings(jpr) == []     # f16 is not a forbidden widen

    def test_prefill_and_train_traces_clean(self):
        for fn in (JA.audit_prefill, JA.audit_train):
            findings, stats = fn()
            assert findings == []
            assert stats["host_effects"] == 0


# ---------------------------------------------------------------------------
# the shared check-CLI convention (scripts/_checklib.py)
# ---------------------------------------------------------------------------


def run_check(*argv):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, *argv], cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=600)


class TestCheckCli:
    def test_check_static_clean_and_json(self, tmp_path):
        out = tmp_path / "lint.json"
        r = run_check("scripts/check_static.py", "--no-jaxpr",
                      "--json", str(out))
        assert r.returncode == 0, r.stdout + r.stderr
        doc = json.loads(out.read_text())
        assert doc["check"] == "check_static"
        assert doc["ok"] is True
        assert doc["findings"] == []
        assert doc["stats"]["files"] > 50
        assert set(doc["rules"]) >= {"RL000", "RL005"}

    def test_check_static_nonzero_on_unbaselined_findings(self, tmp_path):
        # a baseline pointing at nothing real cannot mask anything; prove
        # the exit-code mapping with the library the driver uses
        sys.path.insert(0, os.path.join(ROOT, "scripts"))
        try:
            import _checklib
        finally:
            sys.path.pop(0)
        code = _checklib.report(
            "probe", [_checklib.finding("boom", rule="RL000")],
            json_path=str(tmp_path / "probe.json"))
        assert code == _checklib.EXIT_FINDINGS
        doc = json.loads((tmp_path / "probe.json").read_text())
        assert doc["ok"] is False and doc["findings"][0]["rule"] == "RL000"

    def test_check_trace_usage_and_failure_exits(self):
        r = run_check("scripts/check_trace.py")
        assert r.returncode == 2
        assert "usage:" in r.stderr
        r = run_check("scripts/check_trace.py", "/nonexistent.json",
                      "/nonexistent.jsonl")
        assert r.returncode == 1
        assert "FAILED" in r.stderr

    def test_check_docs_json_and_usage(self):
        r = run_check("scripts/check_docs.py", "--json", "-")
        assert r.returncode == 0, r.stdout + r.stderr
        doc = json.loads(r.stdout)
        assert doc["check"] == "check_docs" and doc["ok"] is True
        r = run_check("scripts/check_docs.py", "unexpected-arg")
        assert r.returncode == 2

    def test_report_lint_summary(self, tmp_path):
        out = tmp_path / "lint.json"
        r = run_check("scripts/check_static.py", "--no-jaxpr",
                      "--json", str(out))
        assert r.returncode == 0, r.stdout + r.stderr
        r = run_check("-m", "benchmarks.report", "--lint", str(out))
        assert r.returncode == 0, r.stdout + r.stderr
        assert "CLEAN" in r.stdout
        r = run_check("-m", "benchmarks.report", "--lint",
                      str(tmp_path / "missing.json"))
        assert r.returncode == 1


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-v"]))
