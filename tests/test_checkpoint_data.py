"""Checkpointer (fault tolerance) + data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import (ByteTokenizer, DataState, SyntheticCorpus,
                        make_causal_batch, make_mlm_batch)
from repro.data.pipeline import MASK, VOCAB_RESERVED


class TestCheckpointer:
    def _state(self):
        return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                           "nested": {"b": jnp.ones((4,), jnp.bfloat16)}},
                "opt_state": {"step": jnp.asarray(7, jnp.int32)}}

    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        st = self._state()
        ck.save(10, st, metadata={"data_state": {"seed": 1, "step": 10}})
        restored, meta = ck.restore(10, st)
        np.testing.assert_allclose(restored["params"]["w"], st["params"]["w"])
        assert restored["params"]["nested"]["b"].dtype == jnp.bfloat16
        assert meta["step"] == 10
        assert meta["data_state"]["step"] == 10

    def test_latest_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        st = self._state()
        for s in (1, 2, 3, 4):
            ck.save(s, st)
        assert ck.latest_step() == 4
        assert ck.all_steps() == [3, 4]     # older GC'd

    def test_interrupted_write_is_invisible(self, tmp_path):
        """A crashed writer leaves only a .tmp dir — restore ignores it."""
        ck = Checkpointer(str(tmp_path))
        st = self._state()
        ck.save(1, st)
        os.makedirs(str(tmp_path / "step_00000002.tmp"))  # simulated crash
        assert ck.latest_step() == 1
        restored, _ = ck.restore_latest(st)
        assert restored is not None

    def test_shape_mismatch_rejected(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, self._state())
        bad = self._state()
        bad["params"]["w"] = jnp.zeros((3, 3))
        with pytest.raises(ValueError):
            ck.restore(1, bad)

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore onto explicit (single-device) shardings — the elastic-
        restart path; on a real mesh the same call reshards to new topology."""
        from jax.sharding import SingleDeviceSharding
        ck = Checkpointer(str(tmp_path))
        st = self._state()
        ck.save(1, st)
        dev = jax.devices()[0]
        sh = {"params": jax.tree.map(lambda _: SingleDeviceSharding(dev),
                                     st["params"])}
        restored, _ = ck.restore(1, {"params": st["params"]}, sh)
        assert restored["params"]["w"].sharding == SingleDeviceSharding(dev)


class TestDataPipeline:
    def test_deterministic_across_instances(self):
        c1 = SyntheticCorpus(512, seed=3)
        c2 = SyntheticCorpus(512, seed=3)
        s = DataState(3, 5)
        b1 = make_causal_batch(c1, s, batch=4, seq=64)
        b2 = make_causal_batch(c2, s, batch=4, seq=64)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_step_and_shard_change_data(self):
        c = SyntheticCorpus(512)
        b0 = make_causal_batch(c, DataState(0, 0), batch=2, seq=64)
        b1 = make_causal_batch(c, DataState(0, 1), batch=2, seq=64)
        bs = make_causal_batch(c, DataState(0, 0), batch=2, seq=64, shard=1)
        assert not np.array_equal(b0["tokens"], b1["tokens"])
        assert not np.array_equal(b0["tokens"], bs["tokens"])

    def test_causal_labels_shifted(self):
        c = SyntheticCorpus(512)
        b = make_causal_batch(c, DataState(0, 0), batch=2, seq=64)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_mlm_masking_stats(self):
        c = SyntheticCorpus(512)
        b = make_mlm_batch(c, DataState(0, 0), batch=8, seq=256,
                           mask_prob=0.15)
        frac = b["loss_mask"].mean()
        assert 0.10 < frac < 0.20
        masked = b["loss_mask"].astype(bool)
        # ~80% of masked inputs are [MASK]
        mask_tok_frac = (b["tokens"][masked] == MASK).mean()
        assert 0.6 < mask_tok_frac < 0.95
        # unmasked positions keep original ids
        np.testing.assert_array_equal(b["tokens"][~masked],
                                      b["labels"][~masked])

    def test_tokens_in_range(self):
        c = SyntheticCorpus(512)
        b = make_causal_batch(c, DataState(0, 0), batch=2, seq=128)
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < 512

    def test_copy_structure_is_learnable_signal(self):
        """Sequences contain exact repeated spans (recall structure)."""
        c = SyntheticCorpus(4096, seed=0)
        rng = np.random.default_rng(0)
        seq = c.sequence(np.random.default_rng(1), 512)
        # find at least one repeated 4-gram
        grams = {}
        reps = 0
        for i in range(len(seq) - 4):
            g = tuple(seq[i:i + 4])
            reps += grams.get(g, 0)
            grams[g] = grams.get(g, 0) + 1
        assert reps > 0

    def test_byte_tokenizer_roundtrip(self):
        tok = ByteTokenizer()
        s = "Linformer: O(n) attention! ünïcode"
        assert tok.decode(tok.encode(s)) == s
        assert tok.encode(s).min() >= VOCAB_RESERVED
