"""Multi-device tests (subprocess with 8 host devices): sharded == local for
the MoE shard_map, sharding rules, tiny-mesh lower+compile, and the HLO cost
analyzer on a real partitioned module.

These run in subprocesses because the main test process must keep 1 device.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_moe_shard_map_matches_local():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import MLPConfig, MoEConfig
        from repro.models.moe import apply_moe, init_moe
        from repro.parallel.sharding import ParallelCtx
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(model_shards=4)   # 2 data x 4 model
        cfg = MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                        capacity_factor=8.0)
        mlp = MLPConfig(activation="swiglu")
        p = init_moe(jax.random.PRNGKey(0), 16, cfg, mlp, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
        local, aux_l = apply_moe(p, x, cfg, mlp, None)
        ctx = ParallelCtx(mesh=mesh)
        with mesh:
            sharded, aux_s = jax.jit(
                lambda pp, xx: apply_moe(pp, xx, cfg, mlp, ctx))(p, x)
        err = float(jnp.abs(local - sharded).max())
        print("ERR", err)
        # capacity is computed from LOCAL token counts (T/2 per shard) so
        # with generous capacity_factor routing is identical
        assert err < 1e-4, err
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_moe_weight_stationary_decode_matches_local():
    """§Perf iteration (kimi decode): weights stay sharded, tokens move."""
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs.base import MLPConfig, MoEConfig
        from repro.models.moe import apply_moe, init_moe
        from repro.parallel.sharding import ParallelCtx
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(model_shards=4)
        cfg = MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                        capacity_factor=8.0, weight_stationary_decode=True,
                        capacity_floor_one=True)
        mlp = MLPConfig(activation="swiglu")
        p = init_moe(jax.random.PRNGKey(0), 16, cfg, mlp, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 1, 16))
        local, _ = apply_moe(p, x, dataclasses.replace(
            cfg, weight_stationary_decode=False), mlp, None)
        ctx = ParallelCtx(mesh=mesh, fsdp="data")
        with mesh:
            ws, _ = jax.jit(lambda pp, xx: apply_moe(pp, xx, cfg, mlp,
                                                     ctx))(p, x)
        err = float(jnp.abs(local - ws).max())
        assert err < 1e-4, err
        print("OK", err)
        """)
    assert "OK" in out


@pytest.mark.slow
def test_tiny_mesh_train_step_compiles_with_shardings():
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.configs.base import OptimizerConfig
        from repro.launch.mesh import make_local_mesh
        from repro.models import model as M
        from repro.optim import adamw_init
        from repro.parallel.sharding import ParallelCtx, param_shardings
        from repro.train.trainer import make_train_step

        cfg = dataclasses.replace(get_smoke_config("qwen3-moe-30b-a3b"),
                                  dtype="float32")
        mesh = make_local_mesh(model_shards=4)
        ctx = ParallelCtx(mesh=mesh, fsdp="data")
        params_abs = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        p_sh = param_shardings(params_abs, ctx)
        opt_abs = jax.eval_shape(
            lambda: adamw_init(params_abs, OptimizerConfig()))
        from jax.sharding import NamedSharding, PartitionSpec as P
        o_sh = {"mu": param_shardings(opt_abs["mu"], ctx),
                "nu": param_shardings(opt_abs["nu"], ctx),
                "step": NamedSharding(mesh, P())}
        batch = {
            "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((4, 32), jnp.int32),
        }
        b_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
        step = make_train_step(cfg, OptimizerConfig(), ctx=ctx)
        with mesh:
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
                params_abs, opt_abs, batch)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):   # older jax: one dict per device
            cost = cost[0]
        assert float(cost.get("flops", 0)) > 0
        print("OK flops", cost.get("flops"))
        """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_training_matches_single_device():
    """Numerical parity: DP+TP sharded train step == unsharded step."""
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.configs.base import OptimizerConfig
        from repro.launch.mesh import make_local_mesh
        from repro.models import model as M
        from repro.optim import adamw_init
        from repro.parallel.sharding import ParallelCtx, param_shardings
        from repro.train.trainer import make_train_step

        cfg = dataclasses.replace(get_smoke_config("qwen3-8b"),
                                  dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0)
        opt = adamw_init(params, ocfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 512)
        batch = {"tokens": toks, "labels": toks,
                 "loss_mask": jnp.ones((4, 32), jnp.int32)}

        ref_step = make_train_step(cfg, ocfg)
        p1, o1, m1 = jax.jit(ref_step)(params, opt, batch)

        mesh = make_local_mesh(model_shards=2)
        ctx = ParallelCtx(mesh=mesh, fsdp="data")
        step = make_train_step(cfg, ocfg, ctx=ctx)
        p_sh = param_shardings(params, ctx)
        with mesh:
            p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, None, None))(
                params, opt, batch)
        print("LOSS", float(m1["loss"]), float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
        d = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print("MAXDIFF", d)
        assert d < 1e-4
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_seq_parallel_linformer_matches_exact():
    """Beyond-paper: sequence-parallel projection psums only (k x d)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.core.seq_parallel import seq_parallel_linformer_attention
        from repro.core import exact_linformer_attention
        from repro.parallel.sharding import ParallelCtx
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(model_shards=8)
        ctx = ParallelCtx(mesh=mesh)
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (2, 64, 4, 8))
        k = jax.random.normal(ks[1], (2, 64, 2, 8))
        v = jax.random.normal(ks[2], (2, 64, 2, 8))
        E = jax.random.normal(ks[3], (64, 16)) * 0.25
        F = jax.random.normal(ks[4], (64, 16)) * 0.25
        ref = exact_linformer_attention(q, k, v, E, F)
        with mesh:
            o = jax.jit(lambda *a: seq_parallel_linformer_attention(
                *a, ctx))(q, k, v, E, F)
        err = float(jnp.abs(o - ref).max())
        assert err < 1e-4, err
        print("OK", err)
        """)
    assert "OK" in out


@pytest.mark.slow
def test_hlo_cost_analyzer_counts_loop_collectives():
    """FSDP all-gathers inside a scanned layer loop must be multiplied by the
    trip count (the motivation for hlo_cost.py)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_cost import analyze_text
        mesh = jax.make_mesh((8,), ("data",))
        L, D = 7, 64

        def f(ws, x):
            def body(h, w):
                w = jax.lax.with_sharding_constraint(
                    w, NamedSharding(mesh, P(None, None)))
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, ws)
            return h.sum()

        ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
        x = jax.ShapeDtypeStruct((16, D), jnp.float32)
        sh = NamedSharding(mesh, P(None, "data", None))   # fsdp-style
        with mesh:
            c = jax.jit(f, in_shardings=(sh, NamedSharding(mesh, P()))
                        ).lower(ws, x).compile()
        a = analyze_text(c.as_text())
        ag = a["collectives"]["all-gather"]
        print("AG", ag)
        assert ag["count"] >= L   # one gather per layer iteration
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_cross_pod_gradients_track_exact():
    """EF-int8 cross-pod DP (train/compressed_dp.py): first step identical
    (quantization is absorbed by clip+Adam sign structure at step 1), later
    steps track exact training within quantization noise."""
    out = run_py("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.configs.base import OptimizerConfig
        from repro.models import model as M
        from repro.optim import adamw_init
        from repro.parallel.sharding import ParallelCtx
        from repro.train.trainer import make_train_step
        from repro.train.compressed_dp import (make_compressed_train_step,
                                               init_residual)

        cfg = dataclasses.replace(get_smoke_config("qwen3-8b"),
                                  dtype="float32")
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ctx = ParallelCtx(mesh=mesh, fsdp="data")
        ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params, ocfg)
        ref_step = jax.jit(make_train_step(cfg, ocfg))
        comp_step = jax.jit(make_compressed_train_step(cfg, ocfg, ctx))
        res = init_residual(params, 2)
        pe, oe, pc, oc = params, opt, params, opt
        for s in range(3):
            toks = jax.random.randint(jax.random.PRNGKey(s), (8, 32), 0,
                                      cfg.vocab_size)
            b = {"tokens": toks, "labels": toks,
                 "loss_mask": jnp.ones((8, 32), jnp.int32)}
            pe, oe, me = ref_step(pe, oe, b)
            with mesh:
                pc, oc, res, mc = comp_step(pc, oc, res, b)
            diff = abs(float(me["loss"]) - float(mc["loss"]))
            assert diff < 5e-3, (s, diff)
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_trainer_with_compressed_pod_grads_end_to_end():
    """TrainConfig.compressed_pod_grads: full loop incl. residual
    checkpointing + resume on a (pod,data,model) mesh."""
    out = run_py("""
        import dataclasses, tempfile, jax
        from repro.configs import get_smoke_config
        from repro.configs.base import OptimizerConfig, TrainConfig
        from repro.parallel.sharding import ParallelCtx
        from repro.train import Trainer

        cfg = dataclasses.replace(get_smoke_config("qwen3-8b"),
                                  dtype="float32")
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ctx = ParallelCtx(mesh=mesh, fsdp="none")
        d = tempfile.mkdtemp()
        tcfg = TrainConfig(seq_len=32, global_batch=8, steps=6, log_every=99,
                           checkpoint_every=3, checkpoint_dir=d,
                           compressed_pod_grads=True,
                           optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                     total_steps=20))
        with mesh:
            m = Trainer(cfg, tcfg, log_fn=lambda s: None, ctx=ctx).run()
            tr2 = Trainer(cfg, dataclasses.replace(tcfg, steps=8),
                          log_fn=lambda s: None, ctx=ctx)
            p, o, ds, start = tr2.restore_or_init()
            assert start == 6, start
            m2 = tr2.run()
        assert m2["loss"] < 8.0
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_restart_trainer_on_mesh():
    """Checkpoint written single-device, resumed on an 8-device mesh with
    resharding — the elastic-restart path end to end."""
    out = run_py("""
        import dataclasses, tempfile, jax
        from repro.configs import get_smoke_config
        from repro.configs.base import OptimizerConfig, TrainConfig
        from repro.launch.mesh import make_local_mesh
        from repro.parallel.sharding import ParallelCtx
        from repro.train import Trainer

        cfg = dataclasses.replace(get_smoke_config("qwen3-8b"),
                                  dtype="float32")
        d = tempfile.mkdtemp()
        tcfg = TrainConfig(seq_len=32, global_batch=8, steps=4, log_every=99,
                          checkpoint_every=2, checkpoint_dir=d,
                          optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1,
                                                    total_steps=20))
        # phase 1: single-device "cluster"
        Trainer(cfg, tcfg, log_fn=lambda s: None).run()
        # phase 2: "grown" cluster — 8 devices, 2-way TP
        mesh = make_local_mesh(model_shards=2)
        ctx = ParallelCtx(mesh=mesh, fsdp="data")
        tcfg2 = dataclasses.replace(tcfg, steps=6)
        with mesh:
            tr = Trainer(cfg, tcfg2, ctx=ctx, log_fn=lambda s: None)
            params, opt, ds, start = tr.restore_or_init()
            assert start == 4, start
            # params actually sharded on the new mesh
            shardings = {str(x.sharding) for x in jax.tree.leaves(params)}
            assert any("model" in s for s in shardings), shardings
            m = tr.run()
        assert m["loss"] > 0
        print("OK")
        """)
    assert "OK" in out


def test_param_sharding_rules():
    """Path-based rules produce the documented PartitionSpecs."""
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import spec_for_path
    assert spec_for_path("layers/attn/wq", ("data",), 3) == \
        P(None, "data", "model")
    assert spec_for_path("layers/attn/wo", ("data",), 3) == \
        P(None, "model", "data")
    assert spec_for_path("layers/moe/w_in", ("data",), 4) == \
        P(None, "model", "data", None)
    assert spec_for_path("embed/tok", (), 2) == P("model", None)
    assert spec_for_path("lm_head", ("pod", "data"), 2) == \
        P(("pod", "data"), "model")
    # shared zamba block: rank-2 (no layer axis)
    assert spec_for_path("shared_block/attn/wq", (), 2) == P(None, "model")
    # linformer E/F replicated
    assert spec_for_path("shared/lin/E", ("data",), 2) == P(None, None)
    # rwkv
    assert spec_for_path("layers/rwkv/w_r", ("data",), 3) == \
        P(None, "data", "model")
