"""Blockwise-causal Linformer: equivalences + strict causality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (blockwise_causal_attention,
                        blockwise_causal_attention_chunked,
                        compressed_decode_attention, init_compressed_cache)


def _qkv(B=2, S=32, H=4, Hkv=2, Dh=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, H, Dh)),
            jax.random.normal(ks[1], (B, S, Hkv, Dh)),
            jax.random.normal(ks[2], (B, S, Hkv, Dh)))


EF = jax.random.normal(jax.random.PRNGKey(42), (8, 4)) * 0.3


class TestParallelForm:
    def test_chunked_equals_unchunked(self):
        q, k, v = _qkv()
        o1 = blockwise_causal_attention(q, k, v, EF, EF, block_size=8)
        o2 = blockwise_causal_attention_chunked(q, k, v, EF, EF, block_size=8,
                                                q_chunk_blocks=2)
        np.testing.assert_allclose(o1, o2, atol=1e-6)

    def test_rejects_non_multiple_length(self):
        q, k, v = _qkv(S=30)
        with pytest.raises(ValueError):
            blockwise_causal_attention(q, k, v, EF, EF, block_size=8)

    def test_strict_causality(self):
        """Perturbing token t must not change outputs at positions < t."""
        q, k, v = _qkv()
        base = blockwise_causal_attention(q, k, v, EF, EF, block_size=8)
        t = 17
        k2 = k.at[:, t:].add(3.0)
        v2 = v.at[:, t:].add(-2.0)
        q2 = q.at[:, t:].add(1.0)
        pert = blockwise_causal_attention(q2, k2, v2, EF, EF, block_size=8)
        np.testing.assert_allclose(base[:, :t], pert[:, :t], atol=1e-6)
        # and the perturbation is visible at position >= t
        assert not np.allclose(base[:, t:], pert[:, t:])

    def test_first_block_is_pure_local(self):
        """Block 0 has no compressed prefix -> exact causal attention."""
        q, k, v = _qkv()
        out = blockwise_causal_attention(q, k, v, EF, EF, block_size=8)
        # reference: standard causal attention on the first 8 positions
        from tests.test_core_linformer import _std_attention
        ref = _std_attention(q[:, :8], k[:, :8], v[:, :8], causal=True)
        np.testing.assert_allclose(out[:, :8], ref, atol=2e-5)

    def test_per_head_projection_shapes(self):
        q, k, v = _qkv()
        Eh = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 4)) * 0.3
        out = blockwise_causal_attention(q, k, v, Eh, Eh, block_size=8)
        assert out.shape == q.shape


class TestDecode:
    def test_stepwise_matches_parallel(self):
        q, k, v = _qkv()
        ref = blockwise_causal_attention(q, k, v, EF, EF, block_size=8)
        cache = init_compressed_cache(
            num_layers=1, batch=2, max_seq=32, block_size=8, block_slots=4,
            num_kv_heads=2, head_dim=8, dtype=jnp.float32)
        lc = {kk: vv[0] for kk, vv in cache.items() if kk != "lengths"}
        outs = []
        for t in range(32):
            o, lc = compressed_decode_attention(
                q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1], lc, EF, EF,
                jnp.int32(t))
            outs.append(o)
        np.testing.assert_allclose(jnp.concatenate(outs, 1), ref, atol=1e-5)

    def test_cache_width_is_compressed(self):
        """The decode cache for n tokens holds c + r*(n/c) slots, not n."""
        S, c, r = 512, 32, 4
        cache = init_compressed_cache(
            num_layers=1, batch=1, max_seq=S, block_size=c, block_slots=r,
            num_kv_heads=2, head_dim=8)
        slots = cache["comp_k"].shape[2] + cache["raw_k"].shape[2]
        assert slots == (S // c) * r + c == 96   # 5.3x smaller than 512

    def test_block_fold_happens_at_boundary(self):
        q, k, v = _qkv(S=16)
        cache = init_compressed_cache(
            num_layers=1, batch=2, max_seq=16, block_size=8, block_slots=4,
            num_kv_heads=2, head_dim=8, dtype=jnp.float32)
        lc = {kk: vv[0] for kk, vv in cache.items() if kk != "lengths"}
        for t in range(7):
            _, lc = compressed_decode_attention(
                q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1], lc, EF, EF,
                jnp.int32(t))
        assert float(jnp.abs(lc["comp_k"]).sum()) == 0.0   # not folded yet
        _, lc = compressed_decode_attention(
            q[:, 7:8], k[:, 7:8], v[:, 7:8], lc, EF, EF, jnp.int32(7))
        assert float(jnp.abs(lc["comp_k"][:, :4]).sum()) > 0.0  # folded
        assert float(jnp.abs(lc["comp_k"][:, 4:]).sum()) == 0.0
